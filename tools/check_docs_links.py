"""Docs link checker: every relative link and code reference must resolve.

Scans ``docs/**/*.md``, ``ROADMAP.md``, and ``CHANGES.md`` for

* **relative markdown links** — ``[text](path)`` without a URL scheme;
  resolved against the linking file's directory,
* **anchors** — ``#fragment`` targets (same-file or on a relative ``.md``
  link) must match a real heading of the target file, slugified the way
  GitHub does it (lowercase, markdown formatting stripped, punctuation
  dropped, spaces to hyphens, ``-N`` suffixes for duplicates), and
* **backticked code references** — ``path/to/file.py``-shaped tokens with a
  known source extension; resolved against the repo root, ``src/``, and
  ``src/repro/`` (so prose can say ``core/oracle_pool.py`` the way the
  modules name themselves)

and fails if any target does not exist, so renames and deletions cannot rot
the docs silently.  Historical references (files a past PR renamed away,
exemplar paths from related external repos) live in
``tools/docs_link_allowlist.txt`` — one token per line, ``#`` comments.

CI runs this in the lint job; ``--self-test`` verifies the checker itself
still detects a deliberately broken link (a checker that silently passes
everything is worse than none):

    python tools/check_docs_links.py
    python tools/check_docs_links.py --self-test
"""
from __future__ import annotations

import argparse
import glob
import os
import re
import sys
import tempfile
from typing import Dict, List, Set, Tuple

# [text](target) — target without whitespace; schemes filtered later
_MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# ATX headings collected for anchor validation (fenced code excluded)
_HEADING = re.compile(r"^#{1,6}\s+(.+?)\s*#*\s*$")
_FENCE = re.compile(r"^\s{0,3}(```|~~~)")
_INLINE_MD = re.compile(r"\[([^\]]*)\]\([^)]*\)")  # [text](url) -> text
_NON_SLUG = re.compile(r"[^\w\- ]")
# `token.ext` with at least one path separator and a source-like extension
_CODE_REF = re.compile(
    r"`([A-Za-z0-9_\-./]*/[A-Za-z0-9_\-.]+\."
    r"(?:py|md|json|jsonl|yml|yaml|toml|ini|txt|sh|cfg))`")

DOC_GLOBS = ("docs/**/*.md", "ROADMAP.md", "CHANGES.md")
#: roots a code reference may resolve against, in order
CODE_ROOTS = ("", "src", os.path.join("src", "repro"))
ALLOWLIST_PATH = os.path.join("tools", "docs_link_allowlist.txt")


def _load_allowlist(root: str) -> Set[str]:
    path = os.path.join(root, ALLOWLIST_PATH)
    allowed: Set[str] = set()
    if os.path.isfile(path):
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line and not line.startswith("#"):
                    allowed.add(line)
    return allowed


def _slugify(heading: str) -> str:
    """GitHub's anchor algorithm: markdown stripped, lowercased, punctuation
    removed, spaces hyphenated."""
    text = _INLINE_MD.sub(r"\1", heading).replace("`", "")
    text = _NON_SLUG.sub("", text.strip().lower())
    return text.replace(" ", "-")


def _heading_anchors(path: str, cache: Dict[str, Set[str]]) -> Set[str]:
    """Every anchor the markdown file at ``path`` exposes (memoized)."""
    anchors = cache.get(path)
    if anchors is not None:
        return anchors
    anchors = set()
    counts: Dict[str, int] = {}
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for line in f:
            if _FENCE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            m = _HEADING.match(line)
            if m:
                slug = _slugify(m.group(1))
                n = counts.get(slug, 0)
                counts[slug] = n + 1
                anchors.add(slug if n == 0 else f"{slug}-{n}")
    cache[path] = anchors
    return anchors


def _doc_files(root: str) -> List[str]:
    files: List[str] = []
    for pattern in DOC_GLOBS:
        files.extend(sorted(glob.glob(os.path.join(root, pattern),
                                      recursive=True)))
    return [f for f in files if os.path.isfile(f)]


def _check_file(root: str, path: str, allowed: Set[str],
                anchor_cache: Dict[str, Set[str]]) -> List[Tuple[int, str, str]]:
    """(line, token, problem) triples for one markdown file."""
    problems: List[Tuple[int, str, str]] = []
    base = os.path.dirname(path)
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            for target in _MD_LINK.findall(line):
                bare, _, frag = target.partition("#")
                if "://" in target or bare.startswith("mailto:"):
                    continue
                if bare in allowed or target in allowed:
                    continue
                if not bare:
                    # same-file anchor: must name one of this file's headings
                    if frag and frag not in _heading_anchors(path,
                                                             anchor_cache):
                        problems.append((lineno, target,
                                         "broken anchor (no such heading "
                                         "in this file)"))
                    continue
                if os.path.isabs(bare):
                    problems.append((lineno, target,
                                     "absolute link (use a relative path)"))
                    continue
                resolved = os.path.normpath(os.path.join(base, bare))
                if not os.path.exists(resolved):
                    problems.append((lineno, target, "broken relative link"))
                    continue
                if frag and resolved.endswith(".md") \
                        and frag not in _heading_anchors(resolved,
                                                         anchor_cache):
                    problems.append((lineno, target,
                                     "broken anchor (no such heading in "
                                     f"{bare})"))
            for token in _CODE_REF.findall(line):
                if token in allowed or token.startswith("/"):
                    # absolute tokens are runtime paths (/tmp/...), not
                    # repo references
                    continue
                if not any(os.path.isfile(os.path.normpath(
                        os.path.join(root, r, token))) for r in CODE_ROOTS):
                    problems.append((lineno, token,
                                     "code reference resolves to no file "
                                     f"under {' / '.join(x or '.' for x in CODE_ROOTS)}"))
    return problems


def check(root: str) -> int:
    allowed = _load_allowlist(root)
    files = _doc_files(root)
    if not files:
        print(f"check_docs_links: no doc files found under {root}",
              file=sys.stderr)
        return 2
    anchor_cache: Dict[str, Set[str]] = {}
    n_problems = 0
    for path in files:
        for lineno, token, problem in _check_file(root, path, allowed,
                                                  anchor_cache):
            rel = os.path.relpath(path, root)
            print(f"{rel}:{lineno}: {problem}: {token}", file=sys.stderr)
            n_problems += 1
    if n_problems:
        print(f"check_docs_links: {n_problems} broken reference(s) across "
              f"{len(files)} file(s)", file=sys.stderr)
        return 1
    print(f"check_docs_links: OK ({len(files)} files)")
    return 0


def self_test() -> int:
    """The checker must flag a deliberately broken link and pass a good
    one; run by CI so a regression in the checker itself cannot hide."""
    with tempfile.TemporaryDirectory(prefix="docs-link-selftest-") as tmp:
        docs = os.path.join(tmp, "docs")
        os.makedirs(docs)
        with open(os.path.join(docs, "good.md"), "w") as f:
            f.write("# A `Good` Heading!\n"
                    "```\n# not a heading (fenced)\n```\n"
                    "see [the index](good.md) and `docs/good.md`,\n"
                    "[here](#a-good-heading) and "
                    "[also](good.md#a-good-heading)\n")
        if check(tmp) != 0:
            print("self-test FAILED: a valid doc was flagged",
                  file=sys.stderr)
            return 1
        with open(os.path.join(docs, "bad.md"), "w") as f:
            f.write("see [gone](no-such-file.md) and `src/missing.py`,\n"
                    "[frag](#no-such-heading) and "
                    "[xfrag](good.md#not-a-heading-fenced)\n")
        if check(tmp) != 1:
            print("self-test FAILED: broken references were not flagged",
                  file=sys.stderr)
            return 1
        probs = _check_file(tmp, os.path.join(docs, "bad.md"), set(), {})
        if sum("anchor" in p[2] for p in probs) != 2:
            print("self-test FAILED: broken anchors were not flagged as "
                  f"anchors: {probs}", file=sys.stderr)
            return 1
    print("check_docs_links: self-test OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="verify docs/ROADMAP/CHANGES file references resolve")
    ap.add_argument("--root", default=".",
                    help="repository root to scan (default: cwd)")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the checker flags a deliberately broken "
                         "link (and passes a valid one)")
    args = ap.parse_args(argv)
    if args.self_test:
        return self_test()
    return check(os.path.abspath(args.root))


if __name__ == "__main__":
    sys.exit(main())
