"""Docs snippet checker: ``python`` fences in the API docs must compile.

Extracts every fenced ```` ```python ```` block from ``docs/api/*.md`` and
runs it through :func:`compile` (syntax only — snippets are not executed,
so they may reference servers, paths, and fixtures that don't exist here).
A snippet that drifts into pseudo-code or breaks with an API rename fails
the lint job instead of silently mis-teaching the reader.

Snippets that are deliberately illustrative fragments can opt out by
putting ``# not-runnable`` on their first line.

    python tools/check_docs_snippets.py
    python tools/check_docs_snippets.py --self-test
"""
from __future__ import annotations

import argparse
import glob
import os
import re
import sys
import tempfile
from typing import List, Tuple

DOC_GLOB = os.path.join("docs", "api", "*.md")
_OPEN = re.compile(r"^\s{0,3}```python\s*$")
_CLOSE = re.compile(r"^\s{0,3}```\s*$")
OPT_OUT = "# not-runnable"


def extract(path: str) -> List[Tuple[int, str]]:
    """(first fence line, source) for each python fence in one file."""
    snippets: List[Tuple[int, str]] = []
    lines: List[str] = []
    start = None
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            if start is None:
                if _OPEN.match(line):
                    start = lineno
                    lines = []
            elif _CLOSE.match(line):
                snippets.append((start, "".join(lines)))
                start = None
            else:
                lines.append(line)
    if start is not None:
        snippets.append((start, "".join(lines)))  # unterminated: still check
    return snippets


def check(root: str) -> int:
    files = sorted(glob.glob(os.path.join(root, DOC_GLOB)))
    if not files:
        print(f"check_docs_snippets: no files match {DOC_GLOB} under {root}",
              file=sys.stderr)
        return 2
    n_snippets = 0
    n_problems = 0
    for path in files:
        rel = os.path.relpath(path, root)
        for lineno, source in extract(path):
            if source.lstrip().startswith(OPT_OUT):
                continue
            n_snippets += 1
            try:
                compile(source, f"{rel}:{lineno}", "exec")
            except SyntaxError as e:
                # e.lineno is relative to the snippet; report doc-file lines
                print(f"{rel}:{lineno + (e.lineno or 0)}: snippet does not "
                      f"compile: {e.msg}", file=sys.stderr)
                n_problems += 1
    if n_problems:
        print(f"check_docs_snippets: {n_problems} broken snippet(s) across "
              f"{len(files)} file(s)", file=sys.stderr)
        return 1
    print(f"check_docs_snippets: OK ({n_snippets} snippets, "
          f"{len(files)} files)")
    return 0


def self_test() -> int:
    """The checker must flag a deliberately broken fence and pass a valid
    one — same discipline as ``check_docs_links.py --self-test``."""
    with tempfile.TemporaryDirectory(prefix="docs-snippet-selftest-") as tmp:
        api = os.path.join(tmp, "docs", "api")
        os.makedirs(api)
        with open(os.path.join(api, "good.md"), "w") as f:
            f.write("# Good\n```python\nstore = open_store('/tmp/x')\n```\n"
                    "```python\n# not-runnable\nhot -> warm -> oracle\n```\n"
                    "```\nnot python, ignored {\n```\n")
        if check(tmp) != 0:
            print("self-test FAILED: a valid snippet was flagged",
                  file=sys.stderr)
            return 1
        with open(os.path.join(api, "bad.md"), "w") as f:
            f.write("# Bad\n```python\ndef broken(:\n```\n")
        if check(tmp) != 1:
            print("self-test FAILED: a broken snippet was not flagged",
                  file=sys.stderr)
            return 1
    print("check_docs_snippets: self-test OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="compile-check python fences in docs/api/*.md")
    ap.add_argument("--root", default=".",
                    help="repository root to scan (default: cwd)")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the checker flags a deliberately broken "
                         "snippet (and passes a valid one)")
    args = ap.parse_args(argv)
    if args.self_test:
        return self_test()
    return check(os.path.abspath(args.root))


if __name__ == "__main__":
    sys.exit(main())
