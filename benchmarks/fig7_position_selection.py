"""Fig. 7: SUPG selection of objects on the left-hand side — a query that
violates the Lipschitz assumption; prior proxies were not designed for
positions (paper §6.4)."""
import numpy as np

from benchmarks import common
from repro.core.queries.selection import false_positive_rate, supg_recall_target


def run(quick: bool = False):
    rows = []
    ds = "night-street"
    wl = common.get_workload(ds, quick)
    truth = common.truth_vector(wl, "score_left_side") > 0.5

    def oracle(ids):
        return truth[ids].astype(float)
    budget = 300 if quick else 500
    bl = common.get_blazeit_scores(ds, "score_left_side", quick, classify=True)
    seeds = range(2 if quick else 4)

    def mean_fpr(proxy):
        return float(np.mean([false_positive_rate(
            supg_recall_target(np.clip(proxy, 0, 1), oracle, budget=budget,
                               seed=s).selected, truth) for s in seeds]))

    rows.append(("fig7/blazeit", "fpr", round(mean_fpr(bl), 4)))
    for variant in ("PT", "T"):
        sv = common.get_tasti(ds, variant, quick)
        proxy = sv.proxy_scores(wl.score_left_side)
        rows.append((f"fig7/tasti_{variant.lower()}", "fpr",
                     round(mean_fpr(proxy), 4)))
    return rows
