"""Fig. 9: factor analysis — optimizations added in sequence (none -> +triplet
-> +FPF mining -> +FPF clustering) on aggregation and limit queries."""
import numpy as np

from benchmarks import common
from repro.core.pipeline import build_tasti
from repro.core.queries.aggregation import aggregate_control_variates
from repro.core.queries.limit import limit_query


def _eval(sv, wl, truth_cnt, truth_rare, rare_fn):
    agg = aggregate_control_variates(sv.proxy_scores(wl.score_count),
                                     lambda i: truth_cnt[i], err=0.05,
                                     seed=0).n_invocations
    lim = limit_query(sv.proxy_scores(rare_fn, mode="top1"),
                      lambda i: truth_rare[i], k_results=5, batch=4).n_invocations
    return agg, lim


def run(quick: bool = False):
    rows = []
    ds = "night-street"
    wl = common.get_workload(ds, quick)
    truth_cnt = common.truth_vector(wl, "score_count")
    rare_fn = common.rare_event_fn(wl, ds)
    truth_rare = np.asarray([rare_fn(r) for r in
                             wl.target_dnn_batch(range(len(wl.features)))])
    stages = [
        ("none", dict(variant="PT", use_fpf_mining=False,
                      use_fpf_clustering=False)),
        ("+triplet", dict(variant="T", use_fpf_mining=False,
                          use_fpf_clustering=False)),
        ("+fpf_mining", dict(variant="T", use_fpf_mining=True,
                             use_fpf_clustering=False)),
        ("+fpf_clustering", dict(variant="T", use_fpf_mining=True,
                                 use_fpf_clustering=True)),
    ]
    for name, kw in stages:
        sv = build_tasti(wl, common.tasti_cfg(quick), **kw)
        agg, lim = _eval(sv, wl, truth_cnt, truth_rare, rare_fn)
        rows.append((f"fig9/{name}/agg", "invocations", agg))
        rows.append((f"fig9/{name}/limit", "invocations", lim))
    return rows
