"""Fig. 4: target-DNN invocations for aggregation queries (lower is better):
random sampling, BlazeIt proxy (10x construction budget), TASTI-PT, TASTI-T.
"""
import numpy as np

from benchmarks import common
from repro.core.queries.aggregation import aggregate_control_variates


def run(quick: bool = False):
    rows = []
    err = 0.05
    for ds in common.ALL_SETS:
        wl = common.get_workload(ds, quick)
        attr = common.agg_score_attr(ds)
        truth = common.truth_vector(wl, attr)
        oracle = lambda ids: truth[ids]
        seeds = range(2 if quick else 3)

        def mean_inv(proxy, use_cv=True):
            return float(np.mean([aggregate_control_variates(
                proxy, oracle, err=err, seed=s, use_cv=use_cv).n_invocations
                for s in seeds]))

        rnd = mean_inv(np.zeros(len(truth)), use_cv=False)
        rows.append((f"fig4/{ds}/random", "invocations", rnd))
        bl = common.get_blazeit_scores(ds, attr, quick)
        rows.append((f"fig4/{ds}/blazeit", "invocations", mean_inv(bl)))
        for variant in ("PT", "T"):
            sv = common.get_tasti(ds, variant, quick)
            proxy = sv.proxy_scores(getattr(wl, attr))
            rows.append((f"fig4/{ds}/tasti_{variant.lower()}", "invocations",
                         mean_inv(proxy)))
            if variant == "T":
                rho2 = float(np.corrcoef(proxy, truth)[0, 1] ** 2)
                rows.append((f"fig4/{ds}/tasti_t_rho2", "rho2", round(rho2, 3)))
    return rows
