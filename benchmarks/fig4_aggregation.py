"""Fig. 4: target-DNN invocations for aggregation queries (lower is better):
random sampling, BlazeIt proxy (10x construction budget), TASTI-PT, TASTI-T.
All methods run through the declarative engine (``QuerySpec`` -> plan ->
execute); baselines supply their proxy scores via the spec's ``proxy``
override, TASTI variants use the engine's memoized propagation.
"""
import numpy as np

from benchmarks import common
from repro.core.engine import QuerySpec


def run(quick: bool = False):
    rows = []
    err = 0.05
    for ds in common.ALL_SETS:
        wl = common.get_workload(ds, quick)
        attr = common.agg_score_attr(ds)
        truth = common.truth_vector(wl, attr)
        seeds = range(2 if quick else 3)

        def mean_inv(engine, proxy=None, use_cv=True):
            return float(np.mean([engine.execute(QuerySpec(
                kind="aggregation", score=attr, proxy=proxy, err=err,
                seed=s, use_cv=use_cv, reuse_labels=False)).n_invocations
                for s in seeds]))

        eng_t = common.get_engine(ds, "T", quick)
        rnd = mean_inv(eng_t, proxy=np.zeros(len(truth)), use_cv=False)
        rows.append((f"fig4/{ds}/random", "invocations", rnd))
        bl = common.get_blazeit_scores(ds, attr, quick)
        rows.append((f"fig4/{ds}/blazeit", "invocations",
                     mean_inv(eng_t, proxy=bl)))
        for variant in ("PT", "T"):
            eng = common.get_engine(ds, variant, quick)
            rows.append((f"fig4/{ds}/tasti_{variant.lower()}", "invocations",
                         mean_inv(eng)))
            if variant == "T":
                proxy = eng.proxy_scores(attr)
                rho2 = float(np.corrcoef(proxy, truth)[0, 1] ** 2)
                rows.append((f"fig4/{ds}/tasti_t_rho2", "rho2", round(rho2, 3)))
    return rows
