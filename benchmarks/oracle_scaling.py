"""Oracle sharding scaling: flush latency and queries/s vs replica count.

The unit under test is the serving stack's expensive path — an
:class:`~repro.core.broker.OracleBroker` flush — against a sleep-calibrated
synthetic target DNN (fixed per-batch setup cost plus per-id cost, like real
batched inference; ``time.sleep`` releases the GIL, so replicas genuinely
overlap, as a real model would).  For each replica count we measure

* **flush latency / labels per second** — one big microbatched flush of
  ``n_ids`` pending ids;
* **queries/s** — a train of smaller request+flush cycles (each cycle is
  one query's oracle demand hitting the broker).

Asserted, not just reported: >=1.5x flush-throughput speedup at 4 replicas
over 1, and byte-identical labels plus identical fresh/cached accounting at
every replica count (sharding must never change an answer or a charge).

    PYTHONPATH=src python -m benchmarks.oracle_scaling --quick --json out.json
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.broker import OracleBroker
from repro.core.oracle_pool import OraclePool

REPLICA_COUNTS = (1, 2, 4)
SPEEDUP_FLOOR = 1.5          # required flush-throughput gain at 4 replicas
PER_BATCH_S = 0.004          # fixed cost per target_dnn_batch call
PER_ID_S = 0.00005           # marginal cost per id


def _sleepy_oracle(per_batch_s: float = PER_BATCH_S,
                   per_id_s: float = PER_ID_S):
    """A calibrated stand-in for batched target-DNN inference."""
    def annotate(ids):
        time.sleep(per_batch_s + per_id_s * len(ids))
        return [int(i) * 2 for i in ids]
    return annotate


def _measure(n_replicas: int, n_ids: int, max_batch: int,
             n_queries: int, query_ids: int) -> Dict[str, object]:
    annotate = _sleepy_oracle()
    pool = (OraclePool(annotate, n_replicas=n_replicas)
            if n_replicas > 1 else None)
    broker = OracleBroker(annotate, max_batch=max_batch, pool=pool)
    acct = broker.account("bench")
    try:
        # one big flush: the latency a session's combined prefetch pays
        broker.request(np.arange(n_ids), account=acct)
        t0 = time.perf_counter()
        broker.flush()
        flush_s = time.perf_counter() - t0
        labels = broker.fetch(np.arange(n_ids), account=acct)

        # a train of query-sized cycles: fresh ids each, flushed per query
        t0 = time.perf_counter()
        for q in range(n_queries):
            lo = n_ids + q * query_ids
            broker.fetch(np.arange(lo, lo + query_ids), account=acct)
        queries_s = time.perf_counter() - t0
    finally:
        if pool is not None:
            pool.close()
    return {
        "replicas": n_replicas,
        "flush_latency_s": flush_s,
        "labels_per_s": n_ids / max(flush_s, 1e-9),
        "queries_per_s": n_queries / max(queries_s, 1e-9),
        "labels": labels,
        "fresh": acct.fresh,
        "cached": acct.cached,
        "broker_fresh": broker.stats["fresh"],
        "broker_cached": broker.stats["cached"],
    }


def scaling(quick: bool = False) -> Dict[str, Dict[str, object]]:
    """Measurements per replica count, parity-checked against 1 replica."""
    n_ids = 512 if quick else 2048
    n_queries = 4 if quick else 8
    query_ids = 64 if quick else 128
    out: Dict[str, Dict[str, object]] = {}
    for r in REPLICA_COUNTS:
        out[str(r)] = _measure(r, n_ids, max_batch=32,
                               n_queries=n_queries, query_ids=query_ids)
    base = out["1"]
    for r in REPLICA_COUNTS[1:]:
        m = out[str(r)]
        if m["labels"] != base["labels"]:
            raise AssertionError(
                f"{r}-replica labels differ from the single-oracle path")
        acct_keys = ("fresh", "cached", "broker_fresh", "broker_cached")
        if any(m[k] != base[k] for k in acct_keys):
            raise AssertionError(
                f"{r}-replica accounting differs from single-oracle: "
                + ", ".join(f"{k}={m[k]} vs {base[k]}" for k in acct_keys))
    speedup = (base["flush_latency_s"]
               / max(out["4"]["flush_latency_s"], 1e-9))
    if speedup < SPEEDUP_FLOOR:
        raise AssertionError(
            f"4-replica flush speedup {speedup:.2f}x < required "
            f"{SPEEDUP_FLOOR}x (1 replica: {base['flush_latency_s']:.3f}s, "
            f"4 replicas: {out['4']['flush_latency_s']:.3f}s)")
    for m in out.values():
        m.pop("labels")  # bulky; parity already asserted
        m["speedup_vs_1"] = (base["flush_latency_s"]
                             / max(m["flush_latency_s"], 1e-9))
    return out


def run(quick: bool = False) -> List[tuple]:
    """Benchmark-harness entry point: CSV rows per replica count."""
    out = scaling(quick)
    rows = []
    for r in REPLICA_COUNTS:
        m = out[str(r)]
        rows.append((f"oracle_scaling/replicas_{r}", "flush_latency_s",
                     round(m["flush_latency_s"], 4)))
        rows.append((f"oracle_scaling/replicas_{r}", "labels_per_s",
                     round(m["labels_per_s"], 1)))
        rows.append((f"oracle_scaling/replicas_{r}", "queries_per_s",
                     round(m["queries_per_s"], 2)))
        rows.append((f"oracle_scaling/replicas_{r}", "speedup_vs_1",
                     round(m["speedup_vs_1"], 2)))
    return rows


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="flush latency and queries/s vs oracle replica count")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="also write the full measurements as JSON (the CI "
                         "bench-oracle-scaling artifact)")
    args = ap.parse_args(argv)
    out = scaling(args.quick)
    payload = {"quick": args.quick, "speedup_floor": SPEEDUP_FLOOR,
               "speedup_at_4": out["4"]["speedup_vs_1"], "replicas": out}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
    print(json.dumps(payload, indent=2))


if __name__ == "__main__":
    main()
