"""Oracle sharding scaling: flush latency and queries/s vs replica count.

The unit under test is the serving stack's expensive path — an
:class:`~repro.core.broker.OracleBroker` flush — against a sleep-calibrated
synthetic target DNN (fixed per-batch setup cost plus per-id cost, like real
batched inference; ``time.sleep`` releases the GIL, so replicas genuinely
overlap, as a real model would).  For each replica count we measure

* **flush latency / labels per second** — one big microbatched flush of
  ``n_ids`` pending ids;
* **queries/s** — a train of smaller request+flush cycles (each cycle is
  one query's oracle demand hitting the broker).

Asserted, not just reported: >=1.5x flush-throughput speedup at 4 replicas
over 1, and byte-identical labels plus identical fresh/cached accounting at
every replica count (sharding must never change an answer or a charge).

The **compute-bound leg** is the backend discriminator: the same flush
against a pure-Python hot-loop oracle that *holds* the GIL.  Thread
replicas serialize (speedup must stay < 1.3x — if they ever "pass", the
oracle stopped being compute-bound and the leg is meaningless), while
forked process replicas must reach >= 2.5x at 4 replicas on a >=4-core
machine (the assert is skipped below 4 cores, where no backend could).
Labels and accounting parity across inline/thread/process is asserted
unconditionally.

    PYTHONPATH=src python -m benchmarks.oracle_scaling --quick --json out.json
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.broker import OracleBroker
from repro.core.oracle_pool import OraclePool

REPLICA_COUNTS = (1, 2, 4)
SPEEDUP_FLOOR = 1.5          # required flush-throughput gain at 4 replicas
PER_BATCH_S = 0.004          # fixed cost per target_dnn_batch call
PER_ID_S = 0.00005           # marginal cost per id

COMPUTE_SPEEDUP_FLOOR = 2.5    # process backend, 4 replicas, >=4 cores
THREAD_SPEEDUP_CEILING = 1.3   # GIL bound: thread backend cannot beat this
COMPUTE_ITERS = 4000           # pure-Python loop iterations per id


def _sleepy_oracle(per_batch_s: float = PER_BATCH_S,
                   per_id_s: float = PER_ID_S):
    """A calibrated stand-in for batched target-DNN inference."""
    def annotate(ids):
        time.sleep(per_batch_s + per_id_s * len(ids))
        return [int(i) * 2 for i in ids]
    return annotate


def _measure(n_replicas: int, n_ids: int, max_batch: int,
             n_queries: int, query_ids: int) -> Dict[str, object]:
    annotate = _sleepy_oracle()
    pool = (OraclePool(annotate, n_replicas=n_replicas)
            if n_replicas > 1 else None)
    broker = OracleBroker(annotate, max_batch=max_batch, pool=pool)
    acct = broker.account("bench")
    try:
        # one big flush: the latency a session's combined prefetch pays
        broker.request(np.arange(n_ids), account=acct)
        t0 = time.perf_counter()
        broker.flush()
        flush_s = time.perf_counter() - t0
        labels = broker.fetch(np.arange(n_ids), account=acct)

        # a train of query-sized cycles: fresh ids each, flushed per query
        t0 = time.perf_counter()
        for q in range(n_queries):
            lo = n_ids + q * query_ids
            broker.fetch(np.arange(lo, lo + query_ids), account=acct)
        queries_s = time.perf_counter() - t0
    finally:
        if pool is not None:
            pool.close()
    return {
        "replicas": n_replicas,
        "flush_latency_s": flush_s,
        "labels_per_s": n_ids / max(flush_s, 1e-9),
        "queries_per_s": n_queries / max(queries_s, 1e-9),
        "labels": labels,
        "fresh": acct.fresh,
        "cached": acct.cached,
        "broker_fresh": broker.stats["fresh"],
        "broker_cached": broker.stats["cached"],
    }


def scaling(quick: bool = False) -> Dict[str, Dict[str, object]]:
    """Measurements per replica count, parity-checked against 1 replica."""
    n_ids = 512 if quick else 2048
    n_queries = 4 if quick else 8
    query_ids = 64 if quick else 128
    out: Dict[str, Dict[str, object]] = {}
    for r in REPLICA_COUNTS:
        out[str(r)] = _measure(r, n_ids, max_batch=32,
                               n_queries=n_queries, query_ids=query_ids)
    base = out["1"]
    for r in REPLICA_COUNTS[1:]:
        m = out[str(r)]
        if m["labels"] != base["labels"]:
            raise AssertionError(
                f"{r}-replica labels differ from the single-oracle path")
        acct_keys = ("fresh", "cached", "broker_fresh", "broker_cached")
        if any(m[k] != base[k] for k in acct_keys):
            raise AssertionError(
                f"{r}-replica accounting differs from single-oracle: "
                + ", ".join(f"{k}={m[k]} vs {base[k]}" for k in acct_keys))
    speedup = (base["flush_latency_s"]
               / max(out["4"]["flush_latency_s"], 1e-9))
    if speedup < SPEEDUP_FLOOR:
        raise AssertionError(
            f"4-replica flush speedup {speedup:.2f}x < required "
            f"{SPEEDUP_FLOOR}x (1 replica: {base['flush_latency_s']:.3f}s, "
            f"4 replicas: {out['4']['flush_latency_s']:.3f}s)")
    for m in out.values():
        m.pop("labels")  # bulky; parity already asserted
        m["speedup_vs_1"] = (base["flush_latency_s"]
                             / max(m["flush_latency_s"], 1e-9))
    return out


# ---------------------------------------------------------------------------
# compute-bound leg: the backend discriminator
# ---------------------------------------------------------------------------
def _compute_bound_oracle(iters: int = COMPUTE_ITERS):
    """A target DNN whose cost is pure-Python bytecode — it never releases
    the GIL, so thread replicas serialize and only process replicas scale."""
    def annotate(ids):
        out = []
        for i in ids:
            acc = 0
            for j in range(iters):
                acc += (j * j) % 7
            out.append(int(i) * 2 + (acc - acc))
        return out
    return annotate


def _measure_compute(backend: Optional[str], n_ids: int,
                     max_batch: int) -> Dict[str, object]:
    """One flush of ``n_ids`` against the compute-bound oracle: inline
    (``backend=None``), or 4 replicas on the given backend.  Pool spawn
    cost stays outside the timed window, like a serving deployment."""
    annotate = _compute_bound_oracle()
    pool = (OraclePool(annotate, n_replicas=4, backend=backend)
            if backend is not None else None)
    broker = OracleBroker(annotate, max_batch=max_batch, pool=pool)
    acct = broker.account("bench")
    try:
        broker.request(np.arange(n_ids), account=acct)
        t0 = time.perf_counter()
        broker.flush()
        flush_s = time.perf_counter() - t0
        labels = broker.fetch(np.arange(n_ids), account=acct)
    finally:
        if pool is not None:
            pool.close()
    return {
        "backend": backend or "inline",
        "flush_latency_s": flush_s,
        "labels_per_s": n_ids / max(flush_s, 1e-9),
        "labels": labels,
        "fresh": acct.fresh,
        "cached": acct.cached,
        "broker_fresh": broker.stats["fresh"],
        "broker_cached": broker.stats["cached"],
    }


def compute_bound(quick: bool = False) -> Dict[str, object]:
    """Inline vs thread vs process backend on the GIL-holding oracle, with
    parity asserted and the backend speedup bounds enforced."""
    n_ids = 192 if quick else 512
    legs = {"inline": _measure_compute(None, n_ids, max_batch=32),
            "thread": _measure_compute("thread", n_ids, max_batch=32),
            "process": _measure_compute("process", n_ids, max_batch=32)}
    base = legs["inline"]
    acct_keys = ("fresh", "cached", "broker_fresh", "broker_cached")
    for name in ("thread", "process"):
        m = legs[name]
        if m["labels"] != base["labels"]:
            raise AssertionError(
                f"{name}-backend labels differ from the inline path")
        if any(m[k] != base[k] for k in acct_keys):
            raise AssertionError(
                f"{name}-backend accounting differs from inline: "
                + ", ".join(f"{k}={m[k]} vs {base[k]}" for k in acct_keys))
    thread_speedup = (base["flush_latency_s"]
                      / max(legs["thread"]["flush_latency_s"], 1e-9))
    process_speedup = (base["flush_latency_s"]
                       / max(legs["process"]["flush_latency_s"], 1e-9))
    if thread_speedup >= THREAD_SPEEDUP_CEILING:
        raise AssertionError(
            f"thread backend 'sped up' the GIL-holding oracle "
            f"{thread_speedup:.2f}x (>= {THREAD_SPEEDUP_CEILING}x): the "
            "compute-bound leg is no longer compute-bound")
    cores = os.cpu_count() or 1
    if cores >= 4 and process_speedup < COMPUTE_SPEEDUP_FLOOR:
        raise AssertionError(
            f"process backend speedup {process_speedup:.2f}x < required "
            f"{COMPUTE_SPEEDUP_FLOOR}x at 4 replicas on {cores} cores "
            f"(inline: {base['flush_latency_s']:.3f}s, process: "
            f"{legs['process']['flush_latency_s']:.3f}s)")
    for m in legs.values():
        m.pop("labels")  # bulky; parity already asserted
    return {"cpu_count": cores,
            "thread_speedup_at_4": round(thread_speedup, 3),
            "process_speedup_at_4": round(process_speedup, 3),
            "process_gate_active": cores >= 4,
            "legs": legs}


def run(quick: bool = False) -> List[tuple]:
    """Benchmark-harness entry point: CSV rows per replica count."""
    out = scaling(quick)
    cb = compute_bound(quick)
    rows = []
    for r in REPLICA_COUNTS:
        m = out[str(r)]
        rows.append((f"oracle_scaling/replicas_{r}", "flush_latency_s",
                     round(m["flush_latency_s"], 4)))
        rows.append((f"oracle_scaling/replicas_{r}", "labels_per_s",
                     round(m["labels_per_s"], 1)))
        rows.append((f"oracle_scaling/replicas_{r}", "queries_per_s",
                     round(m["queries_per_s"], 2)))
        rows.append((f"oracle_scaling/replicas_{r}", "speedup_vs_1",
                     round(m["speedup_vs_1"], 2)))
    for name in ("thread", "process"):
        rows.append(("oracle_scaling/compute_bound",
                     f"{name}_speedup_at_4", cb[f"{name}_speedup_at_4"]))
    return rows


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="flush latency and queries/s vs oracle replica count")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="also write the full measurements as JSON (the CI "
                         "bench-oracle-scaling artifact)")
    args = ap.parse_args(argv)
    out = scaling(args.quick)
    cb = compute_bound(args.quick)
    payload = {"quick": args.quick, "speedup_floor": SPEEDUP_FLOOR,
               "speedup_at_4": out["4"]["speedup_vs_1"], "replicas": out,
               "compute_bound": cb}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
    print(json.dumps(payload, indent=2))


if __name__ == "__main__":
    main()
