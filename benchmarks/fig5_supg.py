"""Fig. 5: false-positive rate for recall-target SUPG queries (lower is
better): BlazeIt-style proxy vs TASTI-PT vs TASTI-T at a fixed oracle budget.
All methods execute ``QuerySpec(kind="selection")`` through the engine (which
clips proxies to [0,1] and picks numeric propagation automatically).
"""
import numpy as np

from benchmarks import common
from repro.core.engine import QuerySpec
from repro.core.queries.selection import achieved_recall, false_positive_rate


def run(quick: bool = False):
    rows = []
    for ds in common.ALL_SETS:
        wl = common.get_workload(ds, quick)
        score_fn = common.sel_score_fn(wl, ds)
        n = len(wl.features)
        truth = np.asarray([score_fn(r) for r in
                            wl.target_dnn_batch(range(n))]) > 0.5
        budget = 300 if quick else 500
        seeds = range(2 if quick else 4)

        def mean_fpr(engine, proxy=None):
            fprs, recs = [], []
            for s in seeds:
                r = engine.execute(QuerySpec(
                    kind="selection", score=score_fn, proxy=proxy,
                    budget=budget, recall_target=0.9, delta=0.05, seed=s,
                    score_key=f"fig5/{ds}", reuse_labels=False))
                fprs.append(false_positive_rate(r.selected, truth))
                recs.append(achieved_recall(r.selected, truth))
            return float(np.mean(fprs)), float(np.mean(recs))

        eng_t = common.get_engine(ds, "T", quick)
        bl = common.get_blazeit_scores(ds, "sel_rare", quick, classify=True,
                                       score_fn=score_fn)
        f, rec = mean_fpr(eng_t, proxy=bl)
        rows.append((f"fig5/{ds}/blazeit", "fpr", round(f, 4)))
        rows.append((f"fig5/{ds}/blazeit_recall", "recall", round(rec, 3)))
        for variant in ("PT", "T"):
            eng = common.get_engine(ds, variant, quick)
            f, rec = mean_fpr(eng)
            rows.append((f"fig5/{ds}/tasti_{variant.lower()}", "fpr", round(f, 4)))
            rows.append((f"fig5/{ds}/tasti_{variant.lower()}_recall", "recall",
                         round(rec, 3)))
    return rows
