"""Tiered LabelStore: bigger-than-memory label reuse stays free.

TASTI's economics only hold if labels paid for once stay reusable — and a
long-lived deployment accumulates more labels than it wants resident in
RAM.  This leg measures the tiered store under exactly that pressure, in
three phases over one engine + index:

* **cold** — empty store, every label paid at the target DNN; records the
  total label bytes the workload produced (the sizing input);
* **tiered warm restart** — a NEW engine over the same stem, with the hot
  budget clamped to ~10% of those label bytes.  The repeat spec list must
  cost **0 fresh oracle calls** (answered hot + warm), and the tracked hot
  bytes must never exceed the budget — both asserted, not just reported;
* **lookup microbench** — the broker's per-id serving sequence (membership
  probe, tier-attributed ``record_hit``, read) against a fully-hot store vs
  one whose answers come from warm segments; the warm/hot time ratio is
  gated (within 5x) so segment lookups can't quietly regress into a per-id
  file parse.  Raw batched ``get_many`` numbers ride along unenforced.

    PYTHONPATH=src python -m benchmarks.label_store_tiering --quick --json out.json

(the ``--json`` form feeds the CI ``bench-gate`` job's regression check,
``benchmarks/check_regression.py``)
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time
from typing import List, Optional

from benchmarks import common
from repro.core.engine import QueryEngine, QuerySpec
from repro.core.index import TastiIndex
from repro.serve import LabelStore


def _specs(quick: bool) -> List[QuerySpec]:
    out = []
    for seed in range(3 if quick else 6):
        out.append(QuerySpec(kind="aggregation", score="score_count",
                             err=0.15, seed=seed))
        out.append(QuerySpec(kind="selection", score="score_has_object",
                             budget=100 + 20 * seed, seed=seed))
        out.append(QuerySpec(kind="limit", score="score_has_object",
                             k_results=3 + seed % 3))
    return out


def _drive(engine: QueryEngine, specs: List[QuerySpec]) -> int:
    fresh0 = engine.broker.stats["fresh"]
    for spec in specs:
        engine.execute(spec)
    return engine.broker.stats["fresh"] - fresh0


def run(quick: bool = False):
    wl = common.get_workload("night-street", quick)
    index = TastiIndex.build(wl.features, 150 if quick else 300,
                             wl.target_dnn_batch, k=4, random_fraction=0.0,
                             seed=0)
    specs = _specs(quick)
    rows = []

    with tempfile.TemporaryDirectory() as tmp:
        stem = f"{tmp}/store"

        # -- phase 1: cold — pay for every label once, unbounded hot tier
        engine = QueryEngine(index, wl)
        store = LabelStore.for_index(stem, index)
        store.attach(engine.broker, engine)
        fresh_cold = _drive(engine, specs)
        label_bytes = store.observe()["hot"]["bytes"]
        n_labels = len(store)
        store.save()
        engine.close()
        rows.append(("store/cold", "fresh_per_query",
                     round(fresh_cold / len(specs), 2)))
        rows.append(("store/cold", "labels", n_labels))
        rows.append(("store/cold", "label_bytes", label_bytes))

        # -- phase 2: warm restart with hot budget ~10% of the label bytes.
        # NEW engine + broker: every repeat answer comes from the store's
        # hot or warm tier, never the oracle, and the hot tier must hold
        # its budget while serving.
        budget = max(4096, label_bytes // 10)
        engine = QueryEngine(index, wl)
        store = LabelStore.for_index(stem, index, hot_budget=budget)
        seeded = store.attach(engine.broker, engine)
        t0 = time.perf_counter()
        fresh_warm = _drive(engine, specs)
        elapsed = time.perf_counter() - t0
        obs = store.observe()
        engine.close()
        if fresh_warm != 0:
            raise AssertionError(
                f"tiered warm restart (budget {budget}B of {label_bytes}B) "
                f"issued {fresh_warm} fresh target-DNN invocations on a "
                "repeated spec list; hot+warm tiers must answer repeats "
                "for free")
        if obs["hot"]["bytes"] > budget:
            raise AssertionError(
                f"tracked hot bytes {obs['hot']['bytes']} exceed the "
                f"budget {budget} after serving")
        rows.append(("store/warm_restart", "fresh_per_query",
                     round(fresh_warm / len(specs), 2)))
        rows.append(("store/warm_restart", "seeded", seeded))
        rows.append(("store/warm_restart", "hot_budget_bytes", budget))
        rows.append(("store/warm_restart", "hot_bytes", obs["hot"]["bytes"]))
        rows.append(("store/warm_restart", "warm_hits",
                     obs["hits"]["warm"]))
        rows.append(("store/warm_restart", "evictions",
                     obs["counters"]["evictions"]))
        rows.append(("store/warm_restart", "queries_per_s",
                     round(len(specs) / max(elapsed, 1e-9), 2)))

        # -- phase 3: serving-path lookup microbench, hot vs warm.
        # What a repeat query pays per already-owned label is the broker's
        # per-id sequence against the store view: membership probe,
        # tier-attributed record_hit, then the read.  A fully-hot store vs
        # a tiny-budget one whose answers come from warm segments; the
        # warm/hot ratio is the gated number (within 5x), so segment reads
        # can't quietly regress into a per-id file parse.  Best-of-5 damps
        # scheduler jitter.
        hot_store = LabelStore.open(stem, index.version)
        cold_store = LabelStore.open(stem, index.version, hot_budget=4096)
        ids = sorted(hot_store.labels)
        hot_store.get_many(ids)  # fault everything hot

        def serve_pass(store_):
            t0 = time.perf_counter()
            for i in ids:
                assert i in store_
                store_.record_hit(i)
                store_.broker_get(i)
            return time.perf_counter() - t0

        def best_of(store_, warm):
            best = float("inf")
            for _ in range(5):
                if warm:
                    with store_._lock:
                        store_._hot.evict(0)  # push everything back warm
                best = min(best, serve_pass(store_))
            return best

        t_hot = best_of(hot_store, warm=False)
        t_warm = best_of(cold_store, warm=True)
        ratio = t_warm / max(t_hot, 1e-9)
        if ratio > 5.0:
            raise AssertionError(
                f"warm-tier lookup is {ratio:.1f}x hot-tier lookup "
                f"({t_warm * 1e6:.0f}us vs {t_hot * 1e6:.0f}us for "
                f"{n_labels} ids); segment reads must stay within 5x")
        rows.append(("store/lookup", "hot_us_per_id",
                     round(t_hot / n_labels * 1e6, 3)))
        rows.append(("store/lookup", "warm_us_per_id",
                     round(t_warm / n_labels * 1e6, 3)))
        rows.append(("store/lookup", "warm_hot_ratio", round(ratio, 3)))

        # informational: raw batched get_many per id, both tiers
        t0 = time.perf_counter()
        hot_store.get_many(ids)
        t_hb = time.perf_counter() - t0
        with cold_store._lock:
            cold_store._hot.evict(0)
        t0 = time.perf_counter()
        cold_store.get_many(ids, promote=False)
        t_wb = time.perf_counter() - t0
        rows.append(("store/lookup", "hot_batch_us_per_id",
                     round(t_hb / n_labels * 1e6, 3)))
        rows.append(("store/lookup", "warm_batch_us_per_id",
                     round(t_wb / n_labels * 1e6, 3)))
    return rows


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="tiered label store: budgeted warm restart costs zero "
                    "fresh labels; warm lookups stay near hot speed")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="also write the measurements as JSON (the CI "
                         "bench-gate artifact)")
    args = ap.parse_args(argv)
    rows = run(args.quick)
    payload = {"quick": args.quick,
               "metrics": {f"{name}.{metric}": value
                           for name, metric, value in rows}}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
    print(json.dumps(payload, indent=2))


if __name__ == "__main__":
    main()
