"""Fig. 3: construction cost vs aggregation performance for a range of TASTI
parameters vs the BlazeIt point."""

from benchmarks import common
from repro.core.queries.aggregation import aggregate_control_variates
from repro.core.schema import TARGET_DNN_COST_S


def run(quick: bool = False):
    rows = []
    wl = common.get_workload("night-street", quick)
    truth = common.truth_vector(wl, "score_count")
    for n_reps in ((150, 300) if quick else (200, 400, 800, 1600)):
        sys_t = common.get_tasti("night-street", "T", quick, n_reps=n_reps)
        proxy = sys_t.proxy_scores(wl.score_count)
        res = aggregate_control_variates(proxy, sys_t.oracle(wl.score_count),
                                         err=0.05, seed=0)
        cost = sys_t.index.cost.wall_clock_s()
        rows.append((f"fig3/tasti_reps{n_reps}/construction", "seconds",
                     round(cost, 1)))
        rows.append((f"fig3/tasti_reps{n_reps}/agg_invocations", "count",
                     res.n_invocations))
    bl = common.get_blazeit_scores("night-street", "score_count", quick)
    res_b = aggregate_control_variates(
        bl, lambda ids: truth[ids], err=0.05, seed=0)
    budget = common.BLAZEIT_BUDGET_FACTOR * (
        (150 if quick else common.N_TRAIN) + (300 if quick else common.N_REPS))
    budget = min(budget, len(wl.features))
    rows.append(("fig3/blazeit/construction", "seconds",
                 round(budget * TARGET_DNN_COST_S, 1)))
    rows.append(("fig3/blazeit/agg_invocations", "count", res_b.n_invocations))
    return rows
