"""Fig. 2: index-construction time breakdown — TASTI vs BlazeIt's TMAS.

TASTI = target-DNN annotations (train set + reps) + embedding + training +
distance computation; BlazeIt = target DNN over the TMAS (10x budget).
Seconds come from the paper-measured cost model (3 fps target, 12k fps
embedder); the ratio is the reproduced claim (paper: ~10x cheaper).
"""

from benchmarks import common
from repro.core.schema import TARGET_DNN_COST_S


def run(quick: bool = False):
    rows = []
    sys_t = common.get_tasti("night-street", "T", quick)
    bd = sys_t.index.cost.breakdown()
    for part, secs in bd.items():
        rows.append((f"fig2/tasti/{part}", "seconds", round(secs, 2)))
    tasti_total = sum(bd.values())
    rows.append(("fig2/tasti/total", "seconds", round(tasti_total, 2)))
    wl = common.get_workload("night-street", quick)
    tmas = common.BLAZEIT_BUDGET_FACTOR * sys_t.index.cost.target_invocations
    tmas = min(tmas, len(wl.features))
    blazeit_total = tmas * TARGET_DNN_COST_S
    rows.append(("fig2/blazeit/target_dnn_s", "seconds", round(blazeit_total, 2)))
    rows.append(("fig2/blazeit/total", "seconds", round(blazeit_total, 2)))
    rows.append(("fig2/construction_speedup", "ratio",
                 round(blazeit_total / tasti_total, 2)))
    return rows
