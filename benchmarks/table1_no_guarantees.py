"""Table 1: queries without statistical guarantees.  Aggregation: % error of
the direct proxy statistic.  Selection: 100 - F1 of thresholded proxy scores
(threshold fit on a small validation sample, as prior systems do)."""
import numpy as np

from benchmarks import common


def _f1(pred, truth):
    tp = float((pred & truth).sum())
    if tp == 0:
        return 0.0
    prec = tp / max(pred.sum(), 1)
    rec = tp / max(truth.sum(), 1)
    return 2 * prec * rec / (prec + rec)


def run(quick: bool = False):
    rows = []
    ds = "night-street"
    wl = common.get_workload(ds, quick)
    truth_cnt = common.truth_vector(wl, "score_count")
    sel_fn = common.sel_score_fn(wl, ds)
    truth_sel = np.asarray([sel_fn(r) for r in
                            wl.target_dnn_batch(range(len(wl.features)))]) > 0.5

    systems = {
        "tasti": common.get_tasti(ds, "T", quick).proxy_scores(wl.score_count),
        "blazeit": common.get_blazeit_scores(ds, "score_count", quick),
    }
    for name, proxy in systems.items():
        err = (abs(float(proxy.mean()) - float(truth_cnt.mean()))
               / max(float(truth_cnt.mean()), 1e-9) * 100)
        rows.append((f"table1/{ds}/agg_{name}", "pct_error", round(err, 2)))

    sel_systems = {
        "tasti": np.clip(common.get_tasti(ds, "T", quick)
                         .proxy_scores(sel_fn), 0, 1),
        "noscope": common.get_blazeit_scores(ds, "sel_rare", quick,
                                             classify=True, score_fn=sel_fn),
    }
    rng = np.random.default_rng(0)
    val = rng.choice(len(truth_sel), 200, replace=False)
    for name, proxy in sel_systems.items():
        best_t, best_f1 = 0.5, -1.0
        for t in np.linspace(0.05, 0.95, 19):
            f1 = _f1(proxy[val] > t, truth_sel[val])
            if f1 > best_f1:
                best_t, best_f1 = t, f1
        f1 = _f1(proxy > best_t, truth_sel)
        rows.append((f"table1/{ds}/sel_{name}", "100_minus_f1",
                     round(100 * (1 - f1), 2)))
    return rows
