"""Fig. 8: aggregation of the average x-position — pure regression, where
per-query proxy training is brittle (the paper could not train BlazeIt to beat
random sampling; we report the proxy anyway)."""
import numpy as np

from benchmarks import common
from repro.core.queries.aggregation import aggregate_control_variates


def run(quick: bool = False):
    rows = []
    ds = "night-street"
    wl = common.get_workload(ds, quick)
    truth = common.truth_vector(wl, "score_mean_x")

    def oracle(ids):
        return truth[ids]
    seeds = range(2 if quick else 3)

    def mean_inv(proxy, use_cv=True):
        return float(np.mean([aggregate_control_variates(
            proxy, oracle, err=0.01, seed=s, use_cv=use_cv).n_invocations
            for s in seeds]))

    rows.append(("fig8/random", "invocations",
                 mean_inv(np.zeros(len(truth)), use_cv=False)))
    bl = common.get_blazeit_scores(ds, "score_mean_x", quick)
    rows.append(("fig8/blazeit_regression", "invocations", mean_inv(bl)))
    for variant in ("PT", "T"):
        sv = common.get_tasti(ds, variant, quick)
        proxy = sv.proxy_scores(wl.score_mean_x)
        rows.append((f"fig8/tasti_{variant.lower()}", "invocations",
                     mean_inv(proxy)))
    return rows
