"""SLO load: interactive latency under a heavy scan, with accounting parity.

The scheduler's reason to exist: on a ``max_workers=1`` server, a
10k-invocation limit scan used to hold the only worker for seconds while
interactive aggregations queued behind it.  This benchmark drives exactly
that collision with the open-loop harness (:mod:`repro.loadgen`) against a
sleep-calibrated target DNN:

* **warm-up** — the interactive aggregation runs once so its oracle demand
  is fully cached; from then on its latency is pure scheduling, and the
  heavy scan's fresh-label set is independent of interleaving;
* **scheduled** — the heavy limit query (priority 2) is posted, then an
  open-loop Poisson train of interactive aggregations (priority 0,
  ``deadline_ms``) fires for several seconds; the scheduler must preempt
  the scan at oracle-slice boundaries to serve them;
* **parity** — every request the server answered is replayed serially on a
  fresh engine (no scheduler, no slicing); per-request accounting rows and
  the total fresh/cached label counts must be **identical** — scheduling
  must never change what the oracle was asked or what was charged;
* **no-preempt control** — the same collision with preemption disabled,
  reported (not gated) so the latency win is visible in the artifact.

Asserted, not just reported: zero failed interactive requests, at least one
preemption, interactive p99 under ``P99_CEILING_MS``, and byte-identical
label accounting between the scheduled run and the serial replay.

    PYTHONPATH=src python -m benchmarks.slo_load --quick --json out.json
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from typing import Dict, List, Optional

from repro.core.codec import result_row
from repro.core.engine import QueryEngine, QuerySpec
from repro.core.index import TastiIndex
from repro.core.schema import make_workload
from repro.core.session import QuerySession
from repro.loadgen import ArrivalProcess, OpenLoopGenerator, SpecClass, SpecMix
from repro.serve import QueryClient, QueryServer

PER_BATCH_S = 0.005    # fixed cost per target-DNN batch call
PER_ID_S = 0.0005      # marginal cost per id
P99_CEILING_MS = 500.0  # interactive p99 SLO while the scan is in flight

# result-row fields that must replay identically (wall-clock timing, plan
# trace, and the routing/scheduling echoes are excluded by construction)
_PARITY_KEYS = ("kind", "n_invocations", "n_oracle_fresh", "n_oracle_cached",
                "n_cracked", "estimate", "ci_half_width", "threshold",
                "n_selected", "selected_head")


class _SleepyWorkload:
    """Delegates everything to a real workload but pays a calibrated sleep
    per ``target_dnn_batch`` call — batched inference cost without a GPU
    (``time.sleep`` releases the GIL, so concurrency is genuine)."""

    def __init__(self, inner):
        self._inner = inner

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def target_dnn_batch(self, ids):
        time.sleep(PER_BATCH_S + PER_ID_S * len(ids))
        return self._inner.target_dnn_batch(ids)


def _interactive_specs(quick: bool) -> List[dict]:
    return [{"kind": "aggregation", "score": "score_count",
             "err": 0.2 if quick else 0.15, "seed": 0}]


def _heavy_specs(quick: bool) -> List[dict]:
    budget = 2000 if quick else 10000
    # k_results == max_invocations: the scan examines exactly its budget
    return [{"kind": "limit", "score": "score_has_object", "batch": 64,
             "k_results": budget, "max_invocations": budget, "priority": 2}]


def _row_parity(row: dict) -> dict:
    return {k: row[k] for k in _PARITY_KEYS if k in row}


def _slowest_trace(client: QueryClient, report) -> Optional[dict]:
    """Fetch the span tree of the slowest completed interactive request
    from the server's flight recorder — the postmortem artifact the CI
    slo-gate uploads when the p99 assertion trips.  Best-effort: ``None``
    when the trace aged out of the ring buffer (it is bounded)."""
    done = [o for o in report.outcomes if o.ok and o.trace_id]
    if not done:
        return None
    worst = max(done, key=lambda o: o.latency_s)
    try:
        doc = client.traces(trace_id=worst.trace_id)
        chrome = client.traces(trace_id=worst.trace_id, fmt="chrome")
    except Exception:  # noqa: BLE001 - a missing postmortem must not
        return None    # fail the benchmark that produced the numbers
    return {"trace_id": worst.trace_id,
            "latency_ms": round(worst.latency_s * 1e3, 3),
            "trace": doc, "chrome": chrome}


def _collide(index: TastiIndex, workload, quick: bool,
             preempt: bool) -> Dict[str, object]:
    """One full collision: warm-up, heavy scan + open-loop interactive
    train, then drain.  Returns latencies, accounting rows, and stats."""
    engine = QueryEngine(index, _SleepyWorkload(workload))
    server = QueryServer(engine, port=0, admission_window=0.0,
                         max_workers=1, preempt=preempt).start()
    requests: List[dict] = []   # replay journal: specs/budget in post order
    try:
        client = QueryClient(server.url)
        client.wait_ready(30)

        # warm-up: the interactive class pays its fresh labels here, once
        warm = client.query(_interactive_specs(quick))
        requests.append({"specs": _interactive_specs(quick), "budget": None})

        # heavy scan posted first so it holds the single worker when the
        # interactive train starts arriving
        heavy_out: Dict[str, object] = {}

        def post_heavy() -> None:
            heavy_out["response"] = client.query(_heavy_specs(quick),
                                                 priority=2)

        requests.append({"specs": _heavy_specs(quick), "budget": None})
        heavy_thread = threading.Thread(target=post_heavy, daemon=True)
        heavy_thread.start()
        time.sleep(0.15)  # let the scan reach the worker before the train

        mix = SpecMix([SpecClass(name="interactive",
                                 specs=_interactive_specs(quick),
                                 priority=0, deadline_ms=250.0)], seed=0)
        process = ArrivalProcess(rate=15.0 if quick else 25.0, cv=1.0, seed=0)
        duration = 2.5 if quick else 5.0

        def post(specs, budget=None, priority=None, deadline_ms=None,
                 name=None, trace_id=None):
            return client.query(specs, budget=budget, priority=priority,
                                deadline_ms=deadline_ms, trace_id=trace_id)

        report = OpenLoopGenerator(post, mix, process, duration).run()
        for o in report.outcomes:
            requests.append({"specs": _interactive_specs(quick),
                             "budget": None})

        heavy_thread.join(timeout=120)
        if heavy_thread.is_alive():
            raise AssertionError("heavy scan starved: still running after "
                                 "the interactive train drained")
        stats = client.stats()
        slow_trace = _slowest_trace(client, report)
    finally:
        server.shutdown()

    rows = [_row_parity(r) for r in warm["results"]]
    rows += [_row_parity(r) for r in heavy_out["response"]["results"]]
    for o in report.outcomes:
        if o.ok:
            rows += [_row_parity(r) for r in o.response["results"]]
    return {
        "report": report,
        "rows": rows,
        "requests": requests,
        "slow_trace": slow_trace,
        "fresh_total": stats["accounts"]["fresh_total"],
        "cached_total": stats["accounts"]["cached_total"],
        "scheduler": stats["server"]["scheduler"],
        "queue": stats["workloads"][stats["server"]["default_workload"]]
                      ["queue"],
    }


def _replay(index: TastiIndex, workload,
            requests: List[dict]) -> Dict[str, object]:
    """The ground truth: the same request train, serially, on a fresh
    engine with no scheduler and no slicing."""
    engine = QueryEngine(index, workload)
    rows: List[dict] = []
    for req in requests:
        session = QuerySession(engine,
                               [QuerySpec.from_dict(s) for s in req["specs"]],
                               budget=req["budget"])
        session.plan()
        out = session.execute()
        rows += [_row_parity(result_row(r)) for r in out.results]
    snap = engine.broker.snapshot()
    return {"rows": rows, "fresh_total": snap["fresh"],
            "cached_total": snap["cached"]}


def bench(quick: bool = False,
          trace_out: Optional[str] = None) -> Dict[str, object]:
    n = 2400 if quick else 12000
    wl = make_workload("night-street", n_frames=n)
    index = TastiIndex.build(wl.features, 150 if quick else 400,
                             wl.target_dnn_batch, k=4,
                             random_fraction=0.0, seed=0)

    sched = _collide(index, wl, quick, preempt=True)
    report = sched["report"]
    inter = report.classes["interactive"]

    # written BEFORE the assertions: when the p99 gate trips, the span tree
    # of the slowest interactive request is exactly the postmortem you want
    if trace_out and sched["slow_trace"] is not None:
        with open(trace_out, "w") as f:
            json.dump(sched["slow_trace"], f, indent=2)

    # starvation-freedom, asserted
    if inter["errors"]:
        raise AssertionError(
            f"{inter['errors']} interactive requests failed under load")
    if sched["scheduler"]["preemptions"] < 1:
        raise AssertionError(
            "the heavy scan was never preempted — interactive latency is "
            "luck, not scheduling")
    if inter["p99_ms"] > P99_CEILING_MS:
        raise AssertionError(
            f"interactive p99 {inter['p99_ms']:.1f}ms exceeds the "
            f"{P99_CEILING_MS:.0f}ms SLO while the scan was in flight")

    # accounting parity vs unscheduled serial execution, asserted
    truth = _replay(index, wl, sched["requests"])
    if (sched["fresh_total"] != truth["fresh_total"]
            or sched["cached_total"] != truth["cached_total"]):
        raise AssertionError(
            f"scheduling changed label accounting: scheduled "
            f"fresh={sched['fresh_total']} cached={sched['cached_total']} "
            f"vs serial replay fresh={truth['fresh_total']} "
            f"cached={truth['cached_total']}")
    # row multisets: scheduled interactive rows are identical repeats, so
    # compare order-insensitively (completion order is load-dependent)
    key = lambda r: json.dumps(r, sort_keys=True)  # noqa: E731
    if sorted(map(key, sched["rows"])) != sorted(map(key, truth["rows"])):
        raise AssertionError(
            "per-request result rows differ between the scheduled run and "
            "the serial replay")

    # the no-preempt control: same collision, FIFO-held worker (reported,
    # not gated — shared-runner wall clock decides its exact numbers)
    control = _collide(index, wl, quick, preempt=False)
    control_inter = control["report"].classes["interactive"]

    return {
        "n_records": n,
        "parity": True,
        "offered": report.offered,
        "completed": report.completed,
        "max_fire_lag_ms": report.max_fire_lag_ms,
        "classes": {"interactive": inter},
        "scheduler": dict(sched["scheduler"]),
        "queue": dict(sched["queue"]),
        "labels": {"fresh": sched["fresh_total"],
                   "cached": sched["cached_total"]},
        "no_preempt": {"interactive": control_inter,
                       "preemptions":
                           control["scheduler"]["preemptions"]},
        "p99_ceiling_ms": P99_CEILING_MS,
        "slowest_interactive": None if sched["slow_trace"] is None else {
            "trace_id": sched["slow_trace"]["trace_id"],
            "latency_ms": sched["slow_trace"]["latency_ms"],
        },
    }


def run(quick: bool = False) -> List[tuple]:
    """Benchmark-harness entry point: CSV rows."""
    out = bench(quick)
    inter = out["classes"]["interactive"]
    return [
        ("slo_load/interactive", "p50_ms", inter["p50_ms"]),
        ("slo_load/interactive", "p99_ms", inter["p99_ms"]),
        ("slo_load/interactive", "completed", inter["ok"]),
        ("slo_load/scheduler", "preemptions",
         out["scheduler"]["preemptions"]),
        ("slo_load/no_preempt", "p99_ms",
         out["no_preempt"]["interactive"]["p99_ms"]),
    ]


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="interactive latency under a heavy scan, with "
                    "accounting parity vs unscheduled execution")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="also write the measurements as JSON (CI artifact)")
    ap.add_argument("--trace-out", default=None,
                    help="dump the slowest interactive request's span tree "
                         "(flight-recorder postmortem, written before the "
                         "SLO assertions so a red gate still gets it)")
    args = ap.parse_args(argv)
    payload = {"quick": args.quick,
               **bench(args.quick, trace_out=args.trace_out)}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
    print(json.dumps(payload, indent=2))


if __name__ == "__main__":
    main()
