"""Benchmark harness: one module per paper table/figure.  Prints
``name,metric,value`` CSV rows (metrics are the paper's hardware-independent
ones: target-DNN invocations, FPR, % error, 100-F1, cost-model seconds).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig4]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    "fig2_construction", "fig3_cost_vs_quality", "fig4_aggregation",
    "fig5_supg", "fig6_limit", "fig7_position_selection", "fig7_session",
    "fig8_avg_position",
    "table1_no_guarantees", "table2_cracking", "fig9_factor_analysis",
    "fig10_lesion", "fig11_buckets", "fig12_train_examples",
    "fig13_embedding_size", "serve_throughput", "oracle_scaling",
    "multi_workload", "slo_load", "proxy_scoring",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from benchmarks import common
    failures = []
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            rows = mod.run(quick=args.quick)
            common.emit(rows)
            print(f"# {mod_name} done in {time.time()-t0:.0f}s",
                  file=sys.stderr)
        except Exception as e:
            failures.append(mod_name)
            print(f"# {mod_name} FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
