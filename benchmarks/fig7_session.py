"""Session sharing: the same specs executed as one jointly-planned
``QuerySession`` vs in isolation (fresh engine per spec, no shared cache).

A session of >=3 specs over one score function shares the stratified sample
across its aggregations, prefetches every spec's certain first requests
through the oracle broker (one combined ``target_dnn_batch`` flush), and
dedups across specs — so it must issue strictly fewer fresh target-DNN
records than the isolated runs.  Metric: fresh labeled records (the paper's
query cost) and oracle microbatches."""

from benchmarks import common
from repro.core.engine import QueryEngine, QuerySpec
from repro.core.session import QuerySession


def _specs(quick: bool):
    budget = 250 if quick else 400
    return [
        QuerySpec(kind="aggregation", score="score_has_object",
                  err=0.1 if quick else 0.08, seed=0),
        QuerySpec(kind="aggregation", score="score_has_object",
                  err=0.06 if quick else 0.04, seed=1),
        QuerySpec(kind="selection", score="score_has_object",
                  budget=budget, seed=0),
        QuerySpec(kind="limit", score="score_has_object", k_results=5),
    ]


def run(quick: bool = False):
    rows = []
    for ds in ("night-street", "taipei"):
        wl = common.get_workload(ds, quick)
        system = common.get_tasti(ds, "T", quick)
        specs = _specs(quick)

        # isolated: a fresh engine per spec — no shared cache, no session
        iso = [QueryEngine(system.index, wl).execute(s) for s in specs]
        iso_fresh = sum(r.n_oracle_fresh for r in iso)

        # shared: one session over one engine
        out = QuerySession(QueryEngine(system.index, wl), specs).execute()
        sess_fresh = out.stats["fresh_total"]

        for i, (spec, ri, rs) in enumerate(zip(specs, iso, out.results)):
            rows.append((f"fig7/{ds}/spec{i}_{spec.kind}/isolated",
                         "fresh_records", ri.n_oracle_fresh))
            rows.append((f"fig7/{ds}/spec{i}_{spec.kind}/session",
                         "fresh_records", rs.n_oracle_fresh))
        rows.append((f"fig7/{ds}/isolated", "fresh_records", iso_fresh))
        rows.append((f"fig7/{ds}/session", "fresh_records", sess_fresh))
        rows.append((f"fig7/{ds}/session", "oracle_batches",
                     out.stats["oracle_batches"]))
        rows.append((f"fig7/{ds}/savings", "pct",
                     round(100.0 * (1.0 - sess_fresh / max(iso_fresh, 1)), 1)))
        if sess_fresh >= iso_fresh:
            raise AssertionError(
                f"{ds}: session issued {sess_fresh} fresh records, isolated "
                f"issued {iso_fresh} — sharing must strictly reduce cost")
    return rows
