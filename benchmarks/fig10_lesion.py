"""Fig. 10: lesion study — each optimization removed individually from the
full system."""
import numpy as np

from benchmarks import common
from repro.core.pipeline import build_tasti
from repro.core.queries.aggregation import aggregate_control_variates
from repro.core.queries.limit import limit_query


def run(quick: bool = False):
    rows = []
    ds = "night-street"
    wl = common.get_workload(ds, quick)
    truth_cnt = common.truth_vector(wl, "score_count")
    rare_fn = common.rare_event_fn(wl, ds)
    truth_rare = np.asarray([rare_fn(r) for r in
                             wl.target_dnn_batch(range(len(wl.features)))])
    lesions = [
        ("full", dict(variant="T")),
        ("-triplet", dict(variant="PT")),
        ("-fpf_mining", dict(variant="T", use_fpf_mining=False)),
        ("-fpf_clustering", dict(variant="T", use_fpf_clustering=False)),
    ]
    for name, kw in lesions:
        sv = build_tasti(wl, common.tasti_cfg(quick), **kw)
        agg = aggregate_control_variates(sv.proxy_scores(wl.score_count),
                                         lambda i: truth_cnt[i], err=0.05,
                                         seed=0).n_invocations
        lim = limit_query(sv.proxy_scores(rare_fn, mode="top1"),
                          lambda i: truth_rare[i], k_results=5, batch=4).n_invocations
        rows.append((f"fig10/{name}/agg", "invocations", agg))
        rows.append((f"fig10/{name}/limit", "invocations", lim))
    return rows
