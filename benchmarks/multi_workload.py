"""Multi-workload serving: one 2-workload server vs 2 single-workload servers.

A production deployment amortizes one endpoint across many workloads, but
only if routing is *free* of cross-workload interference: the fresh-label
accounting a workload sees on a shared server must be exactly what it would
see on a server of its own.  This benchmark drives a video (night-street)
and a text (wikisql) workload

* **isolated** — two single-workload :class:`~repro.serve.server.QueryServer`
  processes-worth of stacks, each workload's request train posted serially
  to its own server;
* **multi** — ONE server mounting both workloads via a
  :class:`~repro.serve.registry.WorkloadRegistry`, the same two request
  trains posted concurrently (each train still serial within its workload,
  so per-workload accounting is deterministic), interleaving on the shared
  worker pool.

Asserted, not just reported: per-workload fresh-label totals and every
result row are **identical** between the two deployments (no cross-workload
interference in fresh-label accounting), with queries/s for both reported.

    PYTHONPATH=src python -m benchmarks.multi_workload --quick --json out.json
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.core.engine import QueryEngine
from repro.core.index import TastiIndex
from repro.core.schema import make_workload
from repro.serve import QueryClient, QueryServer, WorkloadRegistry


def _video_lists(quick: bool) -> List[List[dict]]:
    lists = []
    for seed in range(2 if quick else 4):
        lists.append([
            {"kind": "aggregation", "score": "score_count",
             "err": 0.15, "seed": seed},
            {"kind": "selection", "score": "score_has_object",
             "budget": 80 + 20 * seed, "seed": seed},
            {"kind": "limit", "score": "score_has_object",
             "k_results": 3 + seed % 2},
        ])
    return lists


def _text_lists(quick: bool) -> List[List[dict]]:
    lists = []
    for seed in range(2 if quick else 4):
        lists.append([
            {"kind": "aggregation", "score": "score_n_predicates",
             "err": 0.15, "seed": seed},
            {"kind": "selection", "score": "score_is_select",
             "budget": 70 + 15 * seed, "seed": seed},
        ])
    return lists


def _strip(row: dict) -> dict:
    """Comparable form of a result row: routing stamp and trace removed
    (the multi server stamps rows with its mount name)."""
    return {k: v for k, v in row.items() if k not in ("workload", "plan")}


def _drive_serial(url: str, spec_lists: List[List[dict]],
                  workload: Optional[str] = None
                  ) -> Tuple[List[List[dict]], int]:
    """Post every spec list in order; returns (rows per request, fresh)."""
    client = QueryClient(url)
    client.wait_ready(30)
    rows, fresh = [], 0
    for specs in spec_lists:
        out = client.query(specs, workload=workload)
        rows.append([_strip(r) for r in out["results"]])
        fresh += out["request"]["fresh"]
    return rows, fresh


def _build(dataset: str, n: int, n_reps: int):
    wl = make_workload(dataset, n_records=n)
    index = TastiIndex.build(wl.features, n_reps, wl.target_dnn_batch, k=4,
                             random_fraction=0.0, seed=0)
    return wl, index


def bench(quick: bool = False) -> Dict[str, object]:
    n = 800 if quick else 2000
    wl_v, idx_v = _build("night-street", n, 100 if quick else 200)
    wl_t, idx_t = _build("wikisql", n, 100 if quick else 200)
    trains = {"video": _video_lists(quick), "text": _text_lists(quick)}
    n_queries = sum(len(s) for t in trains.values() for s in t)

    # isolated: each workload on a server of its own.  Only the query
    # drives are timed — server start/ready/shutdown happen outside the
    # window in both deployments, so the queries/s comparison is honest
    iso_rows: Dict[str, List[List[dict]]] = {}
    iso_fresh: Dict[str, int] = {}
    iso_s = 0.0
    for name, (wl, idx) in (("video", (wl_v, idx_v)), ("text", (wl_t, idx_t))):
        server = QueryServer(QueryEngine(idx, wl), port=0,
                             admission_window=0.0).start()
        try:
            QueryClient(server.url).wait_ready(30)
            t0 = time.perf_counter()
            iso_rows[name], iso_fresh[name] = _drive_serial(server.url,
                                                            trains[name])
            iso_s += time.perf_counter() - t0
        finally:
            server.shutdown()

    # multi: ONE server, both workloads, trains posted concurrently
    registry = WorkloadRegistry()
    registry.register("video", QueryEngine(idx_v, wl_v))
    registry.register("text", QueryEngine(idx_t, wl_t))
    server = QueryServer(registry, port=0, admission_window=0.0).start()
    QueryClient(server.url).wait_ready(30)
    multi_rows: Dict[str, List[List[dict]]] = {}
    multi_fresh: Dict[str, int] = {}
    errors: List[BaseException] = []

    def drive(name: str) -> None:
        try:
            multi_rows[name], multi_fresh[name] = _drive_serial(
                server.url, trains[name], workload=name)
        except BaseException as e:  # noqa: BLE001 - re-raised below
            errors.append(e)

    try:
        t0 = time.perf_counter()
        threads = [threading.Thread(target=drive, args=(name,))
                   for name in trains]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        multi_s = time.perf_counter() - t0
        if errors:
            raise errors[0]
        stats = QueryClient(server.url).stats()
        acct = {name: stats["workloads"][name]["accounts"]["fresh_total"]
                for name in trains}
    finally:
        server.shutdown()

    for name in trains:
        if multi_fresh[name] != iso_fresh[name] or acct[name] != iso_fresh[name]:
            raise AssertionError(
                f"cross-workload interference: {name} paid "
                f"{multi_fresh[name]} fresh labels (accounts: {acct[name]}) "
                f"on the shared server vs {iso_fresh[name]} isolated")
        if multi_rows[name] != iso_rows[name]:
            raise AssertionError(
                f"workload {name} answers differ between the shared and "
                "isolated servers")
    return {
        "n_queries": n_queries,
        "isolated_queries_per_s": n_queries / max(iso_s, 1e-9),
        "multi_queries_per_s": n_queries / max(multi_s, 1e-9),
        "fresh_per_workload": dict(iso_fresh),
        "interference_free": True,
    }


def run(quick: bool = False) -> List[tuple]:
    """Benchmark-harness entry point: CSV rows."""
    out = bench(quick)
    rows = [("multi_workload/shared", "queries_per_s",
             round(out["multi_queries_per_s"], 2)),
            ("multi_workload/isolated", "queries_per_s",
             round(out["isolated_queries_per_s"], 2))]
    for name, fresh in out["fresh_per_workload"].items():
        rows.append((f"multi_workload/{name}", "fresh_labels", fresh))
    return rows


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="2-workload server vs 2 single-workload servers")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="also write the measurements as JSON (CI artifact)")
    args = ap.parse_args(argv)
    payload = {"quick": args.quick, **bench(args.quick)}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
    print(json.dumps(payload, indent=2))


if __name__ == "__main__":
    main()
