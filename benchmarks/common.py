"""Shared benchmark context: workloads + TASTI systems, memoized.

Every benchmark module exposes ``run(quick: bool) -> list[(name, metric,
value)]``.  Metrics are the paper's (target-DNN invocations, FPR, % error,
100-F1, construction seconds from the §3.4 cost model) — all hardware-
independent, so the algorithmic comparison is faithful on CPU.
"""
from __future__ import annotations

import sys
from typing import Dict, List, Tuple

import numpy as np

from repro.core.baselines import ProxyConfig, train_query_proxy
from repro.core.engine import QueryEngine
from repro.core.pipeline import TastiConfig, TastiSystem, build_tasti
from repro.core.schema import VIDEO_WORKLOAD_NAMES, WORKLOAD_NAMES, make_workload
from repro.core.triplet import TripletConfig

# dataset names are canonical in repro.core.schema (the serving registry
# and CLIs validate against the same tuples)
VIDEO_SETS = VIDEO_WORKLOAD_NAMES
ALL_SETS = WORKLOAD_NAMES

# scaled-down standard setup (paper: 3000 train / 7000 reps over ~1M frames)
N_FRAMES = 8000
N_TRAIN = 400
N_REPS = 800
K = 4
BLAZEIT_BUDGET_FACTOR = 15  # paper: TMAS 150k vs TASTI 10k annotations

_CACHE: Dict = {}


def get_workload(name: str, quick: bool = False):
    n = 3000 if quick else N_FRAMES
    key = ("wl", name, n)
    if key not in _CACHE:
        _CACHE[key] = make_workload(name, n_records=n)
    return _CACHE[key]


def tasti_cfg(quick: bool = False, **overrides) -> TastiConfig:
    base = dict(n_train=150 if quick else N_TRAIN,
                n_reps=300 if quick else N_REPS, k=K,
                triplet=TripletConfig(steps=150 if quick else 400, batch=256),
                pretrain_steps=60 if quick else 150)
    base.update(overrides)
    return TastiConfig(**base)


def get_tasti(name: str, variant: str = "T", quick: bool = False,
              **overrides) -> TastiSystem:
    key = ("tasti", name, variant, quick, tuple(sorted(overrides.items())))
    if key not in _CACHE:
        wl = get_workload(name, quick)
        _CACHE[key] = build_tasti(wl, tasti_cfg(quick, **overrides),
                                  variant=variant)
    return _CACHE[key]


def get_engine(name: str, variant: str = "T", quick: bool = False,
               **overrides) -> QueryEngine:
    """The memoized TASTI system's query engine (shared caches per system).

    Benchmark drivers execute ``QuerySpec`` s against this; method-vs-method
    comparisons should pass ``reuse_labels=False`` so one method's oracle
    calls don't subsidize another's invocation count.
    """
    return get_tasti(name, variant, quick, **overrides).engine


def get_blazeit_scores(name: str, score_attr: str, quick: bool = False,
                       classify: bool = False, budget: int = 0,
                       score_fn=None) -> np.ndarray:
    """Per-query proxy trained on a TMAS of ``budget`` random annotations.

    ``score_attr`` is a workload method name OR (with score_fn given) a cache
    label for a custom scoring callable."""
    wl = get_workload(name, quick)
    budget = budget or BLAZEIT_BUDGET_FACTOR * ((150 if quick else N_TRAIN)
                                                + (300 if quick else N_REPS))
    budget = min(budget, len(wl.features))
    key = ("blazeit", name, score_attr, quick, classify, budget)
    if key not in _CACHE:
        rng = np.random.default_rng(0)
        ids = rng.choice(len(wl.features), budget, replace=False)
        fn = score_fn if score_fn is not None else getattr(wl, score_attr)
        targets = np.asarray([fn(s) for s in wl.target_dnn_batch(ids)])
        _CACHE[key] = train_query_proxy(
            wl.features, ids, targets,
            ProxyConfig(feature_dim=wl.features.shape[1], classify=classify,
                        steps=200 if quick else 400))
    return _CACHE[key]


def truth_vector(wl, score_attr: str) -> np.ndarray:
    score_fn = getattr(wl, score_attr)
    n = len(wl.features)
    return np.asarray([score_fn(s) for s in wl.target_dnn_batch(range(n))])


def agg_score_attr(name: str) -> str:
    return "score_n_predicates" if name == "wikisql" else "score_count"


def sel_score_attr(name: str) -> str:
    return "score_is_select" if name == "wikisql" else "score_has_object"


def sel_score_fn(wl, name: str):
    """Selection predicate for SUPG figures: rare enough to be non-trivial
    (the has-object predicate is ~65% positive on these streams)."""
    if name == "wikisql":
        return lambda r: 1.0 if r.op == 4 else 0.0  # AVG (~5%)
    return lambda s: 1.0 if s.count >= 3 else 0.0


def rare_event_fn(wl, name: str):
    """Limit-query rare event, dataset-relative (<~1% of records) and
    conjunctive for video (count + position) so interpolating proxies can't
    trivially rank it."""
    if name == "wikisql":
        return lambda r: 1.0 if (r.op == 2 and r.n_predicates >= 3) else 0.0
    import numpy as np
    counts = wl.counts
    xs = np.asarray([sc.mean_x() for sc in wl.scenes])
    # choose (count threshold, x cut) so the event lands at ~3-24 records —
    # genuinely rare, as in the paper's limit queries
    best = None
    for t in range(int(counts.max()), 1, -1):
        for x_cut in (0.3, 0.35, 0.4, 0.45, 0.5):
            n = int(((counts >= t) & (xs < x_cut)).sum())
            if 3 <= n <= 24:
                best = (t, x_cut)
                break
        if best:
            break
    t, x_cut = best if best else (max(int(counts.max()), 1), 0.45)
    return lambda s, t=t, x=x_cut: 1.0 if (s.count >= t and s.mean_x() < x) else 0.0


def tmas_budget(wl) -> int:
    """BlazeIt's TMAS at the paper's dataset fraction (150k / 973k ~ 15%)."""
    return max(200, int(0.15 * len(wl.features)))


def rare_score_attr(name: str) -> str:
    return "score_is_select" if name == "wikisql" else "score_rare"


def emit(rows: List[Tuple[str, str, float]]) -> None:
    for name, metric, value in rows:
        print(f"{name},{metric},{value}")
        sys.stdout.flush()
