"""Regression gate: compare benchmark JSON outputs against a committed
baseline and fail on >tolerance regressions.

The CI ``bench-gate`` job runs ``benchmarks/oracle_scaling.py --json`` and
``benchmarks/serve_throughput.py --json``, then checks every metric listed
in ``benchmarks/BENCH_baseline.json``:

    PYTHONPATH=src python -m benchmarks.check_regression \\
        --baseline benchmarks/BENCH_baseline.json \\
        --current oracle_scaling=reports/oracle_scaling.json \\
        --current serve_throughput=reports/serve_throughput.json

Baseline format — metric keys are ``<alias>:<dotted.path>`` into the
flattened current JSON; ``direction`` says which way is good; an absent
per-metric ``tolerance`` uses ``default_tolerance`` (0.25 = fail on >25%
regression)::

    {"default_tolerance": 0.25,
     "metrics": {
       "oracle_scaling:speedup_at_4": {"value": 3.5, "direction": "higher"},
       "serve_throughput:metrics.serve/warm_serial.fresh_per_query":
           {"value": 0.0, "direction": "lower"}}}

Baseline *values* are calibrated floors/ceilings, not exact expectations:
ratio and label-count metrics transfer across machines; wall-clock metrics
get conservative values (or wider per-metric tolerances) so the gate
catches collapses, not runner jitter.

``--scale key=factor`` multiplies an observed metric before checking — the
CI self-test injects a synthetic 2x slowdown this way and asserts the gate
goes red.  ``--write-baseline`` refreshes the committed values from the
current run (directions/tolerances kept).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional


def flatten(obj, prefix: str = "") -> Dict[str, float]:
    """Numeric leaves of nested dicts as ``a.b.c`` keys (bools/strings/
    lists are not gate-able and are skipped)."""
    out: Dict[str, float] = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(flatten(v, f"{prefix}{k}."))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix[:-1]] = float(obj)
    return out


def check(baseline: dict, currents: Dict[str, Dict[str, float]],
          scales: Dict[str, float], subset: bool = False) -> List[str]:
    """Returns failure messages (empty = gate green); prints one verdict
    line per metric.  With ``subset``, baseline metrics whose alias has no
    ``--current`` file are skipped (printed, not failed) — for CI jobs that
    each gate their own slice of the baseline."""
    default_tol = float(baseline.get("default_tolerance", 0.25))
    failures: List[str] = []
    for key, m in baseline["metrics"].items():
        alias, _, path = key.partition(":")
        direction = m["direction"]
        if direction not in ("higher", "lower"):
            raise ValueError(f"{key}: direction must be higher|lower")
        if alias not in currents:
            if subset:
                print(f"[skip] {key}: alias {alias!r} not in this job's "
                      "slice")
                continue
            failures.append(f"{key}: no --current file for alias {alias!r}")
            continue
        cur = currents[alias].get(path)
        if cur is None:
            failures.append(f"{key}: metric missing from current run")
            continue
        cur *= scales.get(key, 1.0)
        tol = float(m.get("tolerance", default_tol))
        base = float(m["value"])
        if direction == "higher":
            limit = base * (1.0 - tol)
            ok = cur >= limit
            verdict = f"{cur:.4g} >= {limit:.4g}"
        else:
            limit = base * (1.0 + tol)
            ok = cur <= limit
            verdict = f"{cur:.4g} <= {limit:.4g}"
        status = "ok  " if ok else "FAIL"
        print(f"[{status}] {key}: {verdict} "
              f"(baseline {base:.4g}, {direction} is better, "
              f"tolerance {tol:.0%})")
        if not ok:
            failures.append(
                f"{key}: {cur:.4g} regressed past {limit:.4g} "
                f"(baseline {base:.4g} +/- {tol:.0%})")
    return failures


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="fail on >tolerance benchmark regressions vs a "
                    "committed baseline")
    ap.add_argument("--baseline", required=True,
                    help="committed baseline JSON (BENCH_baseline.json)")
    ap.add_argument("--current", action="append", required=True,
                    metavar="ALIAS[,ALIAS...]=PATH",
                    help="benchmark --json output to check, keyed by the "
                         "alias baseline metrics use (repeatable).  A "
                         "comma-separated alias list maps several aliases "
                         "to one file (e.g. serve_throughput,obs_overhead= "
                         "reports/serve_throughput.json, whose run emits "
                         "both metric families)")
    ap.add_argument("--scale", action="append", default=[],
                    metavar="METRIC=FACTOR",
                    help="multiply an observed metric before checking "
                         "(synthetic-regression injection for gate "
                         "self-tests; repeatable)")
    ap.add_argument("--subset", action="store_true",
                    help="skip baseline metrics whose alias has no "
                         "--current file (CI jobs that each gate a slice "
                         "of the baseline; without this, a missing alias "
                         "fails the gate)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="refresh the baseline's values from the current "
                         "run instead of checking (directions/tolerances "
                         "kept)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    currents: Dict[str, Dict[str, float]] = {}
    for spec in args.current:
        aliases, _, path = spec.partition("=")
        if not path or not aliases:
            ap.error(f"--current wants ALIAS[,ALIAS...]=PATH, got {spec!r}")
        with open(path) as f:
            flat = flatten(json.load(f))
        for alias in aliases.split(","):
            if not alias:
                ap.error(f"--current {spec!r} has an empty alias")
            currents[alias] = flat
    scales: Dict[str, float] = {}
    for spec in args.scale:
        key, _, factor = spec.rpartition("=")
        if not key:
            ap.error(f"--scale wants METRIC=FACTOR, got {spec!r}")
        if key not in baseline["metrics"]:
            # a silently ignored scale key would let the CI self-test claim
            # the gate catches regressions it never actually injected
            ap.error(f"--scale key {key!r} is not a baseline metric; "
                     f"known: {sorted(baseline['metrics'])}")
        scales[key] = float(factor)

    if args.write_baseline:
        for key, m in baseline["metrics"].items():
            alias, _, path = key.partition(":")
            if args.subset and alias not in currents:
                continue  # refresh only this job's slice
            cur = currents.get(alias, {}).get(path)
            if cur is None:
                sys.exit(f"cannot refresh {key}: metric missing from "
                         "current run")
            m["value"] = round(cur, 4)
        with open(args.baseline, "w") as f:
            json.dump(baseline, f, indent=2)
            f.write("\n")
        print(f"baseline {args.baseline} refreshed from current run")
        return

    failures = check(baseline, currents, scales, subset=args.subset)
    if failures:
        print(f"\nbench-gate: {len(failures)} regression(s):",
              file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        sys.exit(1)
    print("\nbench-gate: all metrics within tolerance")


if __name__ == "__main__":
    main()
