"""Table 2: cracking — run one query with the engine's cracking feedback loop
enabled (``QuerySpec(crack=True)`` folds its target-DNN invocations back into
the index), run the second query; report before/after.  Each cell executes
as one mid-session-cracking ``QuerySession`` (specs keep
``reuse_labels=False`` so before/after invocation counts stay comparable);
fresh systems per cell because cracking mutates the index."""

from benchmarks import common
from repro.core.engine import QuerySpec
from repro.core.pipeline import build_tasti
from repro.core.queries.selection import false_positive_rate
from repro.core.session import QuerySession


def run(quick: bool = False):
    rows = []
    for ds in ("night-street", "taipei"):
        wl = common.get_workload(ds, quick)
        truth_cnt = common.truth_vector(wl, "score_count")
        truth_sel = truth_cnt > 0

        def supg_spec(seed, crack=False):
            return QuerySpec(kind="selection", score="score_has_object",
                             budget=400, seed=seed, crack=crack,
                             reuse_labels=False)

        def agg_spec(seed, crack=False):
            return QuerySpec(kind="aggregation", score="score_count",
                             err=0.05, seed=seed, crack=crack,
                             reuse_labels=False)

        # --- agg (cracks mid-session) then SUPG ---
        eng = build_tasti(wl, common.tasti_cfg(quick), variant="T").engine
        out = QuerySession(
            eng, [supg_spec(0), agg_spec(0, crack=True), supg_spec(0)]
        ).execute()
        fpr_before = false_positive_rate(out.results[0].selected, truth_sel)
        fpr_after = false_positive_rate(out.results[2].selected, truth_sel)
        rows.append((f"table2/{ds}/agg_then_supg_before", "fpr",
                     round(fpr_before, 4)))
        rows.append((f"table2/{ds}/agg_then_supg_after", "fpr",
                     round(fpr_after, 4)))

        # --- SUPG (cracks mid-session) then agg ---
        eng2 = build_tasti(wl, common.tasti_cfg(quick), variant="T").engine
        out2 = QuerySession(
            eng2, [agg_spec(1), supg_spec(1, crack=True), agg_spec(1)]
        ).execute()
        rows.append((f"table2/{ds}/supg_then_agg_before", "invocations",
                     out2.results[0].n_invocations))
        rows.append((f"table2/{ds}/supg_then_agg_after", "invocations",
                     out2.results[2].n_invocations))
    return rows
