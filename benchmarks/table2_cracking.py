"""Table 2: cracking (seed-averaged) — run one query, fold its target-DNN invocations back
into the index, run the second query; report before/after."""
import numpy as np

from benchmarks import common
from repro.core.pipeline import build_tasti
from repro.core.queries.aggregation import aggregate_control_variates
from repro.core.queries.selection import false_positive_rate, supg_recall_target


def run(quick: bool = False):
    rows = []
    for ds in ("night-street", "taipei"):
        wl = common.get_workload(ds, quick)
        truth_cnt = common.truth_vector(wl, "score_count")
        truth_sel = truth_cnt > 0

        # --- agg then SUPG ---
        sv = build_tasti(wl, common.tasti_cfg(quick), variant="T")
        proxy_sel = np.clip(sv.proxy_scores(wl.score_has_object), 0, 1)
        fpr_before = false_positive_rate(
            supg_recall_target(proxy_sel, lambda i: truth_sel[i].astype(float),
                               budget=400, seed=0).selected, truth_sel)
        agg = aggregate_control_variates(sv.proxy_scores(wl.score_count),
                                         lambda i: truth_cnt[i], err=0.05,
                                         seed=0)
        sv.crack_with(agg.sampled_ids)
        proxy_sel2 = np.clip(sv.proxy_scores(wl.score_has_object), 0, 1)
        fpr_after = false_positive_rate(
            supg_recall_target(proxy_sel2, lambda i: truth_sel[i].astype(float),
                               budget=400, seed=0).selected, truth_sel)
        rows.append((f"table2/{ds}/agg_then_supg_before", "fpr",
                     round(fpr_before, 4)))
        rows.append((f"table2/{ds}/agg_then_supg_after", "fpr",
                     round(fpr_after, 4)))

        # --- SUPG then agg ---
        sv2 = build_tasti(wl, common.tasti_cfg(quick), variant="T")
        n_before = aggregate_control_variates(
            sv2.proxy_scores(wl.score_count), lambda i: truth_cnt[i],
            err=0.05, seed=1).n_invocations
        supg = supg_recall_target(
            np.clip(sv2.proxy_scores(wl.score_has_object), 0, 1),
            lambda i: truth_sel[i].astype(float), budget=400, seed=1)
        sv2.crack_with(np.unique(supg.sampled_ids))
        n_after = aggregate_control_variates(
            sv2.proxy_scores(wl.score_count), lambda i: truth_cnt[i],
            err=0.05, seed=1).n_invocations
        rows.append((f"table2/{ds}/supg_then_agg_before", "invocations",
                     n_before))
        rows.append((f"table2/{ds}/supg_then_agg_after", "invocations",
                     n_after))
    return rows
