"""Fig. 13: embedding size vs performance."""
import numpy as np

from benchmarks import common
from repro.core.queries.aggregation import aggregate_control_variates
from repro.core.queries.limit import limit_query


def run(quick: bool = False):
    rows = []
    ds = "night-street"
    wl = common.get_workload(ds, quick)
    truth_cnt = common.truth_vector(wl, "score_count")
    rare_fn = common.rare_event_fn(wl, ds)
    truth_rare = np.asarray([rare_fn(r) for r in
                             wl.target_dnn_batch(range(len(wl.features)))])
    sweeps = (32, 128) if quick else (32, 64, 128, 256)
    for dim in sweeps:
        sv = common.get_tasti(ds, "T", quick, embed_dim=dim)
        agg = aggregate_control_variates(sv.proxy_scores(wl.score_count),
                                         lambda i: truth_cnt[i], err=0.05,
                                         seed=0).n_invocations
        lim = limit_query(sv.proxy_scores(rare_fn, mode="top1"),
                          lambda i: truth_rare[i], k_results=5, batch=4).n_invocations
        rows.append((f"fig13/dim{dim}/agg", "invocations", agg))
        rows.append((f"fig13/dim{dim}/limit", "invocations", lim))
    return rows
