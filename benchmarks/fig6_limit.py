"""Fig. 6: target-DNN invocations for limit queries over rare events (lower is
better).  Every method executes ``QuerySpec(kind="limit")`` through the
engine, which auto-selects k=1 propagation with distance tie-breaks for TASTI
proxies (paper §6.3); baselines pass their scores via the ``proxy`` override.
"""
import numpy as np

from benchmarks import common
from repro.core.engine import QuerySpec


def run(quick: bool = False):
    rows = []
    for ds in common.ALL_SETS:
        wl = common.get_workload(ds, quick)
        score_fn = common.rare_event_fn(wl, ds)
        n = len(wl.features)
        truth = np.asarray([score_fn(r) for r in wl.target_dnn_batch(range(n))])
        total_rare = int(truth.sum())
        if total_rare == 0:
            rows.append((f"fig6/{ds}/rare_total", "count", 0))
            continue
        want = min(10, max(1, total_rare // 2))
        rows.append((f"fig6/{ds}/rare_total", "count", total_rare))

        def spec(proxy=None):
            return QuerySpec(kind="limit", score=score_fn, proxy=proxy,
                             k_results=want, batch=4,
                             score_key=f"fig6/{ds}", reuse_labels=False)

        eng_t = common.get_engine(ds, "T", quick)
        rng = np.random.default_rng(0)
        res_r = eng_t.execute(spec(proxy=rng.uniform(size=n)))
        rows.append((f"fig6/{ds}/random_order", "invocations",
                     res_r.n_invocations))
        bl = common.get_blazeit_scores(ds, "rare_event", quick, classify=True,
                                       score_fn=score_fn,
                                       budget=common.tmas_budget(wl))
        res_b = eng_t.execute(spec(proxy=bl))
        rows.append((f"fig6/{ds}/blazeit", "invocations", res_b.n_invocations))
        for variant in ("PT", "T"):
            eng = common.get_engine(ds, variant, quick)
            res = eng.execute(spec())
            rows.append((f"fig6/{ds}/tasti_{variant.lower()}", "invocations",
                         res.n_invocations))
    return rows
