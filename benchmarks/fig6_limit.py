"""Fig. 6: target-DNN invocations for limit queries over rare events (lower is
better).  TASTI uses k=1 propagation with distance tie-breaks (paper §6.3).
"""
import numpy as np

from benchmarks import common
from repro.core.queries.limit import limit_query


def run(quick: bool = False):
    rows = []
    for ds in common.ALL_SETS:
        wl = common.get_workload(ds, quick)
        score_fn = common.rare_event_fn(wl, ds)
        n = len(wl.features)
        truth = np.asarray([score_fn(r) for r in wl.target_dnn_batch(range(n))])
        total_rare = int(truth.sum())
        if total_rare == 0:
            rows.append((f"fig6/{ds}/rare_total", "count", 0))
            continue
        want = min(10, max(1, total_rare // 2))
        oracle = lambda ids: truth[ids]
        rows.append((f"fig6/{ds}/rare_total", "count", total_rare))

        rng = np.random.default_rng(0)
        res_r = limit_query(rng.uniform(size=n), oracle, k_results=want,
                            batch=4)
        rows.append((f"fig6/{ds}/random_order", "invocations",
                     res_r.n_invocations))
        bl = common.get_blazeit_scores(ds, "rare_event", quick, classify=True,
                                       score_fn=score_fn,
                                       budget=common.tmas_budget(wl))
        res_b = limit_query(bl, oracle, k_results=want, batch=4)
        rows.append((f"fig6/{ds}/blazeit", "invocations", res_b.n_invocations))
        for variant in ("PT", "T"):
            sv = common.get_tasti(ds, variant, quick)
            proxy = sv.proxy_scores(score_fn, mode="top1")
            res = limit_query(proxy, oracle, k_results=want, batch=4)
            rows.append((f"fig6/{ds}/tasti_{variant.lower()}", "invocations",
                         res.n_invocations))
    return rows
