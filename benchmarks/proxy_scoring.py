"""Proxy-score materialization roofline: scores/s alongside labels/s.

The paper's query-time economics rest on propagation (§4.2) being many
orders of magnitude cheaper than target-DNN labeling: a proxy score is
O(k) arithmetic over cached rep distances, a label is a full DNN
invocation.  This bench measures that roofline directly, for both scoring
paths the engine can take:

* **host** — the float64 numpy reference in :mod:`repro.core.propagation`
  (the CPU serving default);
* **fused** — the jitted device path in :mod:`repro.kernels.propagate`
  (Pallas on TPU, XLA reference elsewhere) that
  :class:`~repro.core.resident.ResidentIndexState` replays against
  device-resident rep structures;

plus end-to-end ``QueryEngine.proxy_scores`` rates (rep-score mapping +
propagation + cache publish) with the resident path off and forced on.
``labels_per_s`` comes from the §3.4 cost model (hardware-independent, like
every other bench metric here); ``scores_per_label`` is the roofline ratio.

Parity is asserted, not just reported: fused numeric must match host within
float32 tolerance, categorical must agree exactly, and fused top1 must keep
the host path's score levels monotone.

    PYTHONPATH=src python -m benchmarks.proxy_scoring --quick --json out.json

(the ``--json`` form feeds the CI ``bench-gate`` job's regression check,
``benchmarks/check_regression.py``)
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List, Optional

import numpy as np

from repro.core import propagation as host
from repro.core import schema as schema_lib
from repro.core.engine import QueryEngine
from repro.core.index import TastiIndex

N_CLASSES = 8


def _make_structures(n: int, c: int, k: int, seed: int = 0):
    """Synthetic rep structures with the real invariants (ascending d2,
    in-range ids) — propagation cost doesn't depend on the geometry."""
    rng = np.random.default_rng(seed)
    topk_ids = rng.integers(0, c, (n, k)).astype(np.int64)
    topk_d2 = np.sort(rng.random((n, k)) * 4.0, axis=1)
    rep_scores = rng.random(c)
    return rep_scores, topk_ids, topk_d2


def _rate(fn, n_items: int, repeats: int = 5, inner: int = 3) -> float:
    """items/sec at best-of-``repeats`` (each averaging ``inner`` calls);
    one warmup call first so jit compilation never lands in a sample."""
    fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(inner):
            fn()
        best = min(best, (time.perf_counter() - t0) / inner)
    return n_items / max(best, 1e-12)


def run(quick: bool = False):
    import jax.numpy as jnp

    from repro.kernels.propagate.ops import propagate as fused
    n, c, k = (60_000, 256, 8) if quick else (250_000, 512, 8)
    rep_scores, topk_ids, topk_d2 = _make_structures(n, c, k)
    cat_scores = np.floor(rep_scores * N_CLASSES)
    dev = dict(scores=jnp.asarray(rep_scores, jnp.float32),
               cat=jnp.asarray(cat_scores, jnp.float32),
               ids=jnp.asarray(topk_ids, jnp.int32),
               d2=jnp.asarray(topk_d2, jnp.float32))
    rows = []

    host_calls = {
        "numeric": lambda: host.propagate_numeric(rep_scores, topk_ids,
                                                  topk_d2),
        "top1": lambda: host.propagate_top1(rep_scores, topk_ids, topk_d2),
        "categorical": lambda: host.propagate_categorical(
            cat_scores, topk_ids, topk_d2, N_CLASSES),
    }
    fused_calls = {
        "numeric": lambda: np.asarray(fused(dev["scores"], dev["ids"],
                                            dev["d2"], "numeric",
                                            donate=False)),
        "top1": lambda: np.asarray(fused(dev["scores"], dev["ids"],
                                         dev["d2"], "top1", donate=False)),
        "categorical": lambda: np.asarray(fused(dev["cat"], dev["ids"],
                                                dev["d2"], "categorical",
                                                n_classes=N_CLASSES,
                                                donate=False)),
    }
    for mode in ("numeric", "top1", "categorical"):
        rows.append((f"proxy/host_{mode}", "scores_per_s",
                     round(_rate(host_calls[mode], n), 1)))
        rows.append((f"proxy/fused_{mode}", "scores_per_s",
                     round(_rate(fused_calls[mode], n), 1)))

    # parity assertions: the fast path must not buy speed with wrong scores
    h_num, f_num = host_calls["numeric"](), fused_calls["numeric"]()
    if not np.allclose(h_num, f_num, rtol=1e-4, atol=1e-5):
        raise AssertionError(
            "fused numeric propagation diverged from the float64 host path "
            f"(max abs err {np.abs(h_num - f_num).max():.3g})")
    h_cat, f_cat = host_calls["categorical"](), fused_calls["categorical"]()
    if (h_cat != f_cat).any():
        raise AssertionError(
            f"fused categorical vote disagreed on {(h_cat != f_cat).sum()} "
            f"of {n} records")
    f_top1 = fused_calls["top1"]()
    levels = rep_scores[topk_ids[:, 0]].astype(np.float32)
    if (np.diff(levels[np.argsort(-f_top1, kind="stable")]) > 0).any():
        raise AssertionError(
            "fused top1 propagation flipped distinct score levels; the "
            "distance nudge must only reorder within one level")

    # end-to-end engine rates: rep-score mapping + propagation + publish
    # (cache cleared per call — we are timing materialization, not the hit)
    index = TastiIndex(embeddings=np.zeros((n, 4), np.float32),
                       rep_ids=np.arange(c),
                       annotations=[float(s) for s in rep_scores],
                       topk_d2=topk_d2, topk_ids=topk_ids, k=k)
    for label, resident in (("engine_host", False), ("engine_resident", True)):
        eng = QueryEngine(index, resident=resident)

        def call(eng=eng):
            eng._proxy_cache.clear()
            eng.proxy_scores(float, mode="numeric", score_key="bench")
        rows.append((f"proxy/{label}", "scores_per_s",
                     round(_rate(call, n), 1)))
        if resident and eng.stats["proxy_device_computes"] == 0:
            raise AssertionError("forced-resident engine never took the "
                                 "fused device path")

    labels_per_s = 1.0 / schema_lib.TARGET_DNN_COST_S
    best_scores = max(v for name, m, v in rows
                      if m == "scores_per_s" and "engine" not in name)
    rows.append(("proxy/model", "labels_per_s", round(labels_per_s, 1)))
    rows.append(("proxy/roofline", "scores_per_label",
                 round(best_scores / labels_per_s, 1)))
    return rows


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="proxy scoring roofline: scores/s (host + fused device "
                    "paths) vs cost-model labels/s")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="also write the measurements as JSON (the CI "
                         "bench-gate artifact)")
    args = ap.parse_args(argv)
    rows = run(args.quick)
    payload = {"quick": args.quick,
               "metrics": {f"{name}.{metric}": value
                           for name, metric, value in rows}}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
    print(json.dumps(payload, indent=2))


if __name__ == "__main__":
    main()
