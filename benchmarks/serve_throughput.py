"""Serving throughput: queries/sec and fresh-labels-per-query over HTTP,
concurrent vs serial clients, cold vs warm label store.

Four phases against a real :class:`~repro.serve.server.QueryServer` (stdlib
HTTP, admission window, worker pool), one shared TASTI index:

* **cold/serial** — empty store, clients one at a time;
* **cold/concurrent** — empty store, all clients posting at once (the
  admission window coalesces them into shared sessions, so fresh labels per
  query drop);
* **warm/serial + warm/concurrent** — a *restarted* server (new engine, new
  broker) over the store the cold phases persisted, answering the same spec
  lists.  The paper's cost metric for a repeat query must be **zero** fresh
  target-DNN invocations — asserted, not just reported.

    PYTHONPATH=src python -m benchmarks.serve_throughput --quick --json out.json

(the ``--json`` form feeds the CI ``bench-gate`` job's regression check,
``benchmarks/check_regression.py``)
"""
from __future__ import annotations

import argparse
import json
import tempfile
import threading
import time
from typing import List, Optional

from benchmarks import common
from repro.core.engine import QueryEngine
from repro.core.index import TastiIndex
from repro.serve import LabelStore, QueryClient, QueryServer


def _spec_lists(quick: bool) -> List[List[dict]]:
    lists = []
    for seed in range(4 if quick else 8):
        lists.append([
            {"kind": "aggregation", "score": "score_count",
             "err": 0.15, "seed": seed},
            {"kind": "selection", "score": "score_has_object",
             "budget": 100 + 20 * seed, "seed": seed},
            {"kind": "limit", "score": "score_has_object",
             "k_results": 3 + seed % 3},
        ])
    return lists


def _start_server(index, wl, stem: str, obs=None) -> QueryServer:
    engine = QueryEngine(index, wl)
    store = LabelStore.for_index(stem, index)
    store.attach(engine.broker, engine)
    return QueryServer(engine, port=0, admission_window=0.05,
                       max_workers=4, store=store, obs=obs).start()


def _drive(url: str, spec_lists: List[List[dict]], concurrent: bool):
    """Post every spec list; returns (queries/sec, total fresh labels)."""
    client = QueryClient(url)
    client.wait_ready(30)
    fresh = [0] * len(spec_lists)
    errors: List[BaseException] = []
    t0 = time.perf_counter()
    if concurrent:
        def post(i):
            try:
                fresh[i] = client.query(spec_lists[i])["request"]["fresh"]
            except BaseException as e:  # noqa: BLE001 - re-raised below
                errors.append(e)
        threads = [threading.Thread(target=post, args=(i,))
                   for i in range(len(spec_lists))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            # a swallowed failure would leave fresh[i]=0 and falsely pass
            # the warm-restart zero-fresh assertion
            raise errors[0]
    else:
        for i, specs in enumerate(spec_lists):
            fresh[i] = client.query(specs)["request"]["fresh"]
    elapsed = time.perf_counter() - t0
    n_queries = sum(len(s) for s in spec_lists)
    return n_queries / max(elapsed, 1e-9), sum(fresh)


def run(quick: bool = False):
    wl = common.get_workload("night-street", quick)
    index = TastiIndex.build(wl.features, 150 if quick else 300,
                             wl.target_dnn_batch, k=4, random_fraction=0.0,
                             seed=0)
    spec_lists = _spec_lists(quick)
    n_queries = sum(len(s) for s in spec_lists)
    rows = []

    with tempfile.TemporaryDirectory() as tmp:
        for mode in ("serial", "concurrent"):
            stem = f"{tmp}/{mode}"
            # cold: empty store, every label paid for at the target DNN
            server = _start_server(index, wl, stem)
            qps, fresh = _drive(server.url, spec_lists, mode == "concurrent")
            server.shutdown()
            rows.append((f"serve/cold_{mode}", "queries_per_s", round(qps, 2)))
            rows.append((f"serve/cold_{mode}", "fresh_per_query",
                         round(fresh / n_queries, 2)))

            # warm restart: NEW engine + broker, labels only from the store
            server = _start_server(index, wl, stem)
            seeded = len(server.store)
            qps, fresh = _drive(server.url, spec_lists, mode == "concurrent")
            server.shutdown()
            rows.append((f"serve/warm_{mode}", "queries_per_s", round(qps, 2)))
            rows.append((f"serve/warm_{mode}", "fresh_per_query",
                         round(fresh / n_queries, 2)))
            rows.append((f"serve/warm_{mode}", "store_labels", seeded))
            if fresh != 0:
                raise AssertionError(
                    f"warm {mode} restart issued {fresh} fresh target-DNN "
                    "invocations on a repeated spec list; the persistent "
                    "label store must answer repeats for free")

        # observability overhead: the warm/concurrent drive (HTTP + sessions,
        # zero oracle work — the layer where per-request tracing and metric
        # increments could actually show up) with observability ON vs OFF on
        # the same warmed store.  Best-of-3 per variant damps scheduler
        # jitter; the bench gate asserts the ratio stays >= 0.95.
        stem = f"{tmp}/concurrent"
        best = {}
        for obs_on in (True, False):
            qps_best = 0.0
            for _ in range(3):
                server = _start_server(index, wl, stem, obs=obs_on)
                qps, fresh = _drive(server.url, spec_lists, True)
                server.shutdown()
                if fresh != 0:
                    raise AssertionError(
                        f"obs_overhead leg (obs={obs_on}) paid {fresh} "
                        "fresh labels on the warmed store")
                qps_best = max(qps_best, qps)
            best[obs_on] = qps_best
        rows.append(("serve/obs_overhead", "qps_ratio",
                     round(best[True] / best[False], 4)))
        rows.append(("serve/obs_overhead", "qps_enabled",
                     round(best[True], 2)))
        rows.append(("serve/obs_overhead", "qps_disabled",
                     round(best[False], 2)))
    return rows


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="serving throughput: queries/s and fresh-per-query, "
                    "serial/concurrent x cold/warm")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="also write the measurements as JSON (the CI "
                         "bench-gate artifact)")
    args = ap.parse_args(argv)
    rows = run(args.quick)
    payload = {"quick": args.quick,
               "metrics": {f"{name}.{metric}": value
                           for name, metric, value in rows}}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
    print(json.dumps(payload, indent=2))


if __name__ == "__main__":
    main()
