"""End-to-end driver: train an LM of the assigned-architecture family with the
full production runtime — sharded resumable data pipeline, AdamW, async
checkpointing, straggler monitor — and an injected node failure at step 30 to
demonstrate checkpoint/restart recovery.

    PYTHONPATH=src python examples/train_lm_resilient.py            # ci size
    PYTHONPATH=src python examples/train_lm_resilient.py --preset 100m \
        --steps 300                                                  # ~100M
"""
import argparse
import subprocess
import sys
import tempfile


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="ci", choices=["ci", "100m"])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--arch", default="llama3.2-1b")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt_dir:
        cmd = [sys.executable, "-m", "repro.launch.train",
               "--arch", args.arch, "--preset", args.preset,
               "--steps", str(args.steps), "--ckpt-dir", ckpt_dir,
               "--ckpt-every", "20", "--inject-failure-at", "30"]
        print("+", " ".join(cmd))
        subprocess.run(cmd, check=True)


if __name__ == "__main__":
    main()
