"""Index cracking across a query session (paper §6.6): every target-DNN
invocation a query makes is folded back into the index, improving later
queries — and the index persists to disk between sessions.

    PYTHONPATH=src python examples/cracking_and_reuse.py
"""
import tempfile

import numpy as np

from repro.core.index import TastiIndex
from repro.core.pipeline import TastiConfig, build_tasti
from repro.core.queries.aggregation import aggregate_control_variates
from repro.core.queries.selection import false_positive_rate, supg_recall_target
from repro.core.schema import make_workload
from repro.core.triplet import TripletConfig


def main() -> None:
    wl = make_workload("taipei", n_frames=6000)
    truth_cnt = wl.counts.astype(float)
    truth_sel = wl.counts > 0
    cfg = TastiConfig(n_train=250, n_reps=500, k=4,
                      triplet=TripletConfig(steps=250), pretrain_steps=80)
    tasti = build_tasti(wl, cfg, variant="T")

    # Query 1: aggregation (samples records with the target DNN)
    agg = aggregate_control_variates(tasti.proxy_scores(wl.score_count),
                                     tasti.oracle(wl.score_count), err=0.05)
    print(f"query 1 (aggregation): {agg.n_invocations} target-DNN calls")

    # FPR of a SUPG query *before* cracking
    sel_proxy = np.clip(tasti.proxy_scores(wl.score_has_object), 0, 1)
    before = false_positive_rate(
        supg_recall_target(sel_proxy, tasti.oracle(wl.score_has_object),
                           budget=400, seed=0).selected, truth_sel)

    # Crack: fold query 1's annotations into the index (cheap: distances to
    # the new representatives only)
    tasti.crack_with(agg.sampled_ids)
    print(f"cracked index: now {tasti.index.n_reps} representatives, "
          f"max intra-cluster dist {tasti.index.max_intra_cluster():.3f}")

    sel_proxy2 = np.clip(tasti.proxy_scores(wl.score_has_object), 0, 1)
    after = false_positive_rate(
        supg_recall_target(sel_proxy2, tasti.oracle(wl.score_has_object),
                           budget=400, seed=0).selected, truth_sel)
    print(f"query 2 (SUPG) FPR: before crack {before:.4f} -> after {after:.4f}")

    # Persist and reload the index (new session, no reconstruction)
    with tempfile.TemporaryDirectory() as d:
        tasti.index.save(f"{d}/taipei_index")
        idx2 = TastiIndex.load(f"{d}/taipei_index")
        print(f"reloaded index: {idx2.n_reps} reps, "
              f"{idx2.cost.target_invocations} total target-DNN calls charged")


if __name__ == "__main__":
    main()
