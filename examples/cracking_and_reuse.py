"""Index cracking across a query session (paper §6.6): with the engine's
feedback loop enabled, every target-DNN invocation a query makes is folded
back into the index, improving later queries — labels are shared across the
session, and the index persists to disk (versioned JSON + npz) between
sessions.

    PYTHONPATH=src python examples/cracking_and_reuse.py
"""
import tempfile

import numpy as np

from repro.core.engine import QueryEngine, QuerySpec
from repro.core.index import TastiIndex
from repro.core.pipeline import TastiConfig, build_tasti
from repro.core.queries.selection import false_positive_rate
from repro.core.schema import make_workload
from repro.core.triplet import TripletConfig


def main() -> None:
    wl = make_workload("taipei", n_frames=6000)
    truth_sel = wl.counts > 0
    cfg = TastiConfig(n_train=250, n_reps=500, k=4,
                      triplet=TripletConfig(steps=250), pretrain_steps=80)
    tasti = build_tasti(wl, cfg, variant="T")
    engine = tasti.engine

    supg = QuerySpec(kind="selection", score="score_has_object", budget=400,
                     seed=0, reuse_labels=False)

    # FPR of a SUPG query *before* cracking
    before = false_positive_rate(engine.execute(supg).selected, truth_sel)

    # Query 1: aggregation with the cracking feedback loop on — its samples
    # are annotated by the target DNN and folded straight back into the index
    # (cheap: distances to the new representatives only)
    agg = engine.execute(QuerySpec(kind="aggregation", score="score_count",
                                   err=0.05, crack=True))
    print(f"query 1 (aggregation): {agg.n_invocations} target-DNN calls, "
          f"{agg.n_cracked} folded back as new representatives")
    print(f"cracked index: now {tasti.index.n_reps} representatives, "
          f"max intra-cluster dist {tasti.index.max_intra_cluster():.3f}")

    # Query 2: the proxy cache self-invalidated, so the SUPG query sees the
    # post-crack propagation
    after = false_positive_rate(engine.execute(supg).selected, truth_sel)
    print(f"query 2 (SUPG) FPR: before crack {before:.4f} -> after {after:.4f}")
    print(f"session stats: {engine.stats}")

    # Persist and reload the index (new session, no reconstruction).  The
    # format is versioned JSON + npz — no pickle, safe to share.
    with tempfile.TemporaryDirectory() as d:
        tasti.index.save(f"{d}/taipei_index")
        idx2 = TastiIndex.load(f"{d}/taipei_index")
        engine2 = QueryEngine(idx2, wl)
        agg2 = engine2.execute(QuerySpec(kind="aggregation",
                                         score="score_count", err=0.05))
        print(f"reloaded index: {idx2.n_reps} reps, "
              f"{idx2.cost.target_invocations} total target-DNN calls charged; "
              f"fresh-session estimate {agg2.estimate:.3f}")


if __name__ == "__main__":
    main()
