"""Serving example: batched prefill + decode against the sequence-sharded KV
cache (the decode path the dry-run's decode_32k/long_500k cells lower).

    PYTHONPATH=src python examples/serve_batched.py
"""
import subprocess
import sys


def main() -> None:
    for arch in ("qwen3-1.7b", "xlstm-350m"):
        cmd = [sys.executable, "-m", "repro.launch.serve_lm", "--arch", arch,
               "--preset", "ci", "--batch", "4", "--prompt-len", "24",
               "--decode-steps", "12"]
        print("+", " ".join(cmd))
        subprocess.run(cmd, check=True)


if __name__ == "__main__":
    main()
