"""Pod-scale index construction path: TASTI with a *transformer backbone*
embedder (the tasti-embedder config — swap in any of the 10 assigned archs),
then the build_index launcher CLI.

    PYTHONPATH=src python examples/pod_scale_index.py
"""
import subprocess
import sys
import tempfile

import numpy as np

from repro.core.embedder import EmbedderConfig
from repro.core.pipeline import TastiConfig, build_tasti
from repro.core.schema import make_workload
from repro.core.triplet import TripletConfig


def main() -> None:
    wl = make_workload("night-street", n_frames=2000)
    # Note: build_tasti's embedder config is constructed internally from
    # TastiConfig; here we demonstrate the backbone path directly through a
    # smaller build (the backbone forward is the §Perf/B prefill workload).
    cfg = TastiConfig(n_train=150, n_reps=300, k=4,
                      triplet=TripletConfig(steps=100), pretrain_steps=40)
    sys_t = build_tasti(wl, cfg, variant="T")
    proxy = sys_t.proxy_scores(wl.score_count)
    rho2 = np.corrcoef(proxy, wl.counts.astype(float))[0, 1] ** 2
    print(f"[pod_scale_index] in-process build: rho^2={rho2:.3f}, "
          f"{sys_t.index.cost.target_invocations} target-DNN calls")

    with tempfile.TemporaryDirectory() as d:
        cmd = [sys.executable, "-m", "repro.launch.build_index",
               "--workload", "taipei", "--n-frames", "2000",
               "--n-train", "150", "--n-reps", "300",
               "--triplet-steps", "100", "--out", f"{d}/taipei_idx"]
        print("+", " ".join(cmd))
        subprocess.run(cmd, check=True)


if __name__ == "__main__":
    main()
