"""Quickstart: build a TASTI index on a synthetic video workload and run the
paper's three query types against it — declaratively, through the query
engine (``QuerySpec`` -> plan -> execute).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.engine import QuerySpec
from repro.core.pipeline import TastiConfig, build_tasti
from repro.core.queries.selection import achieved_recall, false_positive_rate
from repro.core.schema import make_workload
from repro.core.triplet import TripletConfig


def main() -> None:
    # 1. A "video": 6000 frames, mostly empty, with rare heavy-traffic events.
    #    The target DNN (Mask R-CNN stand-in) is 4000x more expensive than the
    #    embedding DNN — the regime TASTI exploits.
    wl = make_workload("night-street", n_frames=6000)
    truth = wl.counts.astype(float)
    print(f"workload: {wl.name}, {len(truth)} frames, "
          f"{int((wl.counts >= wl.rare_count).sum())} rare events")

    # 2. Build the index: FPF-mined triplet training (300 target-DNN
    #    annotations) + 600 FPF cluster representatives.
    cfg = TastiConfig(n_train=300, n_reps=600, k=4,
                      triplet=TripletConfig(steps=300), pretrain_steps=100)
    tasti = build_tasti(wl, cfg, variant="T")
    print(f"index built: {tasti.index.n_reps} reps, "
          f"construction = {tasti.index.cost.wall_clock_s():.0f}s "
          f"(cost model; {tasti.index.cost.target_invocations} target-DNN calls)")

    # 3a. Aggregation: average cars/frame with an error bound.  The engine
    #     picks numeric propagation and wires the oracle automatically.
    agg = tasti.execute(QuerySpec(kind="aggregation", score="score_count",
                                  err=0.05))
    print(f"aggregation: est={agg.estimate:.3f} (true {truth.mean():.3f}) "
          f"using {agg.n_invocations} target-DNN calls")

    # 3b. Selection with recall guarantee (SUPG): frames with any car.
    truth_sel = wl.counts > 0
    sel = tasti.execute(QuerySpec(kind="selection", score="score_has_object",
                                  budget=300, recall_target=0.9))
    print(f"selection: |S|={len(sel.selected)} "
          f"recall={achieved_recall(sel.selected, truth_sel):.3f} "
          f"fpr={false_positive_rate(sel.selected, truth_sel):.3f}")

    # 3c. Limit query: find 10 rare heavy-traffic frames.  The engine uses
    #     top-1 propagation with distance tie-breaks (§6.3) for this kind.
    lim = tasti.execute(QuerySpec(kind="limit", score="score_rare",
                                  k_results=10))
    print(f"limit: found {len(lim.selected)} rare frames with "
          f"{lim.n_invocations} target-DNN calls "
          f"({lim.n_oracle_cached} labels served from the session cache)")
    print(f"  plan: {' | '.join(lim.plan.trace)}")

    # 4. The same index answers a brand-new query type with zero new
    #    target-DNN calls (task-agnosticity).
    pos_proxy = tasti.proxy_scores(wl.score_mean_x)
    print(f"new query (avg x-position) proxy rho^2 = "
          f"{np.corrcoef(pos_proxy, [s.mean_x() for s in wl.scenes])[0,1]**2:.3f}"
          f" — no additional annotations")


if __name__ == "__main__":
    main()
