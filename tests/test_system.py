"""End-to-end behaviour tests for the TASTI system (paper §6 in miniature):
index construction cost structure, all three query types, cracking, and the
task-agnostic reuse property."""
import numpy as np
import pytest

from repro.core.pipeline import TastiConfig, build_tasti
from repro.core.queries.aggregation import aggregate_control_variates
from repro.core.queries.limit import limit_query
from repro.core.queries.selection import (achieved_recall,
                                          false_positive_rate,
                                          supg_recall_target)
from repro.core.schema import make_workload
from repro.core.triplet import TripletConfig


@pytest.fixture(scope="module")
def wl():
    return make_workload("night-street", n_frames=4000)


@pytest.fixture(scope="module")
def tasti(wl):
    cfg = TastiConfig(n_train=250, n_reps=500, k=4,
                      triplet=TripletConfig(steps=250, batch=128),
                      pretrain_steps=80)
    return build_tasti(wl, cfg, variant="T")


def test_construction_cost_model(tasti):
    cost = tasti.index.cost
    assert cost.target_invocations == 250 + 500
    bd = cost.breakdown()
    # target DNN dominates construction (paper fig. 2 structure)
    assert bd["target_dnn_s"] > 10 * (bd["embedding_s"] + bd["distance_s"])


def test_aggregation_query(wl, tasti):
    truth = wl.counts.astype(float)
    proxy = tasti.proxy_scores(wl.score_count)
    rho2 = np.corrcoef(proxy, truth)[0, 1] ** 2
    assert rho2 > 0.8  # paper: 0.91 on night-street
    res = aggregate_control_variates(proxy, tasti.oracle(wl.score_count),
                                     err=0.05, seed=0)
    assert abs(res.estimate - truth.mean()) < 0.15
    res_rand = aggregate_control_variates(proxy, tasti.oracle(wl.score_count),
                                          err=0.05, seed=0, use_cv=False)
    assert res.n_invocations < res_rand.n_invocations


def test_supg_selection_query(wl, tasti):
    truth = wl.counts > 0
    proxy = np.clip(tasti.proxy_scores(wl.score_has_object), 0, 1)
    r = supg_recall_target(proxy, tasti.oracle(wl.score_has_object),
                           budget=250, recall_target=0.9, seed=0)
    assert achieved_recall(r.selected, truth) >= 0.85  # one MC draw
    assert false_positive_rate(r.selected, truth) < 0.3


def test_limit_query_rare_events(wl, tasti):
    proxy = tasti.proxy_scores(wl.score_rare, mode="top1")
    res = limit_query(proxy, tasti.oracle(wl.score_rare), k_results=5)
    rare_total = int((wl.counts >= wl.rare_count).sum())
    assert len(res.found_ids) == min(5, rare_total)
    # far fewer invocations than scanning: the paper's headline win
    assert res.n_invocations < 0.1 * len(wl.counts)


def test_cracking_improves_index(wl, tasti):
    idx_before = tasti.index.max_intra_cluster()
    # crack with the records farthest from their representatives
    far = np.argsort(-tasti.index.topk_d2[:, 0])[:50]
    tasti.crack_with(far)
    assert tasti.index.max_intra_cluster() < idx_before
    truth = wl.counts.astype(float)
    proxy = tasti.proxy_scores(wl.score_count)
    assert np.corrcoef(proxy, truth)[0, 1] ** 2 > 0.8


def test_task_agnostic_reuse(wl, tasti):
    """One index serves all query types (the paper's core claim): no extra
    target-DNN invocations between count/predicate/position/rare queries."""
    inv_before = tasti.index.cost.target_invocations
    _ = tasti.proxy_scores(wl.score_count)
    _ = tasti.proxy_scores(wl.score_has_object)
    _ = tasti.proxy_scores(wl.score_left_side)
    _ = tasti.proxy_scores(wl.score_mean_x)
    _ = tasti.proxy_scores(wl.score_rare, mode="top1")
    assert tasti.index.cost.target_invocations == inv_before


def test_text_workload_end_to_end():
    wl = make_workload("wikisql", n_records=2000)
    cfg = TastiConfig(n_train=150, n_reps=300, k=4,
                      triplet=TripletConfig(steps=150, batch=128),
                      pretrain_steps=60)
    sys_t = build_tasti(wl, cfg, variant="T")
    truth = wl.n_predicates.astype(float)
    proxy = sys_t.proxy_scores(wl.score_n_predicates)
    assert np.corrcoef(proxy, truth)[0, 1] ** 2 > 0.5
