"""Tiered LabelStore tests: journal rotation + crash replay, budgeted
eviction that never loses a journaled label, warm-tier reads byte-identical
to hot, v1 snapshot migration (and the torn-v1 degrade-don't-crash fix),
tier-hit accounting, and the format helpers (byte parsing, bloom filter)."""
import json

import numpy as np
import pytest

from repro.core.broker import OracleBroker
from repro.core.index import _encode_annotation
from repro.core.schema import Scene
from repro.serve.store import LabelStore
from repro.serve.store import format as fmt
from repro.serve.store.hot import CLEAN

pytestmark = pytest.mark.tier1


def _oracle(ids):
    return [float(i) * 0.5 for i in ids]


def _write_v1_snapshot(stem, labels, index_version=0, torn_extra_ids=0):
    """Lay down version-1 store files by hand (one inline snapshot)."""
    meta = {"format_version": 1, "index_version": index_version,
            "fingerprint": None,
            "annotations": [_encode_annotation(a) for a in labels.values()]}
    with open(fmt.manifest_path(stem), "w") as f:
        json.dump(meta, f)
    ids = sorted(labels)
    ids += list(range(10_000, 10_000 + torn_extra_ids))
    np.savez(fmt.ids_path(stem), ids=np.asarray(ids, np.int64))


# -- v1 compatibility ------------------------------------------------------
def test_torn_v1_snapshot_degrades_to_empty_with_warning(tmp_path, capfd):
    """A half-written v1 snapshot (ids/annotations length mismatch) must
    open as an empty store with a logged warning, not crash the server —
    labels are re-derivable, a refused startup is not."""
    stem = tmp_path / "s"
    _write_v1_snapshot(stem, {0: 1.0, 1: 2.0}, torn_extra_ids=3)
    store = LabelStore.open(str(stem), 0)
    assert len(store) == 0
    err = capfd.readouterr().err
    assert "[label-store]" in err and "torn" in err
    # the degraded store is fully usable: writes persist and reload
    store.update({7: 7.5})
    store.save()
    assert LabelStore.open(str(stem), 0).labels == {7: 7.5}


def test_v1_snapshot_loads_and_migrates_to_v2(tmp_path):
    stem = tmp_path / "s"
    labels = {i: float(i) for i in range(20)}
    _write_v1_snapshot(stem, labels)
    store = LabelStore.open(str(stem), 0)
    assert store.labels == labels
    store.save()  # migration: next compaction writes the tiered layout
    with open(store.json_path) as f:
        assert json.load(f)["format_version"] == 2
    again = LabelStore.open(str(stem), 0)
    assert again.labels == labels


def test_future_format_version_still_refuses(tmp_path):
    stem = tmp_path / "s"
    with open(fmt.manifest_path(stem), "w") as f:
        json.dump({"format_version": 99, "index_version": 0}, f)
    with pytest.raises(ValueError, match="format_version 99"):
        LabelStore.open(str(stem), 0)


# -- journal rotation + crash replay ---------------------------------------
def test_crash_between_rotate_and_compact_replays_both_segments(tmp_path):
    """Sealed journal segments AND the active journal both replay after a
    crash (a rotation is not a durability boundary, only a file boundary)."""
    stem = str(tmp_path / "s")
    store = LabelStore(stem, journal_rotate_bytes=256, auto_compact=False)
    broker = OracleBroker(_oracle, max_batch=8)
    store.attach(broker)
    broker.fetch(list(range(40)))  # several flushes; tiny threshold rotates
    broker.fetch([40, 41, 42])     # small tail that stays in the active file
    assert store.stats["journal_rotations"] >= 1
    assert len(fmt.sealed_journals(store.path)) >= 1
    assert store.journal_path.exists()  # active tail past the last rotation
    # crash: no save(); a fresh open must replay sealed + active journals
    revived = LabelStore.open(stem, 0)
    assert sorted(revived.labels) == list(range(43))
    assert revived.labels[11] == pytest.approx(5.5)


def test_background_compaction_folds_sealed_journals(tmp_path):
    stem = str(tmp_path / "s")
    store = LabelStore(stem, journal_rotate_bytes=256, auto_compact=False,
                       compact_after=1)
    broker = OracleBroker(_oracle, max_batch=8)
    store.attach(broker)
    broker.fetch(list(range(40)))
    with store._lock:
        folded = store._compact_sealed_locked()  # what the thread runs
    assert folded > 0
    assert store.stats["compactions"] >= 1
    assert not fmt.sealed_journals(store.path)  # subsumed + unlinked
    assert LabelStore.open(stem, 0).labels == store.labels


# -- budgets + eviction ----------------------------------------------------
def test_eviction_under_pressure_never_loses_a_journaled_label(tmp_path):
    """Tracked hot bytes stay under the budget at every step, and every
    label ever journaled is still readable (hot or warm) and survives a
    restart — eviction only ever drops warm-resident copies."""
    stem = str(tmp_path / "s")
    store = LabelStore(stem, hot_budget=2048, journal_rotate_bytes=256)
    broker = OracleBroker(_oracle, max_batch=8)
    store.attach(broker)
    total = 300
    for start in range(0, total, 20):
        broker.fetch(list(range(start, start + 20)))
        assert store._hot.bytes <= 2048  # enforced after every operation
    assert store.stats["evictions"] > 0
    assert len(store) == total
    got = store.get_many(range(total), promote=False)
    assert len(got) == total
    assert all(got[i] == pytest.approx(i * 0.5) for i in range(total))
    revived = LabelStore.open(stem, 0, hot_budget=2048)
    assert len(revived) == total
    assert revived._hot.bytes <= 2048


def test_bare_update_is_pinned_until_saved(tmp_path):
    """Memory-only labels (no journal yet) must never be evicted either."""
    store = LabelStore(str(tmp_path / "s"), hot_budget=512)
    big = {i: np.full(64, float(i)) for i in range(8)}  # way over budget
    store.update(big)
    assert len(store) == 8
    assert store._hot.bytes > 512  # over budget, but nothing droppable
    assert store.stats["evictions"] == 0
    store.save()  # now warm-resident -> evictable
    assert store._hot.bytes <= 512
    assert len(store) == 8


# -- warm-tier fidelity ----------------------------------------------------
def test_warm_reads_are_byte_identical_to_hot(tmp_path):
    stem = str(tmp_path / "s")
    labels = {
        0: np.arange(12, dtype=np.float32).reshape(3, 4),
        1: Scene(boxes=np.asarray([[0.25, 0.5], [0.75, 0.1]])),
        2: {"tag": "night", "scores": [1.0, 2.5], "n": 3},
        3: "a string annotation",
        4: None,
        5: 42,
    }
    store = LabelStore(stem, labels=dict(labels))
    hot = {i: store.broker_get(i) for i in labels}
    store.save()
    cold = LabelStore.open(stem, 0, hot_budget=1)  # nothing fits hot
    for i in labels:
        warm = cold.broker_get(i)
        if isinstance(labels[i], np.ndarray):
            assert warm.dtype == hot[i].dtype
            assert np.array_equal(warm, hot[i])
        elif isinstance(labels[i], Scene):
            assert np.array_equal(warm.boxes, hot[i].boxes)
        else:
            assert warm == hot[i]


def test_warm_lookup_skips_non_member_segments(tmp_path):
    """Fence + bloom: misses answer without reading annotation bytes."""
    stem = str(tmp_path / "s")
    store = LabelStore(stem, labels={i: float(i) for i in range(100, 200)})
    store.save()
    cold = LabelStore.open(stem, 0)
    assert cold._warm.get_many(range(0, 50)) == {}
    seg = cold._warm.segments[0]
    assert seg._mmap is None  # fences answered before any annotation read
    assert 150 in cold and 50 not in cold


# -- broker integration + accounting ---------------------------------------
def test_tier_hits_plus_fresh_account_for_every_request(tmp_path):
    """hits_hot + hits_warm + dedup_inflight + fresh == requests — the
    accounting invariant the docs promise, across a budgeted restart."""
    stem = str(tmp_path / "s")
    store = LabelStore(stem, hot_budget=4096)
    broker = OracleBroker(_oracle, max_batch=16)
    store.attach(broker)
    broker.fetch(list(range(120)))
    broker.fetch(list(range(60, 180)))     # half cached, half fresh
    broker.fetch(list(range(0, 40)))       # cached (hot or warm)
    s, b = store.stats, broker.stats
    assert b["fresh"] == 180
    assert (s["hits_hot"] + s["hits_warm"] + b["dedup_inflight"]
            + b["fresh"] == b["requests"])
    assert s["hits_hot"] + s["hits_warm"] == b["cached"]
    store.save()
    # warm restart with a tiny hot tier: repeats cost ZERO fresh labels
    cold = LabelStore.open(stem, 0, hot_budget=1024)
    broker2 = OracleBroker(_oracle, max_batch=16)
    assert cold.attach(broker2) == 180
    broker2.fetch(list(range(180)))
    assert broker2.stats["fresh"] == 0
    assert cold.stats["hits_warm"] > 0  # the tiny hot tier can't hold all


def test_adopt_cache_carries_prior_labels(tmp_path):
    broker = OracleBroker(_oracle, max_batch=16)
    broker.fetch([1, 2, 3])
    store = LabelStore(str(tmp_path / "s"))
    assert store.attach(broker) == 3  # pre-attach labels adopted
    broker.fetch([1, 2, 3, 4])
    assert broker.stats["fresh"] == 4
    assert sorted(store.labels) == [1, 2, 3, 4]


def test_mid_serving_fetch_promotes_warm_without_fresh(tmp_path):
    stem = str(tmp_path / "s")
    seed = LabelStore(stem, labels={i: float(i) for i in range(50)})
    seed.save()
    store = LabelStore.open(stem, 0, hot_budget=256)
    broker = OracleBroker(_oracle, max_batch=16)
    store.attach(broker)
    out = broker.fetch([7, 8, 9])
    assert out == [7.0, 8.0, 9.0]
    assert broker.stats["fresh"] == 0
    assert store.stats["hits_warm"] == 3
    assert store._hot.state(9) == CLEAN  # promoted copies stay evictable


# -- observability ---------------------------------------------------------
def test_observe_reports_tier_sizes_and_counters(tmp_path):
    store = LabelStore(str(tmp_path / "s"), hot_budget=4096,
                       journal_rotate_bytes=256)
    broker = OracleBroker(_oracle, max_batch=8)
    store.attach(broker)
    broker.fetch(list(range(60)))
    obs = store.observe()
    assert obs["n_labels"] == 60
    assert obs["hot"]["budget"] == 4096
    assert obs["hot"]["bytes"] <= 4096
    assert obs["journal"]["bytes"] > 0
    assert obs["journal"]["oldest_age_s"] >= 0.0
    store.save()
    obs = store.observe()
    assert obs["journal"]["bytes"] == 0
    assert obs["warm"]["entries"] == 60
    assert obs["warm"]["segments"] >= 1
    assert obs["counters"]["compactions"] >= 1


def test_segment_count_stays_bounded(tmp_path):
    stem = str(tmp_path / "s")
    store = LabelStore(stem, max_segments=3)
    for round_ in range(8):
        store.update({round_ * 100 + i: float(i) for i in range(30)})
        store.save()  # one new segment per save, folded past max_segments
    assert len(store._warm.segments) <= 3
    assert len(store) == 240
    assert LabelStore.open(stem, 0).labels == store.labels


# -- format helpers --------------------------------------------------------
def test_parse_bytes_accepts_ints_and_suffixes():
    assert fmt.parse_bytes(None) is None
    assert fmt.parse_bytes(1024) == 1024
    assert fmt.parse_bytes("64k") == 64 << 10
    assert fmt.parse_bytes("1.5m") == int(1.5 * (1 << 20))
    assert fmt.parse_bytes("2g") == 2 << 30
    for bad in ("nope", 0, -5, "0k", True):
        with pytest.raises(ValueError):
            fmt.parse_bytes(bad)


def test_bloom_filter_has_no_false_negatives():
    rng = np.random.default_rng(0)
    ids = np.unique(rng.integers(0, 1 << 40, size=500))
    bits = fmt.bloom_build(ids)
    assert fmt.bloom_maybe_contains(bits, ids).all()
    others = np.setdiff1d(np.arange(2000, dtype=np.int64), ids)
    fp = fmt.bloom_maybe_contains(bits, others).mean()
    assert fp < 0.25  # ~8 bits/id, 3 hashes: false positives stay rare
