"""Open-loop load harness tests: arrival processes (rate, burstiness,
reproducibility), spec mixes (weights, budget distributions, template
isolation), and the generator's open-loop firing + per-class report."""
import threading
import time

import numpy as np
import pytest

from repro.loadgen import (
    ArrivalProcess,
    OpenLoopGenerator,
    SpecClass,
    SpecMix,
)

pytestmark = pytest.mark.tier1


# -- arrivals --------------------------------------------------------------
def test_poisson_arrivals_hit_the_rate_and_stay_sorted():
    times = ArrivalProcess(rate=100.0, cv=1.0, seed=0).times(20.0)
    assert times == sorted(times)
    assert all(0 <= t < 20.0 for t in times)
    # mean rate within 10% over 2000 expected arrivals
    assert len(times) == pytest.approx(2000, rel=0.1)
    gaps = np.diff(times)
    assert gaps.std() / gaps.mean() == pytest.approx(1.0, abs=0.15)


def test_cv_controls_burstiness():
    regular = ArrivalProcess(rate=50.0, cv=0.2, seed=1).times(20.0)
    bursty = ArrivalProcess(rate=50.0, cv=3.0, seed=1).times(20.0)
    cv_of = lambda ts: np.diff(ts).std() / np.diff(ts).mean()  # noqa: E731
    assert cv_of(regular) < 0.4 < 2.0 < cv_of(bursty)
    # both still hit the same mean rate
    assert len(regular) == pytest.approx(1000, rel=0.15)
    assert len(bursty) == pytest.approx(1000, rel=0.25)


def test_arrivals_are_reproducible_and_validated():
    a = ArrivalProcess(rate=10.0, seed=7).times(5.0)
    b = ArrivalProcess(rate=10.0, seed=7).times(5.0)
    assert a == b
    assert ArrivalProcess(rate=10.0, seed=8).times(5.0) != a
    assert ArrivalProcess(rate=10.0).times(0.0) == []
    with pytest.raises(ValueError, match="rate"):
        ArrivalProcess(rate=0.0)
    with pytest.raises(ValueError, match="cv"):
        ArrivalProcess(rate=1.0, cv=0.0)


# -- mixes -----------------------------------------------------------------
def test_mix_samples_by_weight():
    mix = SpecMix([SpecClass("common", [{"kind": "a"}], weight=9.0),
                   SpecClass("rare", [{"kind": "b"}], weight=1.0)], seed=0)
    names = [mix.sample()[0].name for _ in range(1000)]
    assert names.count("common") == pytest.approx(900, rel=0.1)


def test_mix_budget_distributions():
    fixed = SpecClass("f", [{"kind": "a"}], budget=50)
    ranged = SpecClass("r", [{"kind": "a"}], budget=(10, 20))
    fn = SpecClass("c", [{"kind": "a"}],
                   budget=lambda rng: int(rng.integers(1, 3)))
    unbudgeted = SpecClass("u", [{"kind": "a"}])
    rng = np.random.default_rng(0)
    assert fixed.sample_budget(rng) == 50
    assert all(10 <= ranged.sample_budget(rng) <= 20 for _ in range(50))
    assert fn.sample_budget(rng) in (1, 2)
    assert unbudgeted.sample_budget(rng) is None


def test_mix_spec_templates_are_copied_per_sample():
    cls = SpecClass("t", [{"kind": "a", "seed": 0}])
    mix = SpecMix([cls], seed=0)
    _, specs, _ = mix.sample()
    specs[0]["seed"] = 999                  # caller mutates its copy
    assert mix.sample()[1][0]["seed"] == 0  # template untouched


def test_mix_validation():
    with pytest.raises(ValueError, match="weight"):
        SpecClass("bad", [{"kind": "a"}], weight=0.0)
    with pytest.raises(ValueError, match="duplicate"):
        SpecMix([SpecClass("x", [{"kind": "a"}]),
                 SpecClass("x", [{"kind": "b"}])])
    with pytest.raises(ValueError, match="at least one"):
        SpecMix([])


# -- generator -------------------------------------------------------------
def _mix_one(name="cls", **kw):
    return SpecMix([SpecClass(name, [{"kind": "agg"}], **kw)], seed=0)


def test_generator_reports_per_class_latencies():
    def post(specs, budget=None, priority=None, deadline_ms=None, name=None):
        time.sleep(0.01)
        return {"ok": True}

    report = OpenLoopGenerator(post, _mix_one(priority=0, deadline_ms=99.0),
                               ArrivalProcess(rate=40.0, seed=0), 1.0).run()
    assert report.offered > 10
    assert report.completed == report.offered and report.errors == 0
    cls = report.classes["cls"]
    assert cls["n"] == report.offered and cls["errors"] == 0
    assert 5.0 <= cls["p50_ms"] <= cls["p90_ms"] <= cls["p99_ms"] <= 500.0
    # the harness observed its own firing jitter
    assert report.max_fire_lag_ms >= 0.0


def test_generator_is_open_loop():
    """A stalled server must not slow the offered load: later requests
    fire on schedule while early ones are still blocked."""
    fired = []
    gate = threading.Event()

    def post(specs, budget=None, priority=None, deadline_ms=None, name=None):
        fired.append(time.monotonic())
        gate.wait(5.0)          # every request blocks until the end
        return {}

    t0 = time.monotonic()
    done = {}

    def run():
        done["report"] = OpenLoopGenerator(
            post, _mix_one(), ArrivalProcess(rate=20.0, seed=0), 1.0).run()

    runner = threading.Thread(target=run, daemon=True)
    runner.start()
    time.sleep(1.3)
    n_fired_during_window = len(fired)
    gate.set()
    runner.join(10.0)
    report = done["report"]
    # all arrivals fired during the window despite zero completions
    assert n_fired_during_window == report.offered > 10
    assert (max(o.fire_lag_s for o in report.outcomes)
            < 0.5), "firing fell behind schedule"
    assert time.monotonic() - t0 < 10.0


def test_generator_counts_errors_per_class():
    def post(specs, budget=None, priority=None, deadline_ms=None, name=None):
        if name == "bad":
            raise RuntimeError("boom")
        return {}

    mix = SpecMix([SpecClass("good", [{"kind": "a"}], weight=1.0),
                   SpecClass("bad", [{"kind": "b"}], weight=1.0)], seed=0)
    report = OpenLoopGenerator(post, mix,
                               ArrivalProcess(rate=30.0, seed=0), 1.0).run()
    assert report.classes["bad"]["errors"] == report.classes["bad"]["n"] > 0
    assert report.classes["good"]["errors"] == 0
    assert report.errors == report.classes["bad"]["n"]
    bad = [o for o in report.outcomes if o.name == "bad"]
    assert all("RuntimeError: boom" == o.error for o in bad)


def test_max_inflight_sheds_load_instead_of_hoarding_threads():
    """Against a stalled server a bounded run drops arrivals beyond the cap
    (recorded as dropped, not errors) instead of parking one thread per
    arrival; the requests that did fire still complete and report."""
    inflight = []
    lock = threading.Lock()
    gate = threading.Event()

    def post(specs, budget=None, priority=None, deadline_ms=None, name=None):
        with lock:
            inflight.append(threading.current_thread().name)
        gate.wait(5.0)          # stalled server: nothing completes
        return {}

    done = {}

    def run():
        done["report"] = OpenLoopGenerator(
            post, _mix_one(), ArrivalProcess(rate=40.0, seed=0), 1.0,
            max_inflight=3).run()

    runner = threading.Thread(target=run, daemon=True)
    runner.start()
    time.sleep(1.3)
    n_started = len(inflight)
    gate.set()
    runner.join(10.0)
    report = done["report"]
    assert n_started == 3                    # the cap really held
    assert report.offered > 10
    assert report.completed == 3
    assert report.dropped == report.offered - 3
    assert report.errors == 0                # drops are not server errors
    cls = report.classes["cls"]
    assert cls["dropped"] == report.dropped and cls["errors"] == 0
    assert cls["ok"] == 3
    # dropped outcomes are marked and excluded from latency percentiles
    dropped = [o for o in report.outcomes if o.error_kind == "dropped"]
    assert len(dropped) == report.dropped
    assert all(not o.ok and "dropped" in o.error for o in dropped)
    assert cls["p99_ms"] > 100.0             # percentiles: the 3 stalled oks


def test_max_inflight_unlimited_by_default_and_validated():
    with pytest.raises(ValueError, match="max_inflight"):
        OpenLoopGenerator(lambda s, **kw: {}, _mix_one(),
                          ArrivalProcess(rate=1.0), 1.0, max_inflight=0)
    # an uncontended cap never drops: semantics match the unbounded run
    def post(specs, budget=None, priority=None, deadline_ms=None, name=None):
        return {}

    report = OpenLoopGenerator(post, _mix_one(),
                               ArrivalProcess(rate=30.0, seed=0), 1.0,
                               max_inflight=64).run()
    assert report.dropped == 0
    assert report.completed == report.offered > 10


def test_generator_passes_class_envelope_to_post():
    seen = []

    def post(specs, budget=None, priority=None, deadline_ms=None, name=None):
        seen.append((specs, budget, priority, deadline_ms, name))
        return {}

    mix = SpecMix([SpecClass("c", [{"kind": "a"}], priority=0,
                             deadline_ms=150.0, budget=(5, 9))], seed=0)
    OpenLoopGenerator(post, mix, ArrivalProcess(rate=30.0, seed=0), 0.5).run()
    assert seen
    for specs, budget, priority, deadline_ms, name in seen:
        assert specs == [{"kind": "a"}]
        assert 5 <= budget <= 9
        assert priority == 0 and deadline_ms == 150.0 and name == "c"
