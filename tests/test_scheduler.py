"""SLO-aware scheduler tests: queue ordering (priority classes, EDF,
weighted shares, per-workload caps), grant-time coalescing semantics,
preemption at checkpoints with byte-identical oracle accounting, shutdown
shedding, scheduler/queue observability at /stats, the QuerySpec scheduling
fields' JSON roundtrip, and WorkloadRegistry close() under concurrency."""
import threading
import time

import pytest

from repro.core.engine import QueryEngine, QuerySpec
from repro.core.index import TastiIndex
from repro.core.schema import make_workload
from repro.core.session import QuerySession
from repro.serve import QueryClient, QueryScheduler, QueryServer, ScheduledTask
from repro.serve.registry import WorkloadEntry, WorkloadRegistry

pytestmark = pytest.mark.tier1


@pytest.fixture(scope="module")
def wl():
    return make_workload("night-street", n_frames=1200)


@pytest.fixture(scope="module")
def index(wl):
    return TastiIndex.build(wl.features, 120, wl.target_dnn_batch, k=4,
                            random_fraction=0.0, seed=0)


# -- unit-level scheduler harness ------------------------------------------
class _Sub:
    """Stands in for the server's _Submission (the scheduler only needs
    ``done``)."""

    def __init__(self):
        self.done = threading.Event()


def _mark_done(task):
    for sub in task.submissions:
        sub.done.set()


def _make(run_fn, **kw):
    """A scheduler whose run callback is the test's; failures recorded."""
    fails = []
    sched = QueryScheduler(
        load=lambda t: "entry",
        run=lambda t, e: (run_fn(t), _mark_done(t)),
        fail=lambda t, e, status: (fails.append((t, status)), _mark_done(t)),
        **kw)
    return sched, fails


def _wait_all(tasks, timeout=10.0):
    deadline = time.monotonic() + timeout
    for t in tasks:
        for sub in t.submissions:
            assert sub.done.wait(max(0.01, deadline - time.monotonic())), \
                "task never completed"


def _blocked_scheduler(order, release, **kw):
    """A 1-or-2-slot scheduler with a blocker task holding a slot until
    ``release`` is set; returns (sched, fails, blocker)."""
    running = threading.Event()

    def run(task):
        label = task.submissions[0].label
        if label == "blocker":
            running.set()
            assert release.wait(10.0)
        order.append(label)

    sched, fails = _make(run, **kw)
    blocker = _task("blocker")
    sched.submit(blocker)
    assert running.wait(5.0)
    return sched, fails, blocker


def _task(label, workload="w", priority=1, deadline=None, budget=None):
    sub = _Sub()
    sub.label = label
    return ScheduledTask(workload=workload, submissions=[sub],
                         priority=priority, deadline=deadline, budget=budget)


def test_priority_classes_order_grants():
    """With the only slot held, a later-arriving urgent task outruns an
    earlier relaxed one."""
    order, release = [], threading.Event()
    sched, fails, blocker = _blocked_scheduler(
        order, release, max_workers=1, preempt=False)
    low = _task("low", priority=2)
    high = _task("high", priority=0)
    sched.submit(low)
    time.sleep(0.05)  # low is waiting first (smaller seq)
    sched.submit(high)
    time.sleep(0.05)
    release.set()
    _wait_all([blocker, low, high])
    assert order == ["blocker", "high", "low"]
    assert not fails
    sched.shutdown()


def test_edf_orders_within_a_class():
    """Same class: the tighter deadline runs first, no-deadline runs last,
    regardless of arrival order."""
    order, release = [], threading.Event()
    sched, fails, blocker = _blocked_scheduler(
        order, release, max_workers=1, preempt=False)
    now = time.monotonic()
    none = _task("no-deadline")
    late = _task("late", deadline=now + 60.0)
    soon = _task("soon", deadline=now + 1.0)
    for t in (none, late, soon):
        sched.submit(t)
        time.sleep(0.02)
    release.set()
    _wait_all([blocker, none, late, soon])
    assert order == ["blocker", "soon", "late", "no-deadline"]
    assert not fails
    sched.shutdown()


def test_workload_cap_blocks_even_with_free_slots():
    """A capped workload leaves the second slot to another workload even
    when its own task arrived first."""
    order, release = [], threading.Event()
    sched, fails, blocker = _blocked_scheduler(
        order, release, max_workers=2, preempt=False, caps={"w": 1})
    capped = _task("capped", workload="w")      # w at cap: blocker holds it
    other = _task("other", workload="v")
    sched.submit(capped)
    time.sleep(0.05)
    sched.submit(other)
    _wait_all([other])                          # runs on the free slot
    assert order == ["other"]                   # capped still waiting
    release.set()
    _wait_all([blocker, capped])
    assert order == ["other", "blocker", "capped"]
    assert not fails
    sched.shutdown()


@pytest.mark.parametrize("shares,winner", [
    (None, "a2"),           # equal shares, equal underservice: seq decides
    ({"b": 8.0}, "b2"),     # b's weight makes it the underserved workload
])
def test_weighted_shares_pick_the_underserved_workload(shares, winner):
    """Workloads a, b, c each hold one of three slots while a2 and b2 wait;
    freeing c's slot grants it to the workload with the smaller
    active/share ratio."""
    order = []
    release_ab = threading.Event()
    release_c = threading.Event()
    running = {"a": threading.Event(), "b": threading.Event(),
               "c": threading.Event()}

    def run(task):
        label = task.submissions[0].label
        if label.startswith("blocker"):
            running[task.workload].set()
            gate = release_c if task.workload == "c" else release_ab
            assert gate.wait(10.0)
        order.append(label)

    sched, fails = _make(run, max_workers=3, preempt=False, shares=shares)
    blockers = [_task("blocker-a", workload="a"),
                _task("blocker-b", workload="b"),
                _task("blocker-c", workload="c")]
    for t in blockers:
        sched.submit(t)
    assert all(running[w].wait(5.0) for w in "abc")
    a2 = _task("a2", workload="a")
    b2 = _task("b2", workload="b")
    sched.submit(a2)            # a2 first by seq; both ratios are 1/share
    sched.submit(b2)
    time.sleep(0.1)
    assert order == []          # all slots held, both candidates queued
    release_c.set()             # one slot frees; scheduler picks the winner
    _wait_all([a2, b2])
    candidates = [x for x in order if not x.startswith("blocker")]
    assert candidates[0] == winner
    release_ab.set()
    _wait_all(blockers)
    assert not fails
    sched.shutdown()


def test_preemption_pauses_scan_at_checkpoint_and_resumes():
    """A running low-class task yields its slot at a checkpoint to a
    higher class, then finishes after it."""
    order = []
    high_done = threading.Event()
    sched_box = {}

    def run(task):
        label = task.submissions[0].label
        if label == "heavy":
            for _ in range(400):
                sched_box["sched"].checkpoint(task)
                if high_done.is_set():
                    break
                time.sleep(0.005)
        else:
            high_done.set()
        order.append(label)

    sched, fails = _make(run, max_workers=1, preempt=True)
    sched_box["sched"] = sched
    heavy = _task("heavy", priority=2)
    sched.submit(heavy)
    deadline = time.monotonic() + 5.0
    while not sched.stats["slices"] and time.monotonic() < deadline:
        time.sleep(0.01)  # heavy is mid-scan before the urgent arrival
    high = _task("high", priority=0)
    sched.submit(high)
    _wait_all([heavy, high])
    assert order == ["high", "heavy"]
    assert heavy.preemptions >= 1
    assert sched.stats["preemptions"] >= 1
    assert not fails
    sched.shutdown()


def test_admission_window_merges_only_unbudgeted_same_class():
    """window>0: unbudgeted same-class strangers share one run; a budgeted
    task and a different-class task never merge."""
    runs = []

    def run(task):
        runs.append([s.label for s in task.submissions])

    sched, fails = _make(run, max_workers=1, admission_window=0.15)
    tasks = [_task("u1"), _task("u2"),
             _task("budgeted", budget=50), _task("urgent", priority=0)]
    for t in tasks:
        sched.submit(t)
    _wait_all(tasks)
    merged = next(r for r in runs if "u1" in r)
    assert sorted(merged) == ["u1", "u2"]          # strangers merged...
    assert ["budgeted"] in runs                    # ...budgeted alone...
    assert ["urgent"] in runs                      # ...other class alone
    assert sched.stats["merged"] == 1
    assert not fails
    sched.shutdown()


def test_window_zero_never_merges():
    runs = []
    sched, fails = _make(
        lambda t: runs.append([s.label for s in t.submissions]),
        max_workers=1, admission_window=0.0)
    tasks = [_task("u1"), _task("u2"), _task("u3")]
    for t in tasks:
        sched.submit(t)
    _wait_all(tasks)
    assert sorted(map(tuple, runs)) == [("u1",), ("u2",), ("u3",)]
    assert sched.stats["merged"] == 0
    assert not fails
    sched.shutdown()


def test_shutdown_sheds_waiting_and_drains_running():
    """Waiting tasks fail fast with 503; the running task finishes."""
    order, release = [], threading.Event()
    sched, fails, blocker = _blocked_scheduler(
        order, release, max_workers=1, preempt=False)
    waiter = _task("waiter")
    sched.submit(waiter)
    time.sleep(0.05)
    shutdown_done = threading.Event()
    threading.Thread(
        target=lambda: (sched.shutdown(), shutdown_done.set()),
        daemon=True).start()
    time.sleep(0.1)
    release.set()
    assert shutdown_done.wait(10.0)
    _wait_all([blocker, waiter])
    assert order == ["blocker"]
    assert [status for _, status in fails] == [503]
    assert sched.stats["shed"] == 1


# -- engine-level slicing parity -------------------------------------------
def test_sliced_oracle_execution_is_byte_identical(wl, index):
    """checkpoint+slice_size chunks every fetch, yet ids, labels, and
    fresh/cached accounting match unsliced execution exactly."""
    specs = [QuerySpec(kind="aggregation", score="score_count", err=0.2),
             QuerySpec(kind="limit", score="score_has_object", k_results=4),
             QuerySpec(kind="selection", score="score_has_object",
                       budget=80)]
    plain_eng = QueryEngine(index, wl)
    plain = QuerySession(plain_eng, specs).execute()

    beats = []
    sliced_eng = QueryEngine(index, wl)
    sliced = QuerySession(sliced_eng, specs,
                          checkpoint=lambda: beats.append(1),
                          slice_size=7).execute()
    assert len(beats) > 0
    assert (plain_eng.broker.snapshot()["fresh"]
            == sliced_eng.broker.snapshot()["fresh"])
    for a, b in zip(plain.results, sliced.results):
        assert a.estimate == b.estimate
        assert a.n_invocations == b.n_invocations
        assert a.n_oracle_fresh == b.n_oracle_fresh
        assert a.n_oracle_cached == b.n_oracle_cached
        if a.selected is not None:
            assert list(a.selected) == list(b.selected)


# -- server integration ----------------------------------------------------
def test_spec_scheduling_fields_roundtrip_and_echo():
    spec = QuerySpec(kind="aggregation", score="score_count",
                     priority=0, deadline_ms=150.0)
    d = spec.to_dict()
    assert d["priority"] == 0 and d["deadline_ms"] == 150.0
    back = QuerySpec.from_dict(d)
    assert back.priority == 0 and back.deadline_ms == 150.0
    # unset fields stay off the wire (pre-scheduler payloads unchanged)
    assert "priority" not in QuerySpec(kind="aggregation",
                                       score="score_count").to_dict()


def test_server_schedules_by_priority_and_reports_queue_stats(wl, index):
    server = QueryServer(QueryEngine(index, wl), port=0,
                         admission_window=0.0, max_workers=1).start()
    try:
        client = QueryClient(server.url)
        client.wait_ready(30)
        out = client.query(
            [{"kind": "aggregation", "score": "score_count", "err": 0.2,
              "priority": 0, "deadline_ms": 200.0}])
        row = out["results"][0]
        assert row["priority"] == 0 and row["deadline_ms"] == 200.0
        assert out["session"]["priority"] == 0
        assert out["session"]["queue_wait_s"] >= 0.0
        assert out["session"]["preemptions"] == 0

        stats = client.stats()
        sched = stats["server"]["scheduler"]
        assert sched["granted"] >= 1 and sched["max_workers"] == 1
        queue = stats["workloads"][stats["server"]["default_workload"]][
            "queue"]
        assert queue["admitted"] >= 1
        assert queue["wait_mean_s"] >= 0.0
        assert queue["wait_max_s"] >= queue["wait_mean_s"] >= 0.0
        assert queue["depth"] == 0 and queue["active"] == 0

        with pytest.raises(Exception, match="priority"):
            client.query([{"kind": "aggregation", "score": "score_count"}],
                         priority=-1)
        with pytest.raises(Exception, match="deadline_ms"):
            client.query([{"kind": "aggregation", "score": "score_count"}],
                         deadline_ms=0)
    finally:
        server.shutdown()


def test_server_preempts_heavy_scan_with_accounting_parity(wl, index):
    """End-to-end: an urgent request overtakes a long limit scan on a
    1-worker server, and total accounting matches a serial replay."""
    class Sleepy:
        def __init__(self, inner):
            self._inner = inner

        def __getattr__(self, name):
            return getattr(self._inner, name)

        def target_dnn_batch(self, ids):
            time.sleep(0.004 + 0.0005 * len(ids))
            return self._inner.target_dnn_batch(ids)

    heavy_spec = {"kind": "limit", "score": "score_has_object", "batch": 32,
                  "k_results": 900, "max_invocations": 900, "priority": 2}
    urgent_spec = {"kind": "aggregation", "score": "score_count", "err": 0.2,
                   "priority": 0}
    server = QueryServer(QueryEngine(index, Sleepy(wl)), port=0,
                         admission_window=0.0, max_workers=1).start()
    try:
        client = QueryClient(server.url)
        client.wait_ready(30)
        warm = client.query([urgent_spec])      # urgent ids now cached
        done = {}

        def post_heavy():
            done["heavy"] = client.query([heavy_spec])

        t = threading.Thread(target=post_heavy, daemon=True)
        t.start()
        time.sleep(0.15)                        # scan reaches the worker
        t0 = time.monotonic()
        urgent = client.query([urgent_spec], priority=0)
        urgent_s = time.monotonic() - t0
        t.join(60)
        assert not t.is_alive()
        assert urgent["session"]["preemptions"] == 0
        stats = client.stats()
        assert stats["server"]["scheduler"]["preemptions"] >= 1
        # the urgent request did NOT wait out the whole scan
        assert urgent_s < 1.0
        served_fresh = stats["accounts"]["fresh_total"]
    finally:
        server.shutdown()

    # serial replay on a fresh engine: same three requests, no scheduler
    replay_eng = QueryEngine(index, wl)
    replay_fresh = 0
    for specs in ([urgent_spec], [heavy_spec], [urgent_spec]):
        out = QuerySession(replay_eng,
                           [QuerySpec.from_dict(dict(s)) for s in specs]
                           ).execute()
        replay_fresh += out.stats["fresh_total"]
    assert served_fresh == replay_fresh
    # the warm request itself paid fresh labels exactly once
    assert warm["request"]["fresh"] > 0


# -- registry close() ------------------------------------------------------
def test_registry_close_is_idempotent(wl, index):
    registry = WorkloadRegistry()
    registry.register("video", QueryEngine(index, wl))
    assert registry.get("video").loaded
    registry.close()
    registry.close()                        # second close: clean no-op
    # a closed engine still answers (its broker labels inline)
    res = registry.get("video").engine.execute(
        QuerySpec(kind="aggregation", score="score_count", err=0.2))
    assert res.estimate is not None


def test_registry_close_during_lazy_load_neither_deadlocks_nor_breaks():
    """close() racing an in-flight lazy load returns promptly (the load is
    skipped, not awaited) and the load itself still completes."""
    entry = WorkloadEntry("slow")
    release = threading.Event()
    loaded = threading.Event()

    def slow_load():
        release.wait(10.0)
        entry.engine = "engine"             # sentinel: load published
        loaded.set()

    entry._load = slow_load
    registry = WorkloadRegistry()
    registry._add(entry)

    loader = threading.Thread(target=entry.ensure_loaded, daemon=True)
    loader.start()
    time.sleep(0.05)                        # loader holds the entry lock
    t0 = time.monotonic()
    registry.close()                        # must not block on the load
    assert time.monotonic() - t0 < 5.0
    assert not loaded.is_set()              # close did not wait it out
    release.set()
    loader.join(5.0)
    assert loaded.is_set() and entry.loaded
