"""Observability subsystem tests: metrics registry round-trip (render ->
parse), tracer span nesting + the bounded flight recorder, Chrome trace
export, disabled-path no-ops, loadgen error-kind classification and trace
stamping, and the end-to-end attribution guarantee over a live server —
every fresh oracle label of a traced request lands in exactly one span
chain, and the ``/metrics`` exposition agrees with the request's own
accounting."""
import threading
import time

import pytest

from repro.core.engine import QueryEngine, QuerySpec
from repro.core.index import TastiIndex
from repro.core.schema import make_workload
from repro.core.session import QuerySession
from repro.loadgen import ArrivalProcess, OpenLoopGenerator, SpecClass, SpecMix
from repro.loadgen.generator import _accepts_kwarg, _classify_error
from repro.obs import (
    NULL_SPAN,
    NULL_TRACE,
    MetricsRegistry,
    Observability,
    Sample,
    activate,
    active_trace,
    chrome_trace,
    parse_prometheus_text,
    series_key,
    span,
    start_span,
)
from repro.obs.trace import FlightRecorder, Trace, Tracer
from repro.serve import (
    QueryClient,
    QueryServer,
    WorkloadRegistry,
    WorkloadSpec,
)
from repro.serve.client import ServerError

pytestmark = pytest.mark.tier1


@pytest.fixture(scope="module")
def wl():
    return make_workload("night-street", n_frames=1200)


@pytest.fixture(scope="module")
def index(wl):
    return TastiIndex.build(wl.features, 120, wl.target_dnn_batch, k=4,
                            random_fraction=0.0, seed=0)


SPEC_DICTS = [
    {"kind": "aggregation", "score": "score_count", "err": 0.2, "seed": 0},
    {"kind": "selection", "score": "score_has_object", "budget": 80,
     "seed": 0},
    {"kind": "limit", "score": "score_has_object", "k_results": 3},
]


# -- metrics registry ------------------------------------------------------
def test_metrics_render_parse_roundtrip():
    reg = MetricsRegistry()
    reg.counter("oracle_fresh_total", help="fresh labels",
                workload="video").inc(7)
    reg.counter("oracle_fresh_total", workload="text").inc(3)
    reg.gauge("queue_depth", workload="video").set(5)
    h = reg.histogram("flush_seconds", buckets=(0.1, 1.0), workload="video")
    for v in (0.05, 0.5, 2.0):
        h.observe(v)
    text = reg.render()
    parsed = parse_prometheus_text(text)
    assert parsed[series_key("oracle_fresh_total", workload="video")] == 7
    assert parsed[series_key("oracle_fresh_total", workload="text")] == 3
    assert parsed[series_key("queue_depth", workload="video")] == 5
    # histogram: cumulative buckets, +Inf == count, sum preserved
    assert parsed[series_key("flush_seconds_bucket", workload="video",
                             le="0.1")] == 1
    assert parsed[series_key("flush_seconds_bucket", workload="video",
                             le="1")] == 2
    assert parsed[series_key("flush_seconds_bucket", workload="video",
                             le="+Inf")] == 3
    assert parsed[series_key("flush_seconds_count", workload="video")] == 3
    assert parsed[series_key("flush_seconds_sum",
                             workload="video")] == pytest.approx(2.55)
    # HELP/TYPE lines are present for the exposition to be well-formed
    assert "# TYPE oracle_fresh_total counter" in text
    assert "# TYPE flush_seconds histogram" in text


def test_metric_name_cannot_change_type():
    reg = MetricsRegistry()
    reg.counter("requests_total")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("requests_total")


def test_collectors_emit_samples_and_isolate_failures():
    reg = MetricsRegistry()
    reg.add_collector(lambda: [
        Sample("derived_total", 42, labels={"workload": "v"}, help="derived"),
        Sample("derived_depth", 3, mtype="gauge"),
    ])
    reg.add_collector(lambda: (_ for _ in ()).throw(RuntimeError("boom")))
    parsed = parse_prometheus_text(reg.render())
    assert parsed[series_key("derived_total", workload="v")] == 42
    assert parsed["derived_depth"] == 3
    # one broken snapshot is counted, not fatal to the whole exposition
    assert parsed["metrics_collector_errors_total"] == 1


# -- tracing ---------------------------------------------------------------
def test_span_nesting_follows_the_activation_stack():
    trace = Trace("request", trace_id="a" * 16)
    with activate(trace):
        assert active_trace() is trace
        with span("outer") as outer:
            with span("inner", n=2) as inner:
                assert inner.parent_id == outer.span_id
            timed = trace.find_spans  # keep a handle before deactivation
            loose = start_span("loose")   # manual-end span, same parent
            assert loose.parent_id == outer.span_id
            loose.end()
        after = span("sibling")
        assert after.parent_id == 0       # back under the root
        after.end()
    assert active_trace() is None
    trace.finish()
    assert inner.attrs["n"] == 2
    assert all(s.t1 is not None for s in timed("inner"))


def test_trace_finish_clamps_leaked_spans_and_is_idempotent():
    trace = Trace("request")
    with activate(trace):
        leaked = start_span("never.ended")
    trace.finish()
    assert leaked.t1 is not None
    t1 = trace.t1
    trace.finish()
    assert trace.t1 == t1                 # second finish is a no-op


def test_flight_recorder_is_a_bounded_ring():
    rec = FlightRecorder(capacity=4)
    tracer = Tracer(rec)
    ids = []
    for _ in range(10):
        t = tracer.start("request")
        ids.append(t.trace_id)
        tracer.finish(t)
    assert len(rec) == 4
    assert rec.recorded == 10
    kept = [t.trace_id for t in rec.traces()]
    assert kept == ids[-4:]               # oldest dropped, order preserved
    assert rec.find(ids[0]) is None
    assert rec.find(ids[-1]).trace_id == ids[-1]
    assert [s["trace_id"] for s in rec.summaries()] == kept


def test_chrome_trace_export_shape():
    trace = Trace("request", trace_id="b" * 16, workload="video")
    with activate(trace):
        with span("session.execute", fresh=5):
            time.sleep(0.001)
    trace.finish()
    doc = chrome_trace(trace)
    assert doc["otherData"]["trace_id"] == "b" * 16
    assert doc["otherData"]["attr_workload"] == "video"
    events = doc["traceEvents"]
    assert len(events) == 2               # root + session.execute
    for ev in events:
        assert ev["ph"] == "X"
        assert ev["ts"] >= 0 and ev["dur"] >= 0
        assert "span_id" in ev["args"] and "parent_id" in ev["args"]
    exe = next(ev for ev in events if ev["name"] == "session.execute")
    assert exe["args"]["fresh"] == 5


def test_disabled_observability_is_all_noops():
    obs = Observability(enabled=False)
    t = obs.tracer.start("request", trace_id="c" * 16)
    assert t is NULL_TRACE and t.trace_id == ""
    assert t.new_span("x") is NULL_SPAN
    obs.tracer.finish(t)                  # records nothing
    assert obs.recorder is None
    obs.counter("n_total").inc()
    obs.histogram("h").observe(1.0)
    assert obs.metrics.render() == "# observability disabled\n"
    # activating the null trace leaves the thread trace-free
    with activate(t):
        assert active_trace() is None
        assert span("anything") is NULL_SPAN


def test_scoped_labels_fold_into_instruments():
    obs = Observability()
    scope = obs.scoped(workload="video")
    scope.counter("oracle_fresh_total").inc(4)
    scope.scoped(replica=1).counter("subbatches_total").inc()
    parsed = parse_prometheus_text(obs.metrics.render())
    assert parsed[series_key("oracle_fresh_total", workload="video")] == 4
    assert parsed[series_key("subbatches_total", workload="video",
                             replica=1)] == 1


# -- loadgen error kinds + trace stamping ----------------------------------
def test_error_kind_classification():
    assert _classify_error(ServerError("bad spec", status=400)) == "http_4xx"
    assert _classify_error(ServerError("shedding", status=503)) == "http_5xx"
    assert _classify_error(ConnectionRefusedError("refused")) == "connect"
    assert _classify_error(TimeoutError("slow")) == "connect"
    assert _classify_error(RuntimeError("?")) == "other"

    # an HTTP-status-carrying error subclassing OSError is a server answer
    class StatusOSError(OSError):
        status = 502
    assert _classify_error(StatusOSError()) == "http_5xx"


def test_loadgen_counts_error_kinds_and_stamps_trace_ids():
    lock = threading.Lock()
    seen = []

    def post(specs, budget=None, priority=None, deadline_ms=None,
             name=None, trace_id=None):
        with lock:
            seen.append(trace_id)
            i = len(seen)
        if i % 3 == 1:
            raise ServerError("overloaded", status=503)
        if i % 3 == 2:
            raise ConnectionRefusedError("refused")
        return {"ok": True}

    assert _accepts_kwarg(post, "trace_id")
    mix = SpecMix([SpecClass("c", SPEC_DICTS[:1])], seed=0)
    gen = OpenLoopGenerator(post, mix, ArrivalProcess(rate=150.0, seed=0),
                            duration_s=0.3)
    report = gen.run()
    assert report.offered == len(seen) > 5
    by_kind = {k: sum(o.error_kind == k for o in report.outcomes)
               for k in ("connect", "http_4xx", "http_5xx", "other")}
    assert report.http_errors == by_kind["http_4xx"] + by_kind["http_5xx"] > 0
    assert report.connect_errors == by_kind["connect"] > 0
    assert report.errors == report.offered - report.completed
    assert (report.errors
            == report.connect_errors + report.http_errors + by_kind["other"])
    row = report.classes["c"]
    assert row["connect_errors"] == report.connect_errors
    assert row["http_errors"] == report.http_errors
    # every fired request got a fresh 16-hex trace id
    tids = [o.trace_id for o in report.outcomes]
    assert all(t and len(t) == 16 for t in tids)
    assert len(set(tids)) == len(tids)
    assert sorted(t for t in seen if t) == sorted(tids)


def test_loadgen_skips_trace_ids_for_legacy_post_callables():
    def post(specs, budget=None, priority=None, deadline_ms=None, name=None):
        return {"ok": True}

    assert not _accepts_kwarg(post, "trace_id")
    mix = SpecMix([SpecClass("c", SPEC_DICTS[:1])], seed=0)
    report = OpenLoopGenerator(post, mix, ArrivalProcess(rate=100.0, seed=1),
                               duration_s=0.2).run()
    assert report.completed == report.offered > 0
    assert all(o.trace_id is None for o in report.outcomes)


# -- broker observe(): totals + accounts in one lock pass ------------------
def test_broker_observe_is_consistent_under_concurrent_flush(wl, index):
    engine = QueryEngine(index, wl)
    stop = threading.Event()
    snaps = []

    def scrape():
        while not stop.is_set():
            snaps.append(engine.broker.observe(recent_accounts=0))

    scraper = threading.Thread(target=scrape, daemon=True)
    scraper.start()
    threads = [threading.Thread(
        target=lambda s: QuerySession(
            engine, [QuerySpec.from_dict(dict(s))]).execute(),
        args=(s,), daemon=True) for s in SPEC_DICTS for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    scraper.join(timeout=10)
    snaps.append(engine.broker.observe(recent_accounts=0))
    assert len(snaps) >= 2
    prev_fresh = -1
    for snap in snaps:
        stats, accounts = snap["stats"], snap["accounts"]
        # an account increment is only ever visible together with the total
        # increment it belongs to (both land in one lock hold)
        assert sum(a["fresh"] for a in accounts) <= stats["fresh"]
        assert sum(a["cached"] for a in accounts) <= stats["cached"]
        assert stats["fresh"] >= prev_fresh
        prev_fresh = stats["fresh"]
    # quiescent: every fresh label is attributed to some account
    final = snaps[-1]
    assert sum(a["fresh"] for a in final["accounts"]) \
        == final["stats"]["fresh"] > 0


# -- live server: end-to-end attribution -----------------------------------
def test_traced_request_attributes_every_fresh_label(wl, index):
    """The acceptance invariant: with a replicated oracle pool, a traced
    request's fresh count, the sum over its ``broker.flush`` spans, the sum
    over its ``oracle.subbatch`` spans, and the scraped
    ``oracle_fresh_total`` delta are all the same number — and every span
    chains back to the request root."""
    engine = QueryEngine(index, wl, oracle_replicas=2)
    server = QueryServer(engine, port=0, admission_window=0.0,
                         max_workers=2).start()
    try:
        client = QueryClient(server.url)
        client.wait_ready(30)
        before = parse_prometheus_text(client.metrics())
        tid = "feedfacecafe0001"
        out = client.query(SPEC_DICTS, trace_id=tid)
        req = out["request"]
        assert req["trace_id"] == tid
        fresh = req["fresh"]
        assert fresh > 0

        doc = client.traces(trace_id=tid)
        assert doc["trace_id"] == tid
        spans = doc["spans"]
        by_id = {s["span_id"]: s for s in spans}
        names = {s["name"] for s in spans}
        assert {"request", "sched.queue", "session.plan",
                "session.execute", "broker.flush",
                "oracle.subbatch"} <= names
        # every span reaches the root through its parents: one chain each
        for s in spans:
            hops, cur = 0, s
            while cur["span_id"] != 0:
                cur = by_id[cur["parent_id"]]
                hops += 1
                assert hops < len(spans)
        flushes = [s for s in spans if s["name"] == "broker.flush"]
        flush_fresh = sum(s["attrs"].get("fresh", 0) for s in flushes)
        subs = [s for s in spans if s["name"] == "oracle.subbatch"]
        assert all(by_id[s["parent_id"]]["name"] == "broker.flush"
                   for s in subs)
        sub_n = sum(s["attrs"]["n"] for s in subs)
        # which replica served each sub-batch is load-dependent (work
        # stealing); that it's recorded and valid is the invariant
        assert {s["attrs"]["replica"] for s in subs} <= {0, 1}
        assert flush_fresh == sub_n == fresh

        after = parse_prometheus_text(client.metrics())
        key = series_key("oracle_fresh_total", workload=req["workload"])
        assert after[key] - before.get(key, 0.0) == fresh
        lat = series_key("request_latency_seconds_count",
                         workload=req["workload"])
        assert after[lat] - before.get(lat, 0.0) == 1
        assert after.get(series_key("sched_grants_total",
                                    reason="first"), 0) >= 1

        # flight-recorder listing + chrome export + 404 on unknown id
        listing = client.traces()
        assert listing["recorded"] >= 1
        assert any(s["trace_id"] == tid for s in listing["traces"])
        cdoc = client.traces(trace_id=tid, fmt="chrome")
        assert cdoc["otherData"]["trace_id"] == tid
        assert len(cdoc["traceEvents"]) == len(spans)
        with pytest.raises(ServerError) as ei:
            client.traces(trace_id="0" * 16)
        assert ei.value.status == 404
    finally:
        server.shutdown()


def test_server_with_observability_disabled_still_serves(wl, index):
    server = QueryServer(QueryEngine(index, wl), port=0,
                         admission_window=0.0, max_workers=2,
                         obs=False).start()
    try:
        client = QueryClient(server.url)
        client.wait_ready(30)
        out = client.query(SPEC_DICTS)
        assert out["request"]["fresh"] > 0
        assert out["request"]["trace_id"] is None
        assert client.metrics() == "# observability disabled\n"
        with pytest.raises(ServerError) as ei:
            client.traces()
        assert ei.value.status == 404
        stats = client.stats()
        assert stats["server"]["observability"]["enabled"] is False
    finally:
        server.shutdown()


# -- introspection never triggers or waits on a lazy load ------------------
def test_scrapes_respond_while_a_lazy_load_is_in_flight(wl, index):
    """/healthz, /workloads, /metrics and /stats must answer while a
    workload's first-load is blocked mid-build — and must not themselves
    trigger the load."""
    registry = WorkloadRegistry()
    entry = registry.declare(WorkloadSpec(name="lazy", dataset="night-street",
                                          n_records=1200))
    started, gate = threading.Event(), threading.Event()

    def slow_load():
        started.set()
        assert gate.wait(timeout=30)
        entry.store = None
        entry.engine = QueryEngine(index, wl)
    entry._load = slow_load

    server = QueryServer(registry, port=0, admission_window=0.0,
                         max_workers=2).start()
    try:
        client = QueryClient(server.url)
        client.wait_ready(30)
        # scraping an unloaded mount is free: no load started
        assert client.healthy()
        assert not started.is_set()

        result = {}

        def post():
            result["out"] = client.query(SPEC_DICTS[:1], workload="lazy")
        poster = threading.Thread(target=post, daemon=True)
        poster.start()
        assert started.wait(timeout=30)

        t0 = time.monotonic()
        health = client._call("/healthz")
        wls = client.workloads()
        metrics = parse_prometheus_text(client.metrics())
        stats = client.stats()
        elapsed = time.monotonic() - t0
        assert elapsed < 10.0  # answered while the load was still blocked
        assert not gate.is_set()
        assert health["ok"] is True
        assert health["workloads"]["lazy"]["loaded"] is False
        (row,) = [w for w in wls["workloads"] if w["name"] == "lazy"]
        assert row["loaded"] is False
        # the collector skipped the unloaded entry instead of loading it
        assert series_key("oracle_fresh_total", workload="lazy") not in metrics
        assert stats["workloads"]["lazy"]["loaded"] is False

        gate.set()
        poster.join(timeout=60)
        assert result["out"]["request"]["fresh"] > 0
        assert client._call("/healthz")["workloads"]["lazy"]["loaded"] is True
    finally:
        server.shutdown()
