"""TASTI index unit tests: FPF 2-approximation, top-k caching, cracking."""
import numpy as np
import pytest

from repro.core.fpf import fpf_select, max_intra_cluster_dist
from repro.core.index import TastiIndex
from repro.core.propagation import (propagate_categorical, propagate_numeric,
                                    propagate_top1)


def _embs(n=400, d=16, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 5, size=(8, d))
    asg = rng.integers(0, 8, size=n)
    return (centers[asg] + rng.normal(0, 0.5, size=(n, d))).astype(np.float32)


def test_fpf_2_approximation():
    x = _embs()
    k = 8
    reps = fpf_select(x, k, random_fraction=0.0, seed=0)
    got = max_intra_cluster_dist(x, reps)
    # brute-force optimum over many random k-subsets as a lower-bound probe
    rng = np.random.default_rng(1)
    best = np.inf
    for _ in range(300):
        cand = rng.choice(len(x), size=k, replace=False)
        d = np.sqrt((((x[:, None] - x[cand][None]) ** 2).sum(-1)).min(1)).max()
        best = min(best, d)
    assert got <= 2.0 * best + 1e-5


def test_fpf_covers_all_clusters():
    x = _embs()
    reps = fpf_select(x, 16, random_fraction=0.0, seed=3)
    assert len(np.unique(reps)) == 16
    # FPF with 16 points over 8 well-separated clusters must hit every cluster
    rng = np.random.default_rng(0)
    centers = rng.normal(0, 5, size=(8, 16))
    asg_reps = ((x[reps][:, None] - centers[None]) ** 2).sum(-1).argmin(1)
    assert len(np.unique(asg_reps)) == 8


def _build_index(x, n_reps=32, k=4):
    def annotate(ids):
        return [float(i) for i in ids]  # annotation = record id (traceable)
    return TastiIndex.build(x, n_reps, annotate, k=k, random_fraction=0.0)


def test_index_topk_matches_bruteforce():
    x = _embs(200, 8)
    idx = _build_index(x, n_reps=16, k=4)
    d_full = ((x[:, None] - x[idx.rep_ids][None]) ** 2).sum(-1)
    np.testing.assert_allclose(np.sort(d_full, 1)[:, :4], idx.topk_d2,
                               rtol=1e-4, atol=1e-4)


def test_crack_equals_full_rebuild():
    x = _embs(300, 8)
    idx = _build_index(x, n_reps=16, k=4)
    # pick new ids disjoint from the existing representatives
    pool = np.setdiff1d(np.arange(len(x)), idx.rep_ids)
    new_ids = pool[[3, 77, 150, 250]]
    idx.crack(new_ids, [float(i) for i in new_ids])

    def annotate(ids):
        return [float(i) for i in ids]
    all_reps = np.concatenate([_build_index(x, 16, 4).rep_ids, new_ids])
    d_full = ((x[:, None] - x[all_reps][None]) ** 2).sum(-1)
    np.testing.assert_allclose(np.sort(d_full, 1)[:, :4], idx.topk_d2,
                               rtol=1e-4, atol=1e-4)
    assert idx.n_reps == 20


def test_crack_dedupes_existing_reps():
    x = _embs(100, 8)
    idx = _build_index(x, n_reps=8, k=2)
    before = idx.n_reps
    idx.crack(idx.rep_ids[:3], [0.0, 0.0, 0.0])
    assert idx.n_reps == before


def test_propagation_modes():
    rep_scores = np.array([0.0, 1.0, 2.0, 3.0])
    topk_ids = np.array([[0, 1], [2, 3]])
    topk_d2 = np.array([[0.01, 1.0], [0.25, 0.25]])
    num = propagate_numeric(rep_scores, topk_ids, topk_d2)
    assert 0.0 < num[0] < 0.5        # heavily weighted to rep 0
    assert num[1] == pytest.approx(2.5)
    cat = propagate_categorical(rep_scores.astype(int), topk_ids, topk_d2, 4)
    assert cat[0] == 0
    top1 = propagate_top1(rep_scores, topk_ids, topk_d2)
    assert top1[1] > top1[0]


def test_index_save_load_roundtrip(tmp_path):
    x = _embs(100, 8)
    idx = _build_index(x, n_reps=8, k=2)
    idx.save(str(tmp_path / "idx"))
    # versioned JSON + npz only — no pickle on disk
    assert (tmp_path / "idx.meta.json").exists()
    assert not (tmp_path / "idx.ann.pkl").exists()
    idx2 = TastiIndex.load(str(tmp_path / "idx"))
    np.testing.assert_array_equal(idx.topk_ids, idx2.topk_ids)
    np.testing.assert_allclose(idx.topk_d2, idx2.topk_d2)
    assert idx2.annotations == idx.annotations


def test_save_load_query_roundtrip_with_schema_annotations(tmp_path):
    """Real annotations (Scene records) survive the JSON format, and the
    reloaded index answers queries identically."""
    from repro.core.engine import QueryEngine, QuerySpec
    from repro.core.schema import make_workload

    wl = make_workload("night-street", n_frames=600)
    idx = TastiIndex.build(wl.features, 60, wl.target_dnn_batch, k=4,
                           random_fraction=0.0, seed=0)
    idx.crack([0, 1], wl.target_dnn_batch([0, 1]))  # non-zero version
    idx.save(str(tmp_path / "ns"))
    idx2 = TastiIndex.load(str(tmp_path / "ns"))
    assert idx2.version == idx.version
    assert idx2.cost.target_invocations == idx.cost.target_invocations
    for a, b in zip(idx.annotations, idx2.annotations):
        np.testing.assert_allclose(a.boxes, b.boxes)
    r1 = QueryEngine(idx, wl).execute(
        QuerySpec(kind="aggregation", score="score_count", err=0.1, seed=0))
    r2 = QueryEngine(idx2, wl).execute(
        QuerySpec(kind="aggregation", score="score_count", err=0.1, seed=0))
    assert r1.estimate == pytest.approx(r2.estimate)
    assert r1.n_invocations == r2.n_invocations


def test_load_legacy_pickle_raises_migration_error(tmp_path):
    """The one-release .ann.pkl read fallback is gone: loading a legacy
    pickle index fails with a clear migration error, not a pickle.load."""
    import dataclasses
    import pickle

    x = _embs(100, 8)
    idx = _build_index(x, n_reps=8, k=2)
    stem = tmp_path / "old"
    np.savez(stem.with_suffix(".npz"), embeddings=idx.embeddings,
             rep_ids=idx.rep_ids, topk_d2=idx.topk_d2,
             topk_ids=idx.topk_ids, k=np.int64(idx.k))
    with open(stem.with_suffix(".ann.pkl"), "wb") as f:
        pickle.dump({"annotations": idx.annotations,
                     "cost": dataclasses.asdict(idx.cost)}, f)
    with pytest.raises(ValueError, match="legacy pickle.*re-save"):
        TastiIndex.load(str(stem))
    # a bare stem with neither format still reports file-not-found
    with pytest.raises(FileNotFoundError):
        TastiIndex.load(str(tmp_path / "nothing-here"))


def test_save_is_atomic_no_temp_litter(tmp_path):
    """save() writes temp files then renames: after a save the directory
    holds exactly the two artifacts, and a failing save (an annotation that
    cannot be encoded) touches no file at all — encoding happens first."""
    x = _embs(80, 8)
    idx = _build_index(x, n_reps=8, k=2)
    stem = tmp_path / "atomic"
    idx.save(str(stem))
    names = sorted(f.name for f in tmp_path.iterdir())
    assert names == ["atomic.meta.json", "atomic.npz"]

    bad = _build_index(x, n_reps=8, k=2)
    bad.annotations[0] = object()  # not JSON-encodable -> save raises
    with pytest.raises(TypeError):
        bad.save(str(tmp_path / "torn"))
    assert not (tmp_path / "torn.meta.json").exists()
    assert not (tmp_path / "torn.npz").exists()
    assert not list(tmp_path.glob("*.tmp"))
    # the earlier good artifacts are untouched
    TastiIndex.load(str(stem))


def test_load_detects_mixed_generation_pair(tmp_path):
    """The npz and meta.json are each atomic but not one transaction: a
    crash between the two renames leaves mixed generations, which load()
    must detect via the annotations/rep_ids length cross-check."""
    x = _embs(120, 8)
    idx = _build_index(x, n_reps=8, k=2)
    stem = tmp_path / "idx"
    idx.save(str(stem))
    old_meta = stem.with_suffix(".meta.json").read_bytes()
    pool = np.setdiff1d(np.arange(len(x)), idx.rep_ids)
    idx.crack(pool[:3], [float(i) for i in pool[:3]])
    idx.save(str(stem))  # new generation: 11 reps
    stem.with_suffix(".meta.json").write_bytes(old_meta)  # simulate the crash
    with pytest.raises(ValueError, match="torn"):
        TastiIndex.load(str(stem))


def test_crack_bumps_version_only_on_mutation():
    x = _embs(200, 8)
    idx = _build_index(x, n_reps=16, k=4)
    assert idx.version == 0
    pool = np.setdiff1d(np.arange(len(x)), idx.rep_ids)
    idx.crack(pool[:3], [float(i) for i in pool[:3]])
    assert idx.version == 1
    # cracking with only existing reps is a no-op: no version bump
    idx.crack(idx.rep_ids[:2], [0.0, 0.0])
    assert idx.version == 1
