"""OracleBroker unit tests: microbatching, flush-on-demand, in-flight and
cache dedup, prefetch credits, and exact per-account fresh/cached accounting."""
import numpy as np
import pytest

from repro.core.broker import OracleBroker

pytestmark = pytest.mark.tier1


class SpyOracle:
    """annotate(ids) -> [2*i]; records every batch it was handed."""

    def __init__(self):
        self.batches = []

    def __call__(self, ids):
        ids = np.asarray(ids, np.int64)
        self.batches.append(ids.tolist())
        return [int(i) * 2 for i in ids]


def test_microbatching_and_flush():
    spy = SpyOracle()
    broker = OracleBroker(spy, max_batch=10)
    fut = broker.request(np.arange(25))
    assert not fut.done() and broker.n_pending == 25
    assert broker.flush() == 25
    assert [len(b) for b in spy.batches] == [10, 10, 5]
    assert broker.stats["fresh"] == 25 and broker.stats["batches"] == 3
    assert fut.done()
    assert fut.result() == [2 * i for i in range(25)]


def test_flush_on_demand_via_future():
    spy = SpyOracle()
    broker = OracleBroker(spy, max_batch=8)
    fut = broker.request([3, 1, 2])
    assert fut.result() == [6, 2, 4]  # result() drains the queue
    assert spy.batches == [[3, 1, 2]]


def test_cache_dedup_across_fetches():
    spy = SpyOracle()
    broker = OracleBroker(spy, max_batch=64)
    a = broker.account("a")
    b = broker.account("b")
    broker.fetch(np.arange(10), account=a)
    assert (a.fresh, a.cached) == (10, 0)
    broker.fetch(np.arange(10), account=b)  # all served from cache
    assert (b.fresh, b.cached) == (0, 10)
    assert sum(len(x) for x in spy.batches) == 10
    assert sorted(a.labeled) == list(range(10)) and b.labeled == []


def test_inflight_dedup_charges_first_requester():
    spy = SpyOracle()
    broker = OracleBroker(spy, max_batch=64)
    a = broker.account("a")
    b = broker.account("b")
    fa = broker.request([1, 2, 3], account=a)
    fb = broker.request([2, 3, 4], account=b)  # 2,3 ride a's in-flight ids
    broker.flush()
    assert (a.fresh, a.cached) == (3, 0)
    assert (b.fresh, b.cached) == (1, 2)
    assert broker.stats["dedup_inflight"] == 2
    assert sum(len(x) for x in spy.batches) == 4  # 2,3 labeled once
    assert fa.result() == [2, 4, 6] and fb.result() == [4, 6, 8]


def test_duplicates_within_one_request_count_cached():
    broker = OracleBroker(SpyOracle(), max_batch=64)
    a = broker.account("a")
    out = broker.fetch([5, 5, 5], account=a)
    assert out == [10, 10, 10]
    assert (a.fresh, a.cached) == (1, 2)


def test_reuse_false_bypasses_cache_reads():
    spy = SpyOracle()
    broker = OracleBroker(spy, max_batch=4)
    a = broker.account("a")
    broker.fetch([1, 2, 3], account=a)
    b = broker.account("b")
    broker.fetch([1, 2, 3], account=b, reuse=False)  # re-labels everything
    assert (b.fresh, b.cached) == (3, 0)
    assert sum(len(x) for x in spy.batches) == 6
    # ...but its labels still land in the shared cache for later consumers
    c = broker.account("c")
    broker.fetch([1, 2, 3], account=c)
    assert (c.fresh, c.cached) == (0, 3)


def test_reuse_false_microbatches_too():
    spy = SpyOracle()
    broker = OracleBroker(spy, max_batch=4)
    broker.fetch(np.arange(11), reuse=False)
    assert [len(b) for b in spy.batches] == [4, 4, 3]


def test_prefetch_credit_consumed_once():
    spy = SpyOracle()
    broker = OracleBroker(spy, max_batch=64)
    a = broker.account("a")
    assert broker.prefetch([7, 8, 9], account=a) == 3
    broker.flush()
    assert (a.fresh, a.cached) == (3, 0)
    # the demand read consumes the prefetch credit: no double charge
    broker.fetch([7, 8, 9], account=a)
    assert (a.fresh, a.cached) == (3, 0)
    # later re-reads are ordinary cache hits again
    broker.fetch([7], account=a)
    assert (a.fresh, a.cached) == (3, 1)


def test_prefetch_skips_cached_and_inflight_ids():
    broker = OracleBroker(SpyOracle(), max_batch=64)
    a = broker.account("a")
    b = broker.account("b")
    broker.fetch([1], account=a)
    broker.request([2], account=a)
    assert broker.prefetch([1, 2, 3], account=b) == 1  # only 3 is new
    broker.flush()
    assert (b.fresh, b.cached) == (1, 0)


def test_fresh_plus_cached_equals_requests_per_account():
    rng = np.random.default_rng(0)
    broker = OracleBroker(SpyOracle(), max_batch=7)
    total = 0
    accounts = [broker.account(str(i)) for i in range(3)]
    for t in range(12):
        acct = accounts[t % 3]
        ids = rng.integers(0, 40, size=rng.integers(1, 20))
        total += len(ids)
        broker.fetch(ids, account=acct)
    assert sum(a.fresh + a.cached for a in accounts) == total
    assert broker.stats["fresh"] + broker.stats["cached"] == total
    # fresh ids were each labeled exactly once
    assert broker.stats["fresh"] == len(set().union(
        *[set(a.labeled) for a in accounts]))


def test_invalid_max_batch():
    with pytest.raises(ValueError, match="max_batch"):
        OracleBroker(SpyOracle(), max_batch=0)
