"""Query-serving subsystem tests: persistent LabelStore (round-trip,
invalidation, write-through), concurrent-session parity over one thread-safe
broker, the HTTP QueryServer end to end (admission-window coalescing,
/stats accounting, warm repeat requests costing zero fresh labels), and
multi-workload routing (registry mounts, per-workload admission lanes and
accounting parity vs isolated servers, manifest lazy-load + warm restart)."""
import json
import threading

import numpy as np
import pytest

from repro.core.engine import QueryEngine, QuerySpec
from repro.core.index import TastiIndex
from repro.core.schema import make_workload
from repro.core.session import QuerySession
from repro.serve import (
    LabelStore,
    QueryClient,
    QueryServer,
    WorkloadRegistry,
    WorkloadSpec,
)

pytestmark = pytest.mark.tier1


@pytest.fixture(scope="module")
def wl():
    return make_workload("night-street", n_frames=1200)


@pytest.fixture(scope="module")
def index(wl):
    return TastiIndex.build(wl.features, 120, wl.target_dnn_batch, k=4,
                            random_fraction=0.0, seed=0)


@pytest.fixture(scope="module")
def wl_text():
    return make_workload("wikisql", n_records=900)


@pytest.fixture(scope="module")
def index_text(wl_text):
    return TastiIndex.build(wl_text.features, 90, wl_text.target_dnn_batch,
                            k=4, random_fraction=0.0, seed=0)


SPECS = [QuerySpec(kind="aggregation", score="score_count", err=0.2, seed=0),
         QuerySpec(kind="selection", score="score_has_object", budget=80,
                   seed=0),
         QuerySpec(kind="limit", score="score_has_object", k_results=3)]


# -- LabelStore ------------------------------------------------------------
def test_label_store_roundtrip_zero_fresh_after_restart(wl, index, tmp_path):
    """save -> reload -> broker serves every repeat query from the cache."""
    stem = str(tmp_path / "idx")
    store = LabelStore.for_index(stem, index)
    assert len(store) == 0
    eng = QueryEngine(index, wl)
    store.attach(eng.broker, eng)
    out1 = QuerySession(eng, SPECS).execute()
    fresh1 = out1.stats["fresh_total"]
    assert fresh1 > 0
    # write-through already persisted every flush: files exist and agree
    assert store.json_path.exists() and store.npz_path.exists()

    # "restart": brand-new engine + broker, labels only from disk
    store2 = LabelStore.for_index(stem, index)
    assert len(store2) == len(store) > 0
    eng2 = QueryEngine(index, wl)
    seeded = store2.attach(eng2.broker, eng2)
    assert seeded == len(store2)
    out2 = QuerySession(eng2, SPECS).execute()
    assert out2.stats["fresh_total"] == 0
    # answers are identical to the first run, just free
    for a, b in zip(out1.results, out2.results):
        assert a.estimate == b.estimate
        assert a.n_invocations == b.n_invocations
        if a.selected is not None:
            np.testing.assert_array_equal(a.selected, b.selected)


def test_label_store_invalidated_by_index_version_change(wl, tmp_path):
    """A store cached against one index lineage does not load against
    another: cracking bumps ``TastiIndex.version`` and opens come back
    empty."""
    index = TastiIndex.build(wl.features, 60, wl.target_dnn_batch, k=4,
                             random_fraction=0.0, seed=1)
    stem = str(tmp_path / "idx")
    store = LabelStore.open(stem, index.version)
    store.update({0: wl.target_dnn(0), 5: wl.target_dnn(5)})
    store.save()
    assert len(LabelStore.open(stem, index.version)) == 2

    pool = np.setdiff1d(np.arange(index.n_records), index.rep_ids)
    index.crack(pool[:3], wl.target_dnn_batch(pool[:3]))
    assert len(LabelStore.open(stem, index.version)) == 0  # invalidated


def test_write_through_restamps_version_after_midserving_crack(wl, tmp_path):
    """A crack=True query bumps the index version mid-serving; the attached
    store re-stamps itself on the next write-through so its labels stay
    loadable against the cracked index."""
    index = TastiIndex.build(wl.features, 60, wl.target_dnn_batch, k=4,
                             random_fraction=0.0, seed=2)
    stem = str(tmp_path / "idx")
    store = LabelStore.open(stem, index.version)
    eng = QueryEngine(index, wl)
    store.attach(eng.broker, eng)
    res = eng.execute(QuerySpec(kind="selection", score="score_has_object",
                                budget=40, seed=0, crack=True))
    assert res.n_cracked > 0 and index.version > 0
    assert store.index_version == index.version
    reloaded = LabelStore.open(stem, index.version)
    assert len(reloaded) == len(store) > 0


def test_store_save_is_atomic(tmp_path):
    """A failing save (unencodable annotation) leaves no torn/partial files."""
    store = LabelStore(str(tmp_path / "s"), index_version=0)
    store.update({1: 1.0})
    store.save()
    store.update({2: object()})  # not JSON-encodable
    with pytest.raises(TypeError):
        store.save()
    assert not list(tmp_path.glob("*.tmp"))
    assert len(LabelStore.open(str(tmp_path / "s"), 0)) == 1  # old state intact


def test_store_fingerprint_invalidates_reused_stem(wl, index, tmp_path):
    """A --store stem reused for a DIFFERENT dataset must not serve the old
    labels: same index_version (0 for every fresh build), different
    embedding fingerprint -> the store comes back empty."""
    stem = str(tmp_path / "s")
    store = LabelStore.for_index(stem, index)
    store.update({0: wl.target_dnn(0)})
    store.save()
    assert len(LabelStore.for_index(stem, index)) == 1

    other = make_workload("taipei", n_frames=300)
    other_index = TastiIndex.build(other.features, 30,
                                   other.target_dnn_batch, k=2,
                                   random_fraction=0.0, seed=0)
    assert other_index.version == index.version == 0
    assert len(LabelStore.for_index(stem, other_index)) == 0  # invalidated


def test_journal_makes_unsaved_labels_survive_a_crash(wl, index, tmp_path):
    """Write-through is an O(batch) journal append: labels reach disk on
    every flush even if save() (compaction) never runs, and a torn final
    line (crash mid-append) is skipped on replay, keeping the rest."""
    stem = str(tmp_path / "idx")
    store = LabelStore.for_index(stem, index)
    eng = QueryEngine(index, wl)
    store.attach(eng.broker, eng)
    eng.broker.fetch(np.arange(10))   # flush -> journal append, no save()
    eng.broker.fetch(np.arange(10, 17))
    assert store.journal_path.exists()
    assert len(store) == 17

    # simulated crash: process gone, only the (uncompacted) files remain
    revived = LabelStore.for_index(stem, index)
    assert len(revived) == 17
    assert sorted(revived.labels) == list(range(17))

    # torn tail: a crash mid-append leaves half a JSON line
    with open(store.journal_path, "a") as f:
        f.write('{"ids": [99], "anno')
    survivor = LabelStore.for_index(stem, index)
    assert len(survivor) == 17 and 99 not in survivor.labels

    # compaction folds the journal into the snapshot and truncates it
    survivor.save()
    assert not survivor.journal_path.exists()
    assert len(LabelStore.for_index(stem, index)) == 17


def test_stale_other_lineage_files_are_not_appended_to(wl, index, tmp_path):
    """attach() over stale files from another lineage compacts first, so
    the journal never mixes generations."""
    stem = str(tmp_path / "idx")
    stale = LabelStore.open(stem, index_version=77)  # some other lineage
    stale.update({3: 0.5})
    stale.save()

    store = LabelStore.for_index(stem, index)
    assert len(store) == 0
    eng = QueryEngine(index, wl)
    store.attach(eng.broker, eng)
    eng.broker.fetch([1, 2])
    revived = LabelStore.for_index(stem, index)
    assert sorted(revived.labels) == [1, 2]  # stale label 3 gone


# -- concurrent-session parity ---------------------------------------------
def _result_signature(res):
    return (res.kind, res.estimate, res.threshold, res.n_invocations,
            None if res.selected is None else tuple(int(i)
                                                    for i in res.selected))


def test_threaded_sessions_match_isolated_runs(wl, index):
    """N sessions over ONE shared engine, executing concurrently from
    threads, must produce results identical to the same spec lists run
    isolated (fresh engine each), at no more total fresh-label cost."""
    spec_lists = [
        [QuerySpec(kind="aggregation", score="score_count", err=0.15, seed=0),
         QuerySpec(kind="selection", score="score_has_object", budget=90,
                   seed=0)],
        [QuerySpec(kind="aggregation", score="score_has_object", err=0.1,
                   seed=1),
         QuerySpec(kind="limit", score="score_has_object", k_results=4)],
        # overlaps list 0's selection -> cross-session dedup exercises cache
        [QuerySpec(kind="selection", score="score_has_object", budget=90,
                   seed=0)],
        [QuerySpec(kind="aggregation", score="score_count", err=0.08,
                   seed=3)],
    ]
    iso = [QuerySession(QueryEngine(index, wl), specs).execute()
           for specs in spec_lists]
    iso_fresh = sum(out.stats["fresh_total"] for out in iso)

    shared = QueryEngine(index, wl)
    results = [None] * len(spec_lists)
    errors = []
    barrier = threading.Barrier(len(spec_lists))

    def run(i):
        try:
            barrier.wait(timeout=30)
            results[i] = QuerySession(shared, spec_lists[i]).execute()
        except Exception as e:  # noqa: BLE001 - surfaced below
            errors.append((i, repr(e)))

    threads = [threading.Thread(target=run, args=(i,))
               for i in range(len(spec_lists))]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errors, errors

    for out_iso, out_conc in zip(iso, results):
        for r_iso, r_conc in zip(out_iso.results, out_conc.results):
            assert _result_signature(r_iso) == _result_signature(r_conc)
    conc_fresh = sum(out.stats["fresh_total"] for out in results)
    assert conc_fresh <= iso_fresh
    # every label the broker issued was fresh exactly once
    assert shared.broker.stats["fresh"] == len(shared.broker.cache)


def test_sharded_server_warm_restart_still_free(wl, index, tmp_path):
    """One replica pool shared by all of a server's sessions must not break
    the store's warm-restart guarantee: a restarted sharded server answers
    the repeat spec list with zero fresh labels and identical rows."""
    stem = str(tmp_path / "sharded")

    def start():
        eng = QueryEngine(index, wl, oracle_replicas=2)
        store = LabelStore.for_index(stem, index)
        store.attach(eng.broker, eng)
        return QueryServer(eng, port=0, admission_window=0.0,
                           store=store).start()

    specs = [s.to_dict() for s in SPECS]
    srv = start()
    try:
        client = QueryClient(srv.url)
        client.wait_ready(10)
        out1 = client.query(specs)
        assert out1["request"]["fresh"] > 0
        stats = client.stats()
        assert stats["oracle_pool"]["n_replicas"] == 2
        assert stats["oracle_pool"]["batches"] >= 1
    finally:
        srv.shutdown()

    srv = start()  # warm restart, still sharded
    try:
        c2 = QueryClient(srv.url)
        c2.wait_ready(10)
        out2 = c2.query(specs)
        assert out2["request"]["fresh"] == 0
        for a, b in zip(out1["results"], out2["results"]):
            assert a.get("estimate") == b.get("estimate")
            assert a.get("selected_head") == b.get("selected_head")
            assert a["n_invocations"] == b["n_invocations"]
    finally:
        srv.shutdown()


# -- HTTP server ------------------------------------------------------------
@pytest.fixture()
def server(wl, index, tmp_path):
    stem = str(tmp_path / "idx")
    store = LabelStore.open(stem, index.version)
    engine = QueryEngine(index, wl)
    store.attach(engine.broker, engine)
    srv = QueryServer(engine, port=0, admission_window=0.05,
                      store=store).start()
    yield srv
    srv.shutdown()


def test_server_end_to_end_repeat_is_free(server, wl, index, tmp_path):
    client = QueryClient(server.url)
    client.wait_ready(10)
    specs = [s.to_dict() for s in SPECS]
    out1 = client.query(specs)
    assert len(out1["results"]) == len(specs)
    assert out1["request"]["fresh"] > 0
    assert out1["results"][0]["estimate"] is not None

    out2 = client.query(specs)  # same engine, warm cache
    assert out2["request"]["fresh"] == 0
    for a, b in zip(out1["results"], out2["results"]):
        assert a.get("estimate") == b.get("estimate")
        assert a.get("selected_head") == b.get("selected_head")

    stats = client.stats()
    assert stats["server"]["requests"] == 2
    assert stats["server"]["errors"] == 0
    assert stats["accounts"]["fresh_total"] == out1["request"]["fresh"]
    assert stats["store"]["n_labels"] == stats["broker"]["fresh"]
    assert stats["index"]["records"] == index.n_records

    # cold HTTP restart against the persisted store: repeat costs nothing
    server.shutdown()
    store2 = LabelStore.open(str(tmp_path / "idx"), index.version)
    eng2 = QueryEngine(index, wl)
    store2.attach(eng2.broker, eng2)
    srv2 = QueryServer(eng2, port=0, store=store2).start()
    try:
        c2 = QueryClient(srv2.url)
        c2.wait_ready(10)
        out3 = c2.query(specs)
        assert out3["request"]["fresh"] == 0
        assert out3["results"][0]["estimate"] == out1["results"][0]["estimate"]
    finally:
        srv2.shutdown()


def test_server_admission_window_coalesces_concurrent_posts(wl, index):
    engine = QueryEngine(index, wl)
    srv = QueryServer(engine, port=0, admission_window=1.0).start()
    try:
        client = QueryClient(srv.url)
        client.wait_ready(10)
        barrier = threading.Barrier(2)
        outs = [None, None]

        def post(i, spec):
            barrier.wait(timeout=30)
            outs[i] = client.query([spec])

        threads = [
            threading.Thread(target=post, args=(0, {
                "kind": "aggregation", "score": "score_count", "err": 0.2})),
            threading.Thread(target=post, args=(1, {
                "kind": "selection", "score": "score_has_object",
                "budget": 50})),
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert outs[0] is not None and outs[1] is not None
        # both submissions rode one shared session (joint planning + one
        # combined flush), and each got back exactly its own results
        stats = client.stats()
        assert stats["server"]["sessions"] == 1
        assert stats["server"]["coalesced"] == 1
        assert outs[0]["session"]["coalesced_requests"] == 2
        assert outs[0]["results"][0]["kind"] == "aggregation"
        assert outs[1]["results"][0]["kind"] == "selection"
    finally:
        srv.shutdown()


def test_server_budgeted_submission_never_coalesced(wl, index):
    engine = QueryEngine(index, wl)
    srv = QueryServer(engine, port=0, admission_window=0.5).start()
    try:
        client = QueryClient(srv.url)
        client.wait_ready(10)
        out = client.query([{"kind": "selection", "score": "score_has_object",
                             "budget": 500}], budget=60)
        assert out["session"]["coalesced_requests"] == 1
        assert out["session"]["budget"] == 60
        assert out["request"]["fresh"] <= 60
    finally:
        srv.shutdown()


def test_submit_after_shutdown_fails_fast(wl, index):
    """A submission racing with shutdown must not hang until the request
    timeout: submit() refuses once shutdown began."""
    srv = QueryServer(QueryEngine(index, wl), port=0).start()
    srv.shutdown()
    with pytest.raises(RuntimeError, match="shutting down"):
        srv.submit([QuerySpec(kind="aggregation", score="score_count")])


def test_server_rejects_malformed_specs(wl, index):
    engine = QueryEngine(index, wl)
    srv = QueryServer(engine, port=0, admission_window=0.0).start()
    try:
        client = QueryClient(srv.url)
        client.wait_ready(10)
        from repro.serve.client import ServerError
        with pytest.raises(ServerError, match="unknown QuerySpec fields"):
            client.query([{"kind": "aggregation", "bogus": 1}])
        with pytest.raises(ServerError, match="no specs"):
            client.query([])
        # a spec that fails at plan time comes back 400, not a hung request
        with pytest.raises(ServerError, match="budget"):
            client.query([{"kind": "selection", "score": "score_has_object"}])
    finally:
        srv.shutdown()


# -- multi-workload serving --------------------------------------------------
TEXT_SPECS = [
    QuerySpec(kind="aggregation", score="score_n_predicates", err=0.2,
              seed=0),
    QuerySpec(kind="selection", score="score_is_select", budget=60, seed=0),
    QuerySpec(kind="limit", score="score_is_select", k_results=3),
]


def _two_workload_registry(wl, index, wl_text, index_text):
    registry = WorkloadRegistry()
    registry.register("video", QueryEngine(index, wl))
    registry.register("text", QueryEngine(index_text, wl_text))
    return registry


def _no_stamp(row):
    return {k: v for k, v in row.items() if k != "workload"}


def test_workload_field_roundtrips_through_spec_json():
    spec = QuerySpec.from_dict({"kind": "aggregation", "score": "score_count",
                                "workload": "text"})
    assert spec.workload == "text"
    assert spec.to_dict()["workload"] == "text"
    # unset stays out of the wire form (single-workload requests unchanged)
    assert "workload" not in QuerySpec(kind="aggregation",
                                       score="score_count").to_dict()


def test_multi_workload_routing_and_listing(wl, index, wl_text, index_text):
    registry = _two_workload_registry(wl, index, wl_text, index_text)
    srv = QueryServer(registry, port=0, admission_window=0.0).start()
    try:
        client = QueryClient(srv.url)
        client.wait_ready(10)
        # request-level routing
        out_t = client.query([s.to_dict() for s in TEXT_SPECS],
                             workload="text")
        assert out_t["request"]["workload"] == "text"
        assert all(r["workload"] == "text" for r in out_t["results"])
        # spec-level routing
        out_s = client.query([{"kind": "aggregation", "workload": "text",
                               "score": "score_n_predicates", "err": 0.2}])
        assert out_s["session"]["workload"] == "text"
        # default routing (first mounted)
        out_d = client.query([{"kind": "aggregation", "score": "score_count",
                               "err": 0.2}])
        assert out_d["request"]["workload"] == "video"

        wls = client.workloads()
        assert wls["default"] == "video"
        by_name = {w["name"]: w for w in wls["workloads"]}
        assert set(by_name) == {"video", "text"}
        assert by_name["video"]["default"] and by_name["video"]["loaded"]
        assert by_name["text"]["records"] == index_text.n_records
        assert by_name["text"]["requests"] == 2

        stats = client.stats()
        assert set(stats["workloads"]) == {"video", "text"}
        assert stats["workloads"]["text"]["server"]["requests"] == 2
        assert stats["workloads"]["video"]["server"]["requests"] == 1
        # top level mirrors the default workload (legacy payload shape)
        assert (stats["accounts"]["fresh_total"]
                == stats["workloads"]["video"]["accounts"]["fresh_total"])
        assert stats["index"]["records"] == index.n_records

        from repro.serve.client import ServerError
        with pytest.raises(ServerError, match="unknown workload"):
            client.query([{"kind": "aggregation", "score": "score_count"}],
                         workload="speech")
        with pytest.raises(ServerError, match="one request routes to one"):
            client.query([
                {"kind": "aggregation", "score": "score_count",
                 "workload": "video"},
                {"kind": "aggregation", "score": "score_n_predicates",
                 "workload": "text"}])
        # partial spec-level routing is ambiguous for the unstamped spec
        with pytest.raises(ServerError, match="others none"):
            client.query([
                {"kind": "aggregation", "score": "score_count"},
                {"kind": "aggregation", "score": "score_n_predicates",
                 "workload": "text"}])
        # ...unless a request-level workload covers everything
        with pytest.raises(ServerError, match="a spec names"):
            client.query([{"kind": "aggregation", "score": "score_count",
                           "workload": "video"}], workload="text")
    finally:
        srv.shutdown()


def test_multi_workload_admission_coalesces_per_workload(wl, index, wl_text,
                                                         index_text):
    """Concurrent requests to the SAME workload still share a session;
    a different workload admits independently (its own lane, no window
    shared with strangers on another index)."""
    registry = _two_workload_registry(wl, index, wl_text, index_text)
    srv = QueryServer(registry, port=0, admission_window=1.0).start()
    try:
        client = QueryClient(srv.url)
        client.wait_ready(10)
        barrier = threading.Barrier(3)
        outs = [None, None, None]

        def post(i, spec, workload):
            barrier.wait(timeout=30)
            outs[i] = client.query([spec], workload=workload)

        threads = [
            threading.Thread(target=post, args=(0, {
                "kind": "aggregation", "score": "score_count", "err": 0.2},
                "video")),
            threading.Thread(target=post, args=(1, {
                "kind": "selection", "score": "score_has_object",
                "budget": 50}, "video")),
            threading.Thread(target=post, args=(2, {
                "kind": "aggregation", "score": "score_n_predicates",
                "err": 0.2}, "text")),
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert all(o is not None for o in outs)
        assert outs[0]["session"]["coalesced_requests"] == 2
        assert outs[2]["session"]["coalesced_requests"] == 1
        stats = QueryClient(srv.url).stats()
        video, text = (stats["workloads"][n]["server"]
                       for n in ("video", "text"))
        assert video["sessions"] == 1 and video["coalesced"] == 1
        assert text["sessions"] == 1 and text["coalesced"] == 0
    finally:
        srv.shutdown()


def test_multi_workload_parity_with_isolated_servers(wl, index, wl_text,
                                                     index_text):
    """Interleaved concurrent requests to a 2-workload server produce
    results and per-workload fresh/cached accounting identical to two
    isolated single-workload servers."""
    trains = {
        "video": [[s.to_dict() for s in SPECS],
                  [{"kind": "aggregation", "score": "score_count",
                    "err": 0.15, "seed": 1}]],
        "text": [[s.to_dict() for s in TEXT_SPECS],
                 [{"kind": "selection", "score": "score_is_select",
                   "budget": 40, "seed": 1}]],
    }

    def drive(url, name, workload=None):
        client = QueryClient(url)
        client.wait_ready(10)
        rows, fresh, cached = [], 0, 0
        for specs in trains[name]:
            out = client.query(specs, workload=workload)
            rows.append([_no_stamp(r) for r in out["results"]])
            fresh += out["request"]["fresh"]
            cached += out["request"]["cached"]
        return rows, fresh, cached

    iso = {}
    for name, (w, idx) in (("video", (wl, index)),
                           ("text", (wl_text, index_text))):
        srv = QueryServer(QueryEngine(idx, w), port=0,
                          admission_window=0.0).start()
        try:
            iso[name] = drive(srv.url, name)
        finally:
            srv.shutdown()

    registry = _two_workload_registry(wl, index, wl_text, index_text)
    srv = QueryServer(registry, port=0, admission_window=0.0).start()
    try:
        shared = {}
        errors = []
        barrier = threading.Barrier(2)

        def run(name):
            try:
                barrier.wait(timeout=30)
                shared[name] = drive(srv.url, name, workload=name)
            except Exception as e:  # noqa: BLE001 - surfaced below
                errors.append((name, repr(e)))

        threads = [threading.Thread(target=run, args=(n,)) for n in trains]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not errors, errors
        stats = QueryClient(srv.url).stats()
        for name in trains:
            assert shared[name] == iso[name]
            acct = stats["workloads"][name]["accounts"]
            assert acct["fresh_total"] == iso[name][1]
            assert acct["cached_total"] == iso[name][2]
    finally:
        srv.shutdown()


def test_manifest_lazy_load_and_warm_restart_both_workloads(
        wl, index, wl_text, index_text, tmp_path):
    """A manifest-mounted server loads workloads lazily, and a warm restart
    over the per-workload stores answers repeats on BOTH workloads with
    zero fresh target-DNN invocations."""
    index.save(str(tmp_path / "video-idx"))
    index_text.save(str(tmp_path / "text-idx"))
    manifest = tmp_path / "workloads.json"
    manifest.write_text(json.dumps({
        "default": "video",
        "workloads": {
            "video": {"dataset": "night-street", "n_frames": wl.n_frames,
                      "index": str(tmp_path / "video-idx")},
            "text": {"dataset": "wikisql",
                     "n_records": wl_text.n_records,
                     "index": str(tmp_path / "text-idx"),
                     "store": str(tmp_path / "text-store")},
        },
    }))
    queries = {"video": [s.to_dict() for s in SPECS],
               "text": [s.to_dict() for s in TEXT_SPECS]}

    registry = WorkloadRegistry.from_manifest(str(manifest))
    assert registry.default == "video"
    assert not any(e.loaded for e in registry.entries())
    srv = QueryServer(registry, port=0, admission_window=0.0).start()
    first = {}
    try:
        client = QueryClient(srv.url)
        client.wait_ready(10)
        # lazily mounted: nothing is loaded until a spec routes to it
        health = {w["name"]: w for w in client.workloads()["workloads"]}
        assert not health["video"]["loaded"] and not health["text"]["loaded"]
        first["video"] = client.query(queries["video"])
        loaded = {w["name"]: w["loaded"]
                  for w in client.workloads()["workloads"]}
        assert loaded == {"video": True, "text": False}
        first["text"] = client.query(queries["text"], workload="text")
        assert first["video"]["request"]["fresh"] > 0
        assert first["text"]["request"]["fresh"] > 0
        # store defaults to the index stem; the manifest may override it
        stats = QueryClient(srv.url).stats()
        assert stats["workloads"]["text"]["store"]["path"].endswith(
            "text-store")
    finally:
        srv.shutdown()  # saves every workload's store

    srv = QueryServer(WorkloadRegistry.from_manifest(str(manifest)),
                      port=0, admission_window=0.0).start()
    try:
        client = QueryClient(srv.url)
        client.wait_ready(10)
        for name in ("video", "text"):
            out = client.query(queries[name], workload=name)
            assert out["request"]["fresh"] == 0, name
            for a, b in zip(first[name]["results"], out["results"]):
                assert a.get("estimate") == b.get("estimate")
                assert a.get("selected_head") == b.get("selected_head")
                assert a["n_invocations"] == b["n_invocations"]
    finally:
        srv.shutdown()


def test_registry_rejects_bad_mounts(wl, index):
    registry = WorkloadRegistry()
    registry.register("video", QueryEngine(index, wl))
    with pytest.raises(ValueError, match="already mounted"):
        registry.register("video", QueryEngine(index, wl))
    with pytest.raises(KeyError, match="unknown workload"):
        registry.get("speech")
    with pytest.raises(ValueError, match="unknown dataset"):
        WorkloadSpec(name="x", dataset="imagenet")
    with pytest.raises(ValueError, match="unknown key"):
        WorkloadSpec.from_dict("x", {"dataset": "wikisql", "bogus": 1})


def test_registry_memoizes_a_failed_lazy_load(tmp_path):
    """A deterministically broken mount (missing index files) fails fast on
    every later lookup instead of re-running the whole load each time."""
    registry = WorkloadRegistry()
    registry.declare(WorkloadSpec(name="broken", dataset="wikisql",
                                  n_records=200,
                                  index=str(tmp_path / "missing-idx")))
    with pytest.raises(FileNotFoundError):
        registry.get("broken")
    with pytest.raises(RuntimeError, match="failed to load previously"):
        registry.get("broken")
