"""Multi-device semantics, validated in subprocesses with
``--xla_force_host_platform_device_count=8`` (the main test process keeps the
real 1-device view; forcing devices is process-global).

Covers: sharded train step on a 2x4 mesh, sequence-parallel shard_map
attention == single-device blocked attention, int8-compressed DP psum ==
plain mean, and GPipe pipeline_fwd == sequential block application.
"""
import os
import pathlib
import subprocess
import sys
import textwrap


SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def run_child(body: str) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        if not hasattr(jax, "set_mesh"):  # jax < 0.5: ambient mesh via ctx
            jax.set_mesh = lambda m: m.__enter__()
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, f"child failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout


def test_sharded_train_step_runs_2x4():
    run_child("""
        from repro.configs import get_config
        from repro.launch.mesh import make_host_mesh
        from repro.models import lm
        from repro.optim.adamw import OptimizerConfig, init_opt_state
        from repro.parallel import sharding as shd
        from repro.train.steps import make_train_step

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        jax.set_mesh(mesh)
        cfg = get_config("llama3.2-1b").smoke()
        params = lm.init_model(cfg, jax.random.PRNGKey(0))
        pspecs = lm.model_specs(cfg)
        psh = shd.param_shardings(pspecs, cfg, mesh)
        params = jax.tree.map(jax.device_put, params, psh)
        opt = OptimizerConfig(peak_lr=1e-3, total_steps=4, warmup_steps=1)
        state = init_opt_state(params, opt)
        batch = {"tokens": jnp.zeros((4, 32), jnp.int32) + 3,
                 "targets": jnp.ones((4, 32), jnp.int32)}
        bsh = NamedSharding(mesh, P(("data",), None))
        batch = {k: jax.device_put(v, bsh) for k, v in batch.items()}
        step = jax.jit(make_train_step(cfg, opt))
        p2, s2, m = step(params, state, batch)
        assert jnp.isfinite(m["loss"]), m
        print("loss", float(m["loss"]))
    """)


def test_seq_dp_attention_matches_single_device():
    run_child("""
        import dataclasses
        from repro.configs import get_config
        from repro.models import attention
        from repro.models.common import init_params

        cfg = get_config("llama3.2-1b").smoke()
        cfg_sp = dataclasses.replace(cfg, shard_strategy="seq_dp")
        b, s = 2, 64
        params = init_params(attention.attention_specs(cfg),
                             jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model),
                              jnp.float32)
        ref = attention.attention_fwd(params, x, cfg, causal=True)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        jax.set_mesh(mesh)
        xs = jax.device_put(x, NamedSharding(mesh, P("data", "model", None)))
        ps = jax.tree.map(
            lambda a: jax.device_put(a, NamedSharding(mesh, P())), params)
        out = jax.jit(lambda p, h: attention.attention_fwd(
            p, h, cfg_sp, causal=True))(ps, xs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)
        print("seq_dp == ref OK")
    """)


def test_compressed_psum_matches_mean():
    run_child("""
        from repro.optim.compression import make_compressed_psum

        mesh = jax.make_mesh((8,), ("data",))
        jax.set_mesh(mesh)
        from jax.experimental.shard_map import shard_map
        rng = np.random.default_rng(0)
        # one distinct gradient per shard: global view stacked on axis 0
        g_all = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))

        def local(g):
            gf = g[0]
            scale = jnp.maximum(jax.lax.pmax(jnp.max(jnp.abs(gf)), "data"),
                                1e-12) / 127.0
            q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
            qs = jax.lax.psum(q.astype(jnp.int32), "data")
            return ((qs.astype(jnp.float32) * scale) / 8)[None]

        f = shard_map(local, mesh=mesh, in_specs=(P("data", None),),
                      out_specs=P("data", None), check_rep=False)
        out = f(g_all)
        mean_true = np.asarray(g_all).mean(0)
        # every shard's output approximates the true mean within quant error
        np.testing.assert_allclose(np.asarray(out)[0], mean_true,
                                   atol=np.abs(np.asarray(g_all)).max() / 64)
        print("compressed psum OK")
    """)


def test_pipeline_fwd_matches_sequential():
    run_child("""
        from repro.parallel.pipeline import pipeline_fwd

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        jax.set_mesh(mesh)
        rng = np.random.default_rng(0)
        n_blocks, d = 4, 16
        w = jnp.asarray(rng.normal(size=(n_blocks, d, d)).astype(np.float32)
                        / np.sqrt(d))
        h = jnp.asarray(rng.normal(size=(8, 4, d)).astype(np.float32))

        def block_apply(stage_w, hm):
            for i in range(stage_w.shape[0]):
                hm = jnp.tanh(hm @ stage_w[i])
            return hm

        out = pipeline_fwd(block_apply, w, h, mesh, n_microbatches=4,
                           axis="pod")
        ref = h
        for i in range(n_blocks):
            ref = jnp.tanh(ref @ w[i])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        print("pipeline OK")
    """)
