"""Parity for the fused propagation family (repro.kernels.propagate): the
Pallas kernel (interpret mode on CPU) and the XLA reference against the
float64 host path in repro.core.propagation, over fixed sweeps, randomized
shapes/dtypes, and the padding edge cases (k > n_reps, one rep, empty index).
Tier-1 gates, like distance_topk and fpf_update — the serving hot path
replays these kernels against device-resident index structures."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.propagation import propagate_categorical, propagate_numeric
from repro.kernels.distance_topk.ops import PAD_DIST, distance_topk
from repro.kernels.propagate.ops import MODES, propagate

IMPLS = ("xla", "pallas")


def _call(rep_scores, ids, d2, mode, impl, **kw):
    out = propagate(jnp.asarray(rep_scores, jnp.float32),
                    jnp.asarray(np.asarray(ids, np.int32)),
                    jnp.asarray(np.asarray(d2, np.float32)),
                    mode, impl=impl, interpret=(impl == "pallas"),
                    block_n=128, donate=False, **kw)
    return np.asarray(out, np.float64)


def _random_instance(seed, n_classes=None, pad_cols=0):
    rng = np.random.default_rng(seed)
    c = int(rng.integers(3, 40))
    n = int(rng.integers(5, 300))
    k = int(rng.integers(1, min(c, 8) + 1)) + pad_cols
    if n_classes is None:
        rep_scores = rng.uniform(0.0, 1.0, size=c)
    else:
        rep_scores = rng.integers(0, n_classes, size=c).astype(np.float64)
    ids = rng.integers(0, c, size=(n, k))
    d2 = np.sort(rng.uniform(0.0, 9.0, size=(n, k)), axis=1)
    if pad_cols:
        d2[:, -pad_cols:] = PAD_DIST
    return rep_scores, ids, d2


@pytest.mark.tier1
@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("seed", range(6))
def test_numeric_parity(impl, seed):
    rep_scores, ids, d2 = _random_instance(seed)
    got = _call(rep_scores, ids, d2, "numeric", impl)
    want = propagate_numeric(rep_scores, ids, d2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@pytest.mark.tier1
@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("seed", range(6))
def test_categorical_parity(impl, seed):
    n_classes = int(np.random.default_rng(seed + 500).integers(2, 9))
    rep_scores, ids, d2 = _random_instance(seed, n_classes=n_classes)
    got = _call(rep_scores, ids, d2, "categorical", impl, n_classes=n_classes)
    want = propagate_categorical(rep_scores, ids, d2, n_classes=n_classes)
    np.testing.assert_array_equal(got, want.astype(np.float64))


@pytest.mark.tier1
@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("seed", range(6))
def test_top1_parity(impl, seed):
    """Float32 can't promise the host path bit-for-bit; it must promise the
    same *semantics*: never flip distinct nearest-rep score levels, and
    order by distance within a level up to f32 output ties."""
    rep_scores, ids, d2 = _random_instance(seed)
    got = _call(rep_scores, ids, d2, "top1", impl)
    base = rep_scores[ids[:, 0]].astype(np.float32)
    order = np.argsort(-got, kind="stable")
    sb = base[order]
    assert not (np.diff(sb) > 0).any(), "device top1 flipped score levels"
    sd, sg = np.sqrt(d2[order][:, 0]), got[order]
    for lvl in np.unique(sb):
        m = sb == lvl
        dd, gg = sd[m], sg[m]
        # closer must rank higher unless the f32 outputs tied exactly
        bad = (np.diff(dd) < 0) & (np.diff(gg) != 0)
        assert not bad.any()


@pytest.mark.tier1
@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("mode", MODES)
def test_padded_columns_carry_no_weight(impl, mode):
    """k > n_reps padding (PAD_DIST sentinel columns) must not change the
    result vs the same instance without the padding."""
    rep_scores, ids, d2 = _random_instance(7, n_classes=(
        4 if mode == "categorical" else None), pad_cols=3)
    kw = {"n_classes": 4} if mode == "categorical" else {}
    with_pad = _call(rep_scores, ids, d2, mode, impl, **kw)
    without = _call(rep_scores, ids[:, :-3], d2[:, :-3], mode, impl, **kw)
    np.testing.assert_allclose(with_pad, without, rtol=1e-6, atol=1e-7)


@pytest.mark.tier1
@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("mode", MODES)
def test_empty_and_one_rep_index(impl, mode):
    kw = {"n_classes": 3} if mode == "categorical" else {}
    empty = _call(np.zeros(0), np.zeros((0, 4), np.int64),
                  np.zeros((0, 4)), mode, impl, **kw)
    assert empty.shape == (0,)
    # one rep, k=4: three sentinel columns from distance_topk-style padding
    ids = np.zeros((9, 4), np.int64)
    d2 = np.full((9, 4), PAD_DIST)
    d2[:, 0] = np.linspace(0.0, 4.0, 9)
    out = _call(np.asarray([2.0]), ids, d2, mode, impl, **kw)
    if mode == "top1":
        assert np.all(out <= 2.0) and out[0] == pytest.approx(2.0)
        assert np.all(np.diff(out) <= 0)  # farther from the only rep: lower
    else:
        np.testing.assert_allclose(out, 2.0, rtol=1e-6)


@pytest.mark.tier1
@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16])
def test_low_precision_rep_structures(impl, dtype):
    """bf16/f16 distance caches (and the float16 pad sentinel regression):
    outputs must be finite and close to the float32 computation."""
    rep_scores, ids, d2 = _random_instance(11)
    d2_lp = jnp.asarray(d2, jnp.float32).astype(dtype)
    out = np.asarray(propagate(jnp.asarray(rep_scores, jnp.float32),
                               jnp.asarray(np.asarray(ids, np.int32)),
                               d2_lp, "numeric", impl=impl,
                               interpret=(impl == "pallas"), block_n=128,
                               donate=False))
    assert np.isfinite(out).all()
    want = propagate_numeric(rep_scores, ids,
                             np.asarray(d2_lp, np.float64))
    np.testing.assert_allclose(out, want, rtol=5e-2, atol=5e-2)


@pytest.mark.tier1
@pytest.mark.parametrize("mode", MODES)
def test_clip01_matches_unclipped_clip(mode):
    rep_scores, ids, d2 = _random_instance(13, n_classes=(
        4 if mode == "categorical" else None))
    rep_scores = rep_scores * 3.0 - 1.0 if mode != "categorical" else rep_scores
    kw = {"n_classes": 4} if mode == "categorical" else {}
    clipped = _call(rep_scores, ids, d2, mode, "xla", clip01=True, **kw)
    plain = _call(rep_scores, ids, d2, mode, "xla", **kw)
    np.testing.assert_allclose(clipped, np.clip(plain, 0.0, 1.0),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.tier1
def test_validation_errors():
    ids = jnp.zeros((4, 2), jnp.int32)
    d2 = jnp.zeros((4, 2), jnp.float32)
    s = jnp.zeros((3,), jnp.float32)
    with pytest.raises(ValueError, match="mode"):
        propagate(s, ids, d2, "nearest")
    with pytest.raises(ValueError, match="n_classes"):
        propagate(s, ids, d2, "categorical")


@pytest.mark.tier1
def test_fused_on_real_distance_topk_structures():
    """End-to-end shape check on real kernel output, including the
    k > n_reps sentinel padding distance_topk now emits."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(137, 24)).astype(np.float32))
    r = x[:5]
    d2, ids = distance_topk(x, r, k=8)  # k_eff=5, 3 sentinel columns
    assert np.all(np.asarray(d2)[:, 5:] >= PAD_DIST)
    assert np.asarray(ids).max() < 5
    rep_scores = rng.uniform(size=5)
    got = np.asarray(propagate(jnp.asarray(rep_scores, jnp.float32),
                               ids, d2, "numeric", impl="xla", donate=False))
    want = propagate_numeric(rep_scores, np.asarray(ids), np.asarray(d2))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
