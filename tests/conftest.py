import pathlib
import sys

# Tests see the real device count (1 CPU device); only the dry-run forces 512.
SRC = pathlib.Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))
