"""Checkpoint/restart, fault tolerance, stragglers, elastic meshes, optimizer,
gradient compression, data pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import PipelineState, ShardedLoader, TokenDataset
from repro.optim.adamw import OptimizerConfig, adamw_update, init_opt_state, schedule
from repro.optim.compression import compress_decompress, quantize
from repro.runtime.elastic import choose_mesh_shape
from repro.runtime.fault_tolerance import (PreemptionSignal, StragglerMonitor,
                                           run_resilient)


# ----------------------------- checkpoint ---------------------------------

def _state():
    return {"w": jnp.arange(12.0).reshape(3, 4), "step": jnp.int32(7),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16)}}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    st = _state()
    ck.save(10, st, extra={"next_step": 10})
    out, extra = ck.restore(10, st)
    assert extra["next_step"] == 10
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(st["w"]))
    assert out["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_async_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    st = _state()
    for s in (1, 2, 3, 4):
        ck.save_async(s, st)
    ck.wait()
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_atomicity(tmp_path):
    ck = Checkpointer(tmp_path)
    # a stale tmp dir from a crashed writer must be invisible
    (tmp_path / "step_00000099.tmp").mkdir()
    assert ck.latest_step() is None
    ck.save(5, _state())
    assert ck.latest_step() == 5


# --------------------------- fault tolerance ------------------------------

def test_run_resilient_recovers_from_failures(tmp_path):
    ck = Checkpointer(tmp_path)
    fail_at = {7, 13}

    def step_fn(state, step):
        if step in fail_at:
            fail_at.discard(step)
            raise RuntimeError("injected node failure")
        return {"x": state["x"] + 1.0}, {"loss": float(state["x"])}

    report = run_resilient(step_fn, {"x": jnp.float32(0)}, n_steps=20,
                           ckpt=ck, ckpt_every=5)
    assert report.steps_completed == 20
    assert report.restarts == 2


def test_run_resilient_crash_loop_guard(tmp_path):
    ck = Checkpointer(tmp_path)

    def always_fails(state, step):
        raise RuntimeError("hard failure")

    with pytest.raises(RuntimeError):
        run_resilient(always_fails, {"x": jnp.float32(0)}, n_steps=5,
                      ckpt=ck, max_restarts=3)


def test_preemption_takes_emergency_checkpoint(tmp_path):
    ck = Checkpointer(tmp_path)
    sig = PreemptionSignal()

    def step_fn(state, step):
        if step == 3:
            sig.set()
        return {"x": state["x"] + 1.0}, {}

    report = run_resilient(step_fn, {"x": jnp.float32(0)}, n_steps=6,
                           ckpt=ck, ckpt_every=100, preemption=sig)
    assert report.emergency_checkpoints == 1
    assert ck.latest_step() == 4


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(threshold=2.0, warmup=2)
    for s in range(10):
        mon.observe(s, 1.0)
    assert not mon.events
    assert mon.observe(10, 5.0)
    assert mon.events[0]["step"] == 10
    # baseline unpoisoned
    assert mon.ewma == pytest.approx(1.0)


def test_elastic_mesh_chooser():
    assert choose_mesh_shape(512, preferred_model=16) == (32, 16)
    assert choose_mesh_shape(511, preferred_model=16) == (16, 16)  # 256 usable
    assert choose_mesh_shape(8, preferred_model=16) == (1, 8)
    assert choose_mesh_shape(3, preferred_model=16) == (1, 2)


def test_elastic_restore_resharding(tmp_path):
    """Checkpoint written under one 'mesh' restores onto another (1-device
    meshes here; the path exercised is shardings-at-restore)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    ck = Checkpointer(tmp_path)
    st = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(1, st)
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    out, _ = ck.restore(1, st, shardings=sh)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(st["w"]))
    assert out["w"].sharding == sh["w"]


# ------------------------------ optimizer ---------------------------------

def test_adamw_decreases_quadratic():
    opt = OptimizerConfig(peak_lr=0.1, min_lr=0.01, warmup_steps=0,
                          total_steps=100, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_opt_state(params, opt)

    def loss(p):
        return jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, opt)
    assert float(loss(params)) < 0.1 * l0


def test_schedule_warmup_and_cosine():
    opt = OptimizerConfig(peak_lr=1.0, min_lr=0.1, warmup_steps=10,
                          total_steps=100)
    assert float(schedule(opt, jnp.int32(5))) == pytest.approx(0.5)
    assert float(schedule(opt, jnp.int32(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(schedule(opt, jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)


def test_bf16_opt_state_dtype():
    opt = OptimizerConfig(state_dtype="bfloat16")
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = init_opt_state(params, opt)
    assert state["mu"]["w"].dtype == jnp.bfloat16


# --------------------------- grad compression -----------------------------

def test_compression_error_feedback_converges():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    err = jnp.zeros_like(g)
    # accumulated dequantized stream converges to accumulated true gradient
    acc_true = np.zeros(256)
    acc_deq = np.zeros(256)
    for _ in range(50):
        deq, err = compress_decompress(g, err)
        acc_true += np.asarray(g)
        acc_deq += np.asarray(deq)
    rel = np.abs(acc_deq - acc_true).max() / np.abs(acc_true).max()
    assert rel < 0.02


def test_quantize_range():
    g = jnp.asarray([-1.0, 0.0, 0.5, 1.0])
    q, scale = quantize(g)
    assert q.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(q))) <= 127
    np.testing.assert_allclose(np.asarray(q, np.float32) * float(scale),
                               np.asarray(g), atol=float(scale))


# ------------------------------ data pipeline -----------------------------

def test_loader_determinism_and_resume():
    ds = TokenDataset(vocab_size=512, n_docs=64, doc_len=128, seed=0)
    l1 = ShardedLoader(ds, global_batch=8, seq_len=16)
    batches1 = [l1.next() for _ in range(5)]
    st3 = PipelineState(0, 3)
    l1.close()
    l2 = ShardedLoader(ds, global_batch=8, seq_len=16, state=st3)
    b = l2.next()
    np.testing.assert_array_equal(b["tokens"], batches1[3]["tokens"])
    l2.close()


def test_loader_shards_disjoint():
    ds = TokenDataset(vocab_size=512, n_docs=64, doc_len=128, seed=0)
    l0 = ShardedLoader(ds, global_batch=8, seq_len=16, host_id=0, n_hosts=2)
    l1 = ShardedLoader(ds, global_batch=8, seq_len=16, host_id=1, n_hosts=2)
    b0, b1 = l0.next(), l1.next()
    full = ds.batch(0, 0, 8, 16)
    np.testing.assert_array_equal(np.concatenate([b0["tokens"], b1["tokens"]]),
                                  full["tokens"])
    l0.close()
    l1.close()
