"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and finiteness (deliverable f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import lm
from repro.optim.adamw import OptimizerConfig, init_opt_state
from repro.train.steps import make_train_step

B, S = 2, 32


def _batch(cfg):
    batch = {"tokens": jnp.full((B, S), 5, jnp.int32),
             "targets": jnp.ones((B, S), jnp.int32)}
    if cfg.vision_tokens:
        batch["vision_embeds"] = jnp.ones((B, cfg.vision_tokens, cfg.d_model),
                                          jnp.float32)
    if cfg.encoder_decoder:
        batch["enc_embeds"] = jnp.ones((B, S, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).smoke()
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = jax.jit(lambda p, b: lm.lm_logits(p, b, cfg))(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits[..., :cfg.vocab_size])))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_decreases_nothing_nan(arch):
    cfg = get_config(arch).smoke()
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    opt = OptimizerConfig(peak_lr=1e-3, total_steps=10, warmup_steps=1)
    state = init_opt_state(params, opt)
    step = jax.jit(make_train_step(cfg, opt))
    batch = _batch(cfg)
    params2, state2, metrics = step(params, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state2["step"]) == 1
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_step_shapes(arch):
    cfg = get_config(arch).smoke()
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    caches = lm.init_cache(cfg, B, S, cross_len=S if cfg.encoder_decoder else 0)
    tok = jnp.full((B, 1), 3, jnp.int32)
    logits, new_caches = jax.jit(
        lambda p, c, t: lm.decode_step(p, c, t, jnp.int32(S - 1), cfg)
    )(params, caches, tok)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits[..., :cfg.vocab_size])))
    assert jax.tree.structure(new_caches) == jax.tree.structure(caches)
