"""Device-resident proxy scoring (repro.core.resident) + the engine's
single-flight propagation: parity with the host path, crack invalidation
mid-serving, concurrent same-key sharing, and fallback policy (CPU default
off, env override, external-proxy specs untouched)."""
import threading

import numpy as np
import pytest

from repro.core import resident as resident_mod
from repro.core.engine import QueryEngine, QuerySpec
from repro.core.index import TastiIndex
from repro.core.resident import ResidentIndexState


class ToyWorkload:
    name = "toy"

    def __init__(self, n=300, d=12, seed=0):
        rng = np.random.default_rng(seed)
        self.features = rng.normal(size=(n, d)).astype(np.float32)
        self.truth = rng.random(n)

    def target_dnn_batch(self, ids):
        return [float(self.truth[int(i)]) for i in np.asarray(ids)]

    def score_id(self, a):
        return float(a)

    def score_cls(self, a):
        return float(a > 0.5)


@pytest.fixture()
def setup():
    wl = ToyWorkload()
    index = TastiIndex.build(wl.features, 30, wl.target_dnn_batch, k=4,
                             random_fraction=0.0, seed=0)
    return wl, index


pytestmark = pytest.mark.tier1


def test_cpu_defaults_to_host_path(setup, monkeypatch):
    monkeypatch.delenv(resident_mod.ENV_VAR, raising=False)
    wl, index = setup
    eng = QueryEngine(index, wl)
    import jax
    if jax.devices()[0].platform not in ("tpu", "gpu"):
        assert not eng.resident.enabled
        eng.proxy_scores("score_id")
        assert eng.stats["proxy_device_computes"] == 0
        assert eng.stats["propagation_computes"] == 1


def test_env_var_forces_resident(setup, monkeypatch):
    monkeypatch.setenv(resident_mod.ENV_VAR, "1")
    wl, index = setup
    eng = QueryEngine(index, wl)
    assert eng.resident.enabled
    eng.proxy_scores("score_id")
    assert eng.stats["proxy_device_computes"] == 1
    monkeypatch.setenv(resident_mod.ENV_VAR, "0")
    assert not QueryEngine(index, wl).resident.enabled


@pytest.mark.parametrize("mode,kw", [("numeric", {}), ("top1", {}),
                                     ("categorical", {"n_classes": 2})])
def test_resident_engine_matches_host_engine(setup, mode, kw):
    wl, index = setup
    host = QueryEngine(index, wl, resident=False)
    dev = QueryEngine(index, wl, resident=True)
    score = "score_cls" if mode == "categorical" else "score_id"
    h = host.proxy_scores(score, mode, **kw)
    d = dev.proxy_scores(score, mode, **kw)
    assert dev.stats["proxy_device_computes"] == 1
    if mode == "numeric":
        np.testing.assert_allclose(d, h, rtol=1e-5, atol=1e-6)
    elif mode == "categorical":
        np.testing.assert_array_equal(d, h)
    else:  # top1: same semantics at f32 (levels monotone)
        base = index.rep_scores(getattr(wl, score))[index.topk_ids[:, 0]]
        order = np.argsort(-d, kind="stable")
        assert not (np.diff(base[order].astype(np.float32)) > 0).any()


def test_crack_invalidates_resident_state(setup):
    """A crack mid-serving must drop the uploaded structures and the next
    propagation must reflect the post-crack index exactly (vs a host-path
    engine over the same index)."""
    wl, index = setup
    dev = QueryEngine(index, wl, resident=True)
    dev.proxy_scores("score_id")
    assert dev.resident._version == index.version
    v0 = index.version
    added = dev.crack_with(np.arange(30, 45))
    assert added > 0 and index.version > v0
    assert dev.resident._version is None  # on_crack listener dropped buffers
    d = dev.proxy_scores("score_id")
    assert dev.resident._version == index.version  # re-uploaded
    h = QueryEngine(index, wl, resident=False).proxy_scores("score_id")
    np.testing.assert_allclose(d, h, rtol=1e-5, atol=1e-6)


def test_version_mismatch_returns_none(setup):
    """ResidentIndexState.propagate refuses rep scores computed against a
    stale version (a crack raced the compute) so the engine retries."""
    wl, index = setup
    state = ResidentIndexState(index, enabled=True)
    scores = index.rep_scores(wl.score_id)
    stale = index.version - 1
    assert state.propagate(scores, "numeric", version=stale) is None
    assert state.propagate(scores, "numeric", version=index.version) is not None


def test_disabled_state_is_inert(setup):
    wl, index = setup
    state = ResidentIndexState(index, enabled=False)
    assert state.propagate(index.rep_scores(wl.score_id), "numeric",
                           version=index.version) is None
    assert state.embeddings_device() is None


@pytest.mark.parametrize("resident", [False, True])
def test_single_flight_shares_one_compute(setup, resident):
    wl, index = setup
    eng = QueryEngine(index, wl, resident=resident)
    barrier = threading.Barrier(6)
    outs, errs = [], []

    def go():
        try:
            barrier.wait(5)
            outs.append(eng.proxy_scores("score_id"))
        except BaseException as e:  # noqa: BLE001 - surfaced below
            errs.append(e)

    threads = [threading.Thread(target=go) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert eng.stats["propagation_computes"] == 1
    assert eng.stats["proxy_cache_hits"] == 5
    assert all(o is outs[0] for o in outs)


def test_single_flight_distinct_keys_all_compute(setup):
    wl, index = setup
    eng = QueryEngine(index, wl)
    barrier = threading.Barrier(2)
    errs = []

    def go(score):
        try:
            barrier.wait(5)
            eng.proxy_scores(score)
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=go, args=(s,))
               for s in ("score_id", "score_cls")]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert eng.stats["propagation_computes"] == 2


def test_single_flight_owner_error_propagates_to_waiters(setup):
    """A failing score fn must raise in *every* caller, not strand waiters
    on a flight that never lands."""
    wl, index = setup
    eng = QueryEngine(index, wl)
    barrier = threading.Barrier(4)
    errs = []

    def bad_score(a):
        raise RuntimeError("scorer exploded")

    def go():
        barrier.wait(5)
        try:
            eng.proxy_scores(bad_score, score_key="bad")
        except RuntimeError as e:
            errs.append(e)

    threads = [threading.Thread(target=go) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(10)
    assert not any(t.is_alive() for t in threads), "waiters stranded"
    assert len(errs) == 4
    assert not eng._proxy_flights


def test_external_proxy_spec_skips_scoring_paths(setup):
    """Specs with a caller-provided proxy never touch propagation (host or
    resident) — the array is used as-is."""
    wl, index = setup
    eng = QueryEngine(index, wl, resident=True)
    proxy = np.linspace(0, 1, index.n_records)
    plan = eng.plan(QuerySpec(kind="selection", score="score_cls",
                              proxy=proxy, budget=20))
    assert plan.propagation == "external"
    got = eng.proxy_for(plan)
    np.testing.assert_array_equal(got, np.clip(proxy, 0, 1))
    assert eng.stats["propagation_computes"] == 0
    assert eng.stats["proxy_device_computes"] == 0


def test_resident_survives_empty_and_tiny_index():
    wl = ToyWorkload(n=40)
    index = TastiIndex.build(wl.features, 1, wl.target_dnn_batch, k=4,
                             random_fraction=0.0, seed=0)
    eng = QueryEngine(index, wl, resident=True)
    out = eng.proxy_scores("score_id")
    assert out.shape == (40,) and np.isfinite(out).all()
    # one rep: every record propagates exactly that rep's score
    np.testing.assert_allclose(out, wl.truth[index.rep_ids[0]], rtol=1e-6)
