"""Per-kernel parity: Pallas (interpret mode on CPU) vs pure-jnp refs, over
fixed shape sweeps plus randomized shapes/dtypes.  The distance_topk and
fpf_update parities are tier-1 gates — the semantic index is built on them."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.distance_topk.ops import PAD_DIST, distance_topk
from repro.kernels.distance_topk.ref import distance_topk_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.fpf_update.ops import fpf_update
from repro.kernels.fpf_update.ref import fpf_update_ref


def _random_case(seed):
    """Randomized (n, c, d, k, dtype) — deliberately off block boundaries."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(33, 700))
    c = int(rng.integers(5, 400))
    d = int(rng.integers(8, 160))
    k = int(rng.integers(1, min(c, 16) + 1))
    dtype = [np.float32, jnp.bfloat16][int(rng.integers(0, 2))]
    return n, c, d, k, dtype, rng


@pytest.mark.tier1
@pytest.mark.parametrize("n,c,d,k", [
    (256, 128, 64, 8), (512, 300, 128, 16), (100, 37, 32, 5), (128, 8, 16, 8),
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_distance_topk_sweep(n, c, d, k, dtype):
    rng = np.random.default_rng(n + c)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32)).astype(dtype)
    r = jnp.asarray(rng.normal(size=(c, d)).astype(np.float32)).astype(dtype)
    d_ref, _ = distance_topk_ref(x, r, k)
    d_k, i_k = distance_topk(x, r, k, impl="pallas", interpret=True,
                             block_n=128, block_c=128)
    tol = 1e-4 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_ref),
                               rtol=tol, atol=tol)
    # ids must reproduce the distances (ties may reorder)
    xd = np.asarray(x, np.float32)
    rd = np.asarray(r, np.float32)
    d_from_ids = ((xd[:, None, :] - rd[np.asarray(i_k)]) ** 2).sum(-1)
    np.testing.assert_allclose(np.sort(d_from_ids, 1),
                               np.sort(np.asarray(d_ref), 1),
                               rtol=tol, atol=tol)


@pytest.mark.tier1
@pytest.mark.parametrize("seed", range(6))
def test_distance_topk_randomized_parity(seed):
    n, c, d, k, dtype, rng = _random_case(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32)).astype(dtype)
    r = jnp.asarray(rng.normal(size=(c, d)).astype(np.float32)).astype(dtype)
    d_ref, _ = distance_topk_ref(x, r, k)
    d_k, i_k = distance_topk(x, r, k, impl="pallas", interpret=True,
                             block_n=128, block_c=128)
    tol = 1e-4 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_ref),
                               rtol=tol, atol=tol)
    assert np.asarray(i_k).min() >= 0 and np.asarray(i_k).max() < c
    # returned ids must reproduce the returned distances (ties may reorder)
    xd = np.asarray(x, np.float32)
    rd = np.asarray(r, np.float32)
    d_from_ids = ((xd[:, None, :] - rd[np.asarray(i_k)]) ** 2).sum(-1)
    np.testing.assert_allclose(np.sort(d_from_ids, 1),
                               np.sort(np.asarray(d_ref), 1),
                               rtol=tol, atol=tol)


@pytest.mark.tier1
@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("n_reps,k", [(3, 8), (1, 4), (5, 16)])
def test_distance_topk_pad_columns_are_sentinels(impl, n_reps, k):
    """Regression: with fewer reps than k the padded columns used to tile
    the worst real *distance*, double-weighting that rep downstream.  They
    must now carry the PAD_DIST sentinel, with ids still in range, and the
    real columns must be untouched."""
    rng = np.random.default_rng(n_reps * 10 + k)
    x = jnp.asarray(rng.normal(size=(97, 24)).astype(np.float32))
    r = jnp.asarray(rng.normal(size=(n_reps, 24)).astype(np.float32))
    d_k, i_k = distance_topk(x, r, k, impl=impl, interpret=(impl == "pallas"),
                             block_n=64, block_c=64)
    d_k, i_k = np.asarray(d_k), np.asarray(i_k)
    assert d_k.shape == (97, k) and i_k.shape == (97, k)
    assert np.all(d_k[:, n_reps:] >= PAD_DIST)
    assert i_k.min() >= 0 and i_k.max() < n_reps
    d_ref, i_ref = distance_topk_ref(x, r, n_reps)
    np.testing.assert_allclose(d_k[:, :n_reps], np.asarray(d_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.tier1
def test_distance_topk_float16_pad_reps_stay_finite():
    """Regression: the padded-representative fill value (1e17) overflowed
    float16 to inf, and inf - inf in the distance expansion produced NaNs
    that *won* the top-k.  The fill is now clamped to the embedding dtype."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(70, 16)).astype(np.float16))
    r = jnp.asarray(rng.normal(size=(5, 16)).astype(np.float16))  # pads to 64
    d_k, i_k = distance_topk(x, r, 3, impl="pallas", interpret=True,
                             block_n=64, block_c=64)
    d_k = np.asarray(d_k)
    assert np.isfinite(d_k).all()
    assert np.asarray(i_k).max() < 5  # padded reps never win
    d_ref, _ = distance_topk_ref(x, r, 3)
    np.testing.assert_allclose(d_k, np.asarray(d_ref), rtol=5e-2, atol=5e-2)


@pytest.mark.tier1
@pytest.mark.parametrize("n,d", [(512, 64), (1000, 128), (130, 32)])
def test_fpf_update_sweep(n, d):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    rep = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    m0 = jnp.asarray(rng.uniform(0.5, 8, size=(n,)).astype(np.float32))
    nm_r, i_r, v_r = fpf_update_ref(x, rep, m0)
    nm_k, i_k, v_k = fpf_update(x, rep, m0, impl="pallas", interpret=True,
                                block_n=128)
    np.testing.assert_allclose(np.asarray(nm_k), np.asarray(nm_r), rtol=1e-5)
    assert abs(float(v_k) - float(v_r)) < 1e-4
    assert float(nm_r[int(i_k)]) == pytest.approx(float(v_r), abs=1e-4)


@pytest.mark.tier1
@pytest.mark.parametrize("seed", range(6))
def test_fpf_update_randomized_parity(seed):
    n, _, d, _, dtype, rng = _random_case(seed + 100)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32)).astype(dtype)
    rep = jnp.asarray(rng.normal(size=(d,)).astype(np.float32)).astype(dtype)
    m0 = jnp.asarray(rng.uniform(0.5, 8, size=(n,)).astype(np.float32))
    nm_r, i_r, v_r = fpf_update_ref(x, rep, m0)
    nm_k, i_k, v_k = fpf_update(x, rep, m0, impl="pallas", interpret=True,
                                block_n=128)
    tol = 1e-5 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(nm_k), np.asarray(nm_r),
                               rtol=tol, atol=tol)
    # the argmax value must match, and the returned index must attain it
    assert abs(float(v_k) - float(v_r)) < max(tol, 1e-4)
    assert float(nm_r[int(i_k)]) == pytest.approx(float(v_r), abs=max(tol, 1e-4))
    # new minima never exceed the old ones
    assert np.all(np.asarray(nm_k) <= np.asarray(m0) + tol)


@pytest.mark.slow
@pytest.mark.parametrize("b,s,skv,h,hk,hd,causal,window", [
    (2, 128, 128, 8, 4, 64, True, 0),
    (1, 128, 128, 4, 4, 128, True, 64),
    (2, 96, 96, 8, 2, 80, True, 0),
    (1, 64, 192, 4, 2, 64, False, 0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, skv, h, hk, hd, causal, window, dtype):
    rng = np.random.default_rng(s + h)
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32)).astype(dtype)
    k = jnp.asarray(rng.normal(size=(b, skv, hk, hd)).astype(np.float32)).astype(dtype)
    v = jnp.asarray(rng.normal(size=(b, skv, hk, hd)).astype(np.float32)).astype(dtype)
    o_ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    o_k = flash_attention(q, k, v, causal=causal, window=window,
                          impl="pallas", interpret=True,
                          block_q=64, block_k=64)
    tol = 2e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=tol, atol=tol)
