"""Multi-query session tests: joint planning, shared stratified samples,
broker-prefetched combined flushes, combined budgets, exact per-spec
accounting under dedup, and cracking mid-session."""
import numpy as np
import pytest

from repro.core import propagation
from repro.core.engine import QueryEngine, QuerySpec
from repro.core.index import TastiIndex
from repro.core.schema import make_workload
from repro.core.session import QuerySession, stratified_order


@pytest.fixture(scope="module")
def wl():
    return make_workload("night-street", n_frames=1500)


@pytest.fixture()
def make_engine(wl):
    index = TastiIndex.build(wl.features, 150, wl.target_dnn_batch, k=4,
                             random_fraction=0.0, seed=0)

    def _make(**kw):
        return QueryEngine(index, wl, **kw)

    return _make


# -- stratified order ------------------------------------------------------
def test_stratified_order_is_balanced_permutation():
    rng = np.random.default_rng(0)
    proxy = rng.normal(size=1000)
    order = stratified_order(proxy, n_strata=10, seed=1)
    np.testing.assert_array_equal(np.sort(order), np.arange(1000))
    ranks = np.argsort(np.argsort(proxy))
    strata = (ranks * 10) // 1000
    for m in (50, 100, 400):
        counts = np.bincount(strata[order[:m]], minlength=10)
        assert counts.max() - counts.min() <= 1, (m, counts)


def test_stratified_order_tiny_inputs():
    assert len(stratified_order(np.asarray([0.3]), n_strata=10)) == 1
    order = stratified_order(np.arange(5.0), n_strata=10)
    np.testing.assert_array_equal(np.sort(order), np.arange(5))


# -- accounting under dedup ------------------------------------------------
def test_record_labeled_in_spec_a_is_free_in_spec_b(make_engine):
    eng = make_engine()
    specs = [QuerySpec(kind="selection", score="score_has_object",
                       budget=150, seed=0),
             QuerySpec(kind="selection", score="score_has_object",
                       budget=150, seed=0)]
    out = QuerySession(eng, specs).execute()
    ra, rb = out.results
    assert ra.n_oracle_fresh > 0
    assert rb.n_oracle_fresh == 0          # identical sample: all free
    assert rb.n_oracle_cached == 150
    # counters stay exact under dedup + prefetch: every requested label is
    # either fresh-once or cached, per spec
    assert ra.n_oracle_fresh + ra.n_oracle_cached == 150
    assert out.stats["fresh_total"] == ra.n_oracle_fresh


def test_session_counters_match_engine_and_broker(make_engine):
    eng = make_engine()
    specs = [QuerySpec(kind="aggregation", score="score_count", err=0.1),
             QuerySpec(kind="selection", score="score_has_object",
                       budget=200, seed=1),
             QuerySpec(kind="limit", score="score_has_object", k_results=5)]
    out = QuerySession(eng, specs).execute()
    assert out.stats["fresh_total"] == sum(r.n_oracle_fresh
                                           for r in out.results)
    assert out.stats["cached_total"] == sum(r.n_oracle_cached
                                            for r in out.results)
    assert eng.broker.stats["fresh"] == out.stats["fresh_total"]
    assert eng.stats["label_fresh"] == out.stats["fresh_total"]
    # every result carries the session-level snapshot
    for i, r in enumerate(out.results):
        assert r.session["spec_index"] == i
        assert r.session["session_fresh_total"] == out.stats["fresh_total"]


def test_session_strictly_fewer_fresh_than_isolated(make_engine, wl):
    specs = [QuerySpec(kind="aggregation", score="score_has_object",
                       err=0.08, seed=0),
             QuerySpec(kind="aggregation", score="score_has_object",
                       err=0.05, seed=1),
             QuerySpec(kind="selection", score="score_has_object",
                       budget=300, seed=0),
             QuerySpec(kind="limit", score="score_has_object", k_results=5)]
    iso = [make_engine().execute(s) for s in specs]
    iso_fresh = sum(r.n_oracle_fresh for r in iso)
    out = QuerySession(make_engine(), specs).execute()
    assert out.stats["fresh_total"] < iso_fresh
    # answers stay faithful: aggregation estimates agree across modes
    assert abs(out.results[0].estimate - iso[0].estimate) < 0.1


def test_shared_stratified_sample_nests_aggregations(make_engine):
    eng = make_engine()
    specs = [QuerySpec(kind="aggregation", score="score_count", err=0.15,
                       seed=0),
             QuerySpec(kind="aggregation", score="score_count", err=0.05,
                       seed=7)]
    out = QuerySession(eng, specs).execute()
    a, b = (r.raw for r in out.results)
    small, large = sorted([set(a.sampled_ids.tolist()),
                           set(b.sampled_ids.tolist())], key=len)
    assert small <= large  # nested samples off the one shared order
    g = out.plan.groups[0]
    assert g.shared_order and len(out.plan.groups) == 1


def test_propagation_computed_once_per_mode_in_session(make_engine,
                                                       monkeypatch):
    eng = make_engine()
    calls = []
    orig = propagation.propagate_numeric

    def counting(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(propagation, "propagate_numeric", counting)
    specs = [QuerySpec(kind="aggregation", score="score_count", err=0.1),
             QuerySpec(kind="aggregation", score="score_count", err=0.05,
                       seed=3),
             QuerySpec(kind="selection", score="score_count", budget=100)]
    QuerySession(eng, specs).execute()
    assert len(calls) == 1  # one score fn, one numeric propagation


# -- combined budget -------------------------------------------------------
def test_combined_budget_caps_fresh_labels(make_engine):
    specs = [QuerySpec(kind="aggregation", score="score_count", err=0.001),
             QuerySpec(kind="selection", score="score_has_object",
                       budget=800, seed=2),
             QuerySpec(kind="limit", score="score_rare", k_results=10 ** 6)]
    budget = 400
    out = QuerySession(make_engine(), specs, budget=budget).execute()
    assert out.stats["fresh_total"] <= budget
    assert sum(out.plan.allocations) <= budget
    # the original specs are not mutated by the clamping
    assert specs[1].budget == 800 and specs[2].max_invocations == 0


def test_tiny_budget_never_overshoots(make_engine):
    # flooring allocations at one label each must not breach the cap
    specs = [QuerySpec(kind="selection", score="score_has_object",
                       budget=1000, seed=i) for i in range(5)]
    out = QuerySession(make_engine(), specs, budget=7).execute()
    assert sum(out.plan.allocations) <= 7
    assert out.stats["fresh_total"] <= 7
    with pytest.raises(ValueError, match="budget"):
        QuerySession(make_engine(), specs, budget=3).plan()


def test_crack_with_goes_through_broker(make_engine, wl, monkeypatch):
    eng = make_engine(max_oracle_batch=16)
    batches = []
    orig = wl.target_dnn_batch

    def spy(ids):
        batches.append(len(ids))
        return orig(ids)

    monkeypatch.setattr(wl, "target_dnn_batch", spy)
    added = eng.crack_with(np.arange(40))  # unlabeled: broker microbatches
    assert added > 0
    assert batches and max(batches) <= 16
    assert eng.broker.stats["fresh"] == 40
    assert eng.stats["label_fresh"] == 40


def test_budget_large_enough_leaves_specs_alone(make_engine):
    specs = [QuerySpec(kind="selection", score="score_has_object",
                       budget=100, seed=0)]
    out = QuerySession(make_engine(), specs, budget=10 ** 6).execute()
    assert out.results[0].n_invocations == 100


# -- cracking mid-session --------------------------------------------------
def test_crack_mid_session_invalidates_propagation_not_siblings(make_engine):
    eng = make_engine()
    version0 = eng.index.version
    specs = [QuerySpec(kind="aggregation", score="score_count", err=0.1,
                       crack=True),
             QuerySpec(kind="aggregation", score="score_count", err=0.1,
                       seed=5)]
    out = QuerySession(eng, specs, prefetch=False).execute()
    assert out.results[0].n_cracked > 0
    assert eng.index.version > version0
    assert out.stats["index_version_end"] > out.stats["index_version_start"]
    # the sibling spec re-propagated against the cracked index and stayed sane
    assert eng.stats["propagation_computes"] >= 2
    assert out.results[1].estimate is not None
    assert abs(out.results[1].estimate
               - float(np.mean(eng.workload.counts))) < 0.5


def test_prefetch_disabled_still_dedups(make_engine):
    eng = make_engine()
    specs = [QuerySpec(kind="selection", score="score_has_object",
                       budget=120, seed=0),
             QuerySpec(kind="selection", score="score_has_object",
                       budget=120, seed=0)]
    out = QuerySession(eng, specs, prefetch=False).execute()
    assert out.stats["prefetch_labels"] == 0
    assert out.results[1].n_oracle_fresh == 0


def test_reuse_labels_false_specs_skip_prefetch_and_pay_full(make_engine):
    eng = make_engine()
    specs = [QuerySpec(kind="selection", score="score_has_object",
                       budget=100, seed=0),
             QuerySpec(kind="selection", score="score_has_object",
                       budget=100, seed=0, reuse_labels=False)]
    out = QuerySession(eng, specs).execute()
    assert out.results[1].n_oracle_fresh == 100  # benchmark-fair accounting


def test_engine_routes_oracle_through_broker_microbatches(make_engine, wl,
                                                          monkeypatch):
    eng = make_engine(max_oracle_batch=16)
    batches = []
    orig = wl.target_dnn_batch

    def spy(ids):
        batches.append(len(ids))
        return orig(ids)

    monkeypatch.setattr(wl, "target_dnn_batch", spy)
    eng.execute(QuerySpec(kind="selection", score="score_has_object",
                          budget=100, seed=0))
    assert batches and max(batches) <= 16
    assert eng.broker.stats["batches"] == len(batches)


def test_empty_session_raises(make_engine):
    with pytest.raises(ValueError, match="no specs"):
        QuerySession(make_engine()).execute()
