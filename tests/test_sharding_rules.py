"""Sharding-rule invariants (run on 1 device; full-mesh coherence is proven by
the 512-device dry-run, experiments/dryrun/)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import lm
from repro.models.common import is_spec_leaf
from repro.parallel import sharding as shd


class FakeMesh:
    """Shape-only stand-in for the 16x16 production mesh (no devices)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("mesh", [MESH, MESH3], ids=["single", "multi"])
def test_param_pspecs_no_duplicates_and_divisible(arch, mesh):
    cfg = get_config(arch)
    specs = lm.model_specs(cfg)
    pspecs = shd.param_pspecs(specs, cfg, mesh)
    flat_s = jax.tree.leaves(specs, is_leaf=is_spec_leaf)
    flat_p = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)

    def size_of(axis):
        if isinstance(axis, tuple):
            n = 1
            for a in axis:
                n *= mesh.shape[a]
            return n
        return mesh.shape[axis]

    def members(axis):
        return axis if isinstance(axis, tuple) else (axis,)

    for s, p in zip(flat_s, flat_p):
        named = [m for a in p if a is not None for m in members(a)]
        assert len(named) == len(set(named)), (s, p)
        for dim, axis in zip(s.shape, p):
            if axis is not None:
                assert dim % size_of(axis) == 0, (s.shape, p)


@pytest.mark.parametrize("arch", ["jamba-1.5-large-398b", "qwen3-moe-30b-a3b"])
def test_big_models_are_tp_sharded(arch):
    cfg = get_config(arch)
    specs = lm.model_specs(cfg)
    pspecs = shd.param_pspecs(specs, cfg, mesh=MESH)
    flat_s = jax.tree.leaves(specs, is_leaf=is_spec_leaf)
    flat_p = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    # every big weight matrix must be sharded over 'model'
    for s, p in zip(flat_s, flat_p):
        n = 1
        for d in s.shape:
            n *= d
        if n >= 2 ** 24:
            assert "model" in [a for a in p if a is not None], (s.shape, p)


def test_moe_experts_on_model_axis():
    cfg = get_config("olmoe-1b-7b")
    specs = lm.model_specs(cfg)
    pspecs = shd.param_pspecs(specs, cfg, mesh=MESH)
    moe_spec = pspecs["blocks"][0]["moe"]["wi_gate"]  # (L, E, d, f)
    assert moe_spec[1] == "model"


def test_fsdp_adds_data_axis():
    cfg = get_config("jamba-1.5-large-398b")
    assert cfg.fsdp
    specs = lm.model_specs(cfg)
    pspecs = shd.param_pspecs(specs, cfg, mesh=MESH)
    attn = pspecs["blocks"][3]["attn"]["wq"]  # (L, d, qd)
    assert attn[1] in ("data", ("data",)) and attn[2] == "model"


def test_cache_specs_sequence_sharded():
    cfg = get_config("llama3.2-1b")
    cspecs = lm.cache_specs(cfg, batch=128, seq=32768)
    pspecs = shd.cache_pspecs(cspecs, cfg, MESH, global_batch=128)
    k_spec = pspecs[0]["k"]  # (R, B, S, Hk, hd)
    assert k_spec[1] == ("data",) or k_spec[1] == "data"
    assert k_spec[2] == "model"


def test_cache_specs_long_context_batch1():
    cfg = get_config("h2o-danube-3-4b")
    cspecs = lm.cache_specs(cfg, batch=1, seq=524288)
    pspecs = shd.cache_pspecs(cspecs, cfg, MESH, global_batch=1)
    k_spec = pspecs[0]["k"]
    # batch=1: sequence sharded over every available axis
    assert k_spec[2] == ("data", "model")


def test_batch_pspec_fallback_to_replicated():
    assert shd.batch_pspec(MESH, 1) == P(None, None)
    assert shd.batch_pspec(MESH, 256) == P(("data",), None)
    assert shd.batch_pspec(MESH3, 256) == P(("pod", "data"), None)
