"""Declarative query engine tests: spec -> plan -> execute for all three
query kinds, memoized propagation (computed once per score fn, invalidated by
cracking), the shared oracle-label cache, the cracking feedback loop, and the
spec JSON round-trip."""
import json
import subprocess
import sys

import numpy as np
import pytest

from repro.core import propagation
from repro.core.engine import QueryEngine, QuerySpec
from repro.core.index import TastiIndex
from repro.core.queries.registry import registered_kinds
from repro.core.schema import make_workload


@pytest.fixture(scope="module")
def wl():
    return make_workload("night-street", n_frames=1500)


@pytest.fixture()
def engine(wl):
    # raw features as embeddings: cheap, and the engine mechanics under test
    # are independent of embedder quality
    index = TastiIndex.build(wl.features, 150, wl.target_dnn_batch, k=4,
                             random_fraction=0.0, seed=0)
    return QueryEngine(index, wl)


def test_registry_has_paper_kinds():
    assert {"aggregation", "selection", "limit"} <= set(registered_kinds())


def test_all_three_kinds_execute(engine, wl):
    agg = engine.execute(QuerySpec(kind="aggregation", score="score_count",
                                   err=0.1))
    assert agg.estimate is not None
    assert agg.ci_half_width is not None
    assert 0 < agg.n_invocations <= len(wl.features)
    assert abs(agg.estimate - wl.counts.mean()) < 0.5

    sel = engine.execute(QuerySpec(kind="selection", score="score_has_object",
                                   budget=200))
    assert sel.selected is not None and sel.threshold is not None
    assert sel.n_invocations == 200

    lim = engine.execute(QuerySpec(kind="limit", score="score_has_object",
                                   k_results=5))
    assert lim.selected is not None
    assert len(lim.selected) == 5
    assert all(wl.counts[lim.selected] > 0)


def test_auto_propagation_per_kind(engine):
    assert engine.plan(QuerySpec(kind="aggregation", score="score_count")
                       ).propagation == "numeric"
    assert engine.plan(QuerySpec(kind="limit", score="score_rare",
                                 k_results=3)).propagation == "top1"
    sel_plan = engine.plan(QuerySpec(kind="selection", score="score_has_object",
                                     budget=10))
    assert sel_plan.propagation == "numeric" and sel_plan.clip01
    # explicit mode beats the kind default
    assert engine.plan(QuerySpec(kind="aggregation", score="score_count",
                                 propagation="top1")).propagation == "top1"


def test_propagation_computed_once_and_crack_invalidates(engine, monkeypatch):
    calls = []
    orig = propagation.propagate_numeric

    def counting(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(propagation, "propagate_numeric", counting)
    engine.execute(QuerySpec(kind="aggregation", score="score_count", err=0.1))
    engine.execute(QuerySpec(kind="aggregation", score="score_count", err=0.1,
                             seed=1))
    assert len(calls) == 1  # second query hit the memoized proxy
    assert engine.stats["proxy_cache_hits"] >= 1

    engine.crack_with(np.arange(20))
    engine.execute(QuerySpec(kind="aggregation", score="score_count", err=0.1))
    assert len(calls) == 2  # crack bumped the index version -> recompute


def test_label_cache_shared_across_queries(engine):
    r1 = engine.execute(QuerySpec(kind="selection", score="score_has_object",
                                  budget=150, seed=0))
    assert r1.n_oracle_fresh > 0
    # identical sampling -> every label served from the session cache
    r2 = engine.execute(QuerySpec(kind="selection", score="score_has_object",
                                  budget=150, seed=0))
    assert r2.n_oracle_fresh == 0
    assert r2.n_oracle_cached > 0
    # a *different* score function still reuses the cached annotations
    r3 = engine.execute(QuerySpec(kind="aggregation", score="score_count",
                                  err=0.1, seed=0))
    r4 = engine.execute(QuerySpec(kind="aggregation", score="score_mean_x",
                                  err=0.1, seed=0))
    assert r4.n_oracle_cached > 0
    # reuse_labels=False bypasses the cache for fair method comparisons
    r5 = engine.execute(QuerySpec(kind="selection", score="score_has_object",
                                  budget=150, seed=0, reuse_labels=False))
    assert r5.n_oracle_fresh == 150


def test_crack_feedback_loop(engine):
    n_reps_before = engine.index.n_reps
    version_before = engine.index.version
    res = engine.execute(QuerySpec(kind="aggregation", score="score_count",
                                   err=0.1, crack=True))
    assert res.n_cracked > 0
    assert engine.index.n_reps == n_reps_before + res.n_cracked
    assert engine.index.version > version_before
    # post-crack proxies cover the new reps: next query plans cleanly
    res2 = engine.execute(QuerySpec(kind="aggregation", score="score_count",
                                    err=0.1, seed=2))
    assert res2.estimate is not None


def test_engine_crack_default(wl):
    index = TastiIndex.build(wl.features, 100, wl.target_dnn_batch, k=4,
                             random_fraction=0.0, seed=0)
    eng = QueryEngine(index, wl, crack=True)
    res = eng.execute(QuerySpec(kind="selection", score="score_has_object",
                                budget=100))
    assert res.n_cracked > 0
    # spec-level opt-out beats the engine default
    res2 = eng.execute(QuerySpec(kind="selection", score="score_has_object",
                                 budget=100, seed=3, crack=False))
    assert res2.n_cracked == 0


def test_categorical_propagation_mode(engine, wl):
    cat = engine.proxy_scores("score_count", mode="categorical",
                              n_classes=int(wl.counts.max()) + 1)
    assert set(np.unique(cat)) <= set(range(int(wl.counts.max()) + 1))
    # reachable from a spec too
    plan = engine.plan(QuerySpec(kind="aggregation", score="score_count",
                                 propagation="categorical",
                                 n_classes=int(wl.counts.max()) + 1))
    assert plan.propagation == "categorical"
    with pytest.raises(ValueError, match="n_classes"):
        engine.plan(QuerySpec(kind="aggregation", score="score_count",
                              propagation="categorical"))


def test_proxy_override_skips_propagation(engine, wl, monkeypatch):
    def boom(*a, **kw):  # propagation must not run for external proxies
        raise AssertionError("propagation ran for an external proxy")

    monkeypatch.setattr(propagation, "propagate_numeric", boom)
    proxy = np.zeros(len(wl.features))
    res = engine.execute(QuerySpec(kind="aggregation", score="score_count",
                                   proxy=proxy, err=0.1, use_cv=False))
    assert res.plan.propagation == "external"
    assert res.estimate is not None


def test_plan_validation_errors(engine):
    with pytest.raises(KeyError, match="unknown query kind"):
        engine.plan(QuerySpec(kind="nope", score="score_count"))
    with pytest.raises(ValueError, match="budget"):
        engine.plan(QuerySpec(kind="selection", score="score_has_object"))
    with pytest.raises(ValueError, match="k_results"):
        engine.plan(QuerySpec(kind="limit", score="score_rare"))
    with pytest.raises(ValueError, match="score"):
        engine.execute(QuerySpec(kind="aggregation"))
    with pytest.raises(ValueError, match="scoring method"):
        engine.plan(QuerySpec(kind="aggregation", score="not_a_method"))


def test_spec_json_roundtrip():
    spec = QuerySpec(kind="selection", score="score_has_object", budget=300,
                     recall_target=0.95, seed=7)
    d = json.loads(json.dumps(spec.to_dict()))
    spec2 = QuerySpec.from_dict(d)
    assert spec2 == spec
    with pytest.raises(ValueError, match="unknown QuerySpec fields"):
        QuerySpec.from_dict({"kind": "limit", "k_results": 3, "typo": 1})
    with pytest.raises(ValueError, match="kind"):
        QuerySpec.from_dict({"score": "score_count"})
    # non-serializable specs fail loudly instead of silently changing meaning
    with pytest.raises(ValueError, match="proxy"):
        QuerySpec(kind="aggregation", score="score_count",
                  proxy=np.zeros(4)).to_dict()
    with pytest.raises(ValueError, match="string"):
        QuerySpec(kind="aggregation", score=lambda s: 0.0).to_dict()


def test_reexecuting_a_plan_does_not_mutate_it(engine):
    plan = engine.plan(QuerySpec(kind="aggregation", score="score_count",
                                 err=0.1, crack=True))
    trace_before = list(plan.trace)
    r1 = engine.execute(plan)
    r2 = engine.execute(plan)
    assert plan.trace == trace_before          # caller's plan untouched
    assert r1.plan.trace is not r2.plan.trace  # each result owns its trace
    assert sum("cracked" in t for t in r1.plan.trace) <= 1


def test_facade_shims_share_engine_caches(wl):
    from repro.core.embedder import EmbedderConfig
    from repro.core.pipeline import TastiSystem
    index = TastiIndex.build(wl.features, 100, wl.target_dnn_batch, k=4,
                             random_fraction=0.0, seed=0)
    sv = TastiSystem(index=index, workload=wl, embed_params=None,
                     ecfg=EmbedderConfig(feature_dim=wl.features.shape[1]),
                     variant="T")
    p1 = sv.proxy_scores(wl.score_count)
    p2 = sv.proxy_scores(wl.score_count)
    np.testing.assert_array_equal(p1, p2)
    assert sv.engine.stats["propagation_computes"] == 1
    assert sv.engine.stats["proxy_cache_hits"] == 1
    # categorical mode is reachable through the legacy facade too
    cat = sv.proxy_scores(wl.score_count, mode="categorical",
                          n_classes=int(wl.counts.max()) + 1)
    assert cat.shape == (len(wl.features),)
    # legacy crack_with invalidates the engine cache
    sv.crack_with(np.arange(10))
    _ = sv.proxy_scores(wl.score_count)
    assert sv.engine.stats["propagation_computes"] == 3  # numeric + cat + re-numeric


def test_query_cli_smoke(tmp_path):
    import os
    import pathlib
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    env = {**os.environ,
           "PYTHONPATH": src + os.pathsep + os.environ.get("PYTHONPATH", "")}
    cmd = [sys.executable, "-m", "repro.launch.query",
           "--workload", "night-street", "--n-frames", "800", "--quick",
           "--crack", "--save-index", str(tmp_path / "idx"),
           "--spec", '{"kind": "aggregation", "score": "score_count", "err": 0.2}',
           "--spec", '{"kind": "limit", "score": "score_has_object", "k_results": 3}']
    out = subprocess.run(cmd, capture_output=True, text=True, timeout=600,
                         env=env)
    assert out.returncode == 0, out.stderr
    body = json.loads(out.stdout)
    assert [r["kind"] for r in body["results"]] == ["aggregation", "limit"]
    assert body["results"][0]["estimate"] is not None
    assert (tmp_path / "idx.meta.json").exists()
