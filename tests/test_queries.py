"""Query-processing algorithm tests: EB aggregation coverage, SUPG recall
guarantees, limit-query behavior."""
import numpy as np

from repro.core.queries.aggregation import (aggregate_control_variates,
                                            eb_half_width)
from repro.core.queries.limit import limit_query
from repro.core.queries.selection import (achieved_recall,
                                          false_positive_rate,
                                          supg_recall_target)


def _toy(n=5000, rho=0.9, seed=0):
    rng = np.random.default_rng(seed)
    truth = rng.poisson(1.5, size=n).astype(float)
    noise = rng.normal(0, 1, size=n)
    proxy = rho * (truth - truth.mean()) / truth.std() + \
        np.sqrt(1 - rho ** 2) * noise
    proxy = proxy * truth.std() + truth.mean()
    return truth, proxy


def test_eb_aggregation_within_error():
    truth, proxy = _toy()
    res = aggregate_control_variates(
        proxy, lambda ids: truth[ids], err=0.05, delta=0.05, seed=1)
    assert abs(res.estimate - truth.mean()) <= 0.1  # CI is conservative
    assert res.n_invocations < len(truth)


def test_cv_beats_random_sampling_invocations():
    truth, proxy = _toy(rho=0.95)
    res_cv = aggregate_control_variates(
        proxy, lambda ids: truth[ids], err=0.05, seed=2)
    res_rnd = aggregate_control_variates(
        proxy, lambda ids: truth[ids], err=0.05, seed=2, use_cv=False)
    assert res_cv.n_invocations < res_rnd.n_invocations


def test_better_proxy_fewer_invocations():
    truth, good = _toy(rho=0.97, seed=3)
    _, bad = _toy(rho=0.3, seed=3)
    n_good = aggregate_control_variates(
        good, lambda ids: truth[ids], err=0.05, seed=4).n_invocations
    n_bad = aggregate_control_variates(
        bad, lambda ids: truth[ids], err=0.05, seed=4).n_invocations
    assert n_good < n_bad


def test_eb_half_width_shrinks():
    assert eb_half_width(1.0, 4.0, 1000, 0.05) < eb_half_width(1.0, 4.0, 100, 0.05)


def test_supg_meets_recall_target_whp():
    rng = np.random.default_rng(0)
    n = 4000
    truth = rng.uniform(size=n) < 0.15
    proxy = np.clip(truth * 0.7 + rng.uniform(0, 0.45, size=n), 0, 1)
    hits = 0
    trials = 10
    for s in range(trials):
        r = supg_recall_target(proxy, lambda ids: truth[ids].astype(float),
                               budget=500, recall_target=0.9, delta=0.05,
                               seed=s)
        if achieved_recall(r.selected, truth) >= 0.9:
            hits += 1
    assert hits >= 8  # 90% target at 95% confidence; allow MC slack


def test_supg_better_proxy_lower_fpr():
    rng = np.random.default_rng(1)
    n = 4000
    truth = rng.uniform(size=n) < 0.15
    sharp = np.clip(truth * 0.9 + rng.uniform(0, 0.1, size=n), 0, 1)
    blurry = np.clip(truth * 0.3 + rng.uniform(0, 0.7, size=n), 0, 1)
    f_sharp = np.mean([false_positive_rate(
        supg_recall_target(sharp, lambda i: truth[i].astype(float),
                           budget=500, seed=s).selected, truth)
        for s in range(5)])
    f_blurry = np.mean([false_positive_rate(
        supg_recall_target(blurry, lambda i: truth[i].astype(float),
                           budget=500, seed=s).selected, truth)
        for s in range(5)])
    assert f_sharp < f_blurry


def test_limit_query_exactness():
    rng = np.random.default_rng(2)
    n = 2000
    truth = np.zeros(n)
    truth[rng.choice(n, 20, replace=False)] = 1.0
    perfect = truth + rng.normal(0, 1e-6, n)
    res = limit_query(perfect, lambda ids: truth[ids], k_results=10, batch=4)
    assert len(res.found_ids) == 10
    assert res.n_invocations <= 12  # near-oracle ordering
    assert all(truth[res.found_ids] == 1.0)


def test_limit_query_trims_final_batch():
    """Regression: the scan must stop *counting* at the record that yields the
    Kth match, not at the end of its batch (pins the invocation count)."""
    n = 1000
    truth = np.zeros(n)
    proxy = -np.arange(n, dtype=float)     # scan order = 0, 1, 2, ...
    truth[:10] = 1.0                       # first 10 records all match
    res = limit_query(proxy, lambda ids: truth[ids], k_results=10, batch=4)
    assert res.n_invocations == 10         # was 12: full final batch counted
    assert len(res.found_ids) == 10
    np.testing.assert_array_equal(np.sort(res.found_ids), np.arange(10))
    # Kth match mid-batch with non-matches interleaved
    truth2 = np.zeros(n)
    truth2[[0, 2, 5]] = 1.0
    res2 = limit_query(proxy, lambda ids: truth2[ids], k_results=3, batch=4)
    assert res2.n_invocations == 6         # records 0..5 examined, not 8


def test_limit_query_respects_max_invocations():
    n = 100
    truth = np.zeros(n)
    proxy = -np.arange(n, dtype=float)
    res = limit_query(proxy, lambda ids: truth[ids], k_results=1, batch=16,
                      max_invocations=10)
    assert res.n_invocations == 10
    assert len(res.found_ids) == 0


def test_limit_query_bad_proxy_costs_more():
    rng = np.random.default_rng(3)
    n = 2000
    truth = np.zeros(n)
    truth[rng.choice(n, 20, replace=False)] = 1.0
    random_proxy = rng.uniform(size=n)
    res = limit_query(random_proxy, lambda ids: truth[ids], k_results=10)
    assert res.n_invocations > 200
