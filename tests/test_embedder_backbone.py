"""Embedder paths: MLP (paper-scale) and transformer backbone (pod-scale),
plus hypothesis properties for the kernels backing the index."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# optional dependency: skip (don't error collection) where it's absent, so
# the deterministic parity/property suites still gate tier-1
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.embedder import EmbedderConfig, embed, embed_all, init_embedder
from repro.kernels.distance_topk.ops import distance_topk
from repro.kernels.distance_topk.ref import distance_topk_ref
from repro.kernels.fpf_update.ref import fpf_update_ref


def test_mlp_embedder_shapes():
    cfg = EmbedderConfig(feature_dim=64, embed_dim=32)
    params = init_embedder(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (10, 64))
    e = embed(params, x, cfg)
    assert e.shape == (10, 32)
    assert bool(jnp.all(jnp.isfinite(e)))


def test_transformer_backbone_embedder():
    """The pod-scale path: features -> tokens -> tasti-embedder blocks ->
    mean-pool -> head (DESIGN.md §3)."""
    cfg = EmbedderConfig(feature_dim=64, embed_dim=32,
                         backbone="tasti-embedder", seq_tokens=8)
    params = init_embedder(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 64))
    e = embed(params, x, cfg)
    assert e.shape == (6, 32)
    assert bool(jnp.all(jnp.isfinite(e)))
    # batched host loop agrees with single call
    e2 = embed_all(params, np.asarray(x), cfg, batch=4)
    np.testing.assert_allclose(e2, np.asarray(e), rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(8, 80), c=st.integers(2, 40), d=st.integers(2, 24),
       k=st.integers(1, 6), seed=st.integers(0, 10 ** 6))
def test_distance_topk_properties(n, c, d, k, seed):
    """Property: results sorted ascending, ids valid, distances reproducible,
    and equal to the oracle (XLA impl — the kernel itself is swept in
    test_kernels.py with interpret mode)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    r = jnp.asarray(rng.normal(size=(c, d)).astype(np.float32))
    kk = min(k, c)
    dist, ids = distance_topk(x, r, kk, impl="xla")
    dist, ids = np.asarray(dist), np.asarray(ids)
    assert np.all(np.diff(dist, axis=1) >= -1e-5)          # sorted
    assert ids.min() >= 0 and ids.max() < c                # valid ids
    d_ref, _ = distance_topk_ref(x, r, kk)
    np.testing.assert_allclose(dist, np.asarray(d_ref), rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 100), d=st.integers(2, 16), seed=st.integers(0, 10 ** 6))
def test_fpf_update_properties(n, d, seed):
    """Property: new_min <= old_min elementwise, argmax consistent."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    rep = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    m0 = jnp.asarray(rng.uniform(0.1, 10, size=(n,)).astype(np.float32))
    new_min, idx, val = fpf_update_ref(x, rep, m0)
    assert bool(jnp.all(new_min <= m0 + 1e-6))
    assert float(new_min[int(idx)]) == pytest.approx(float(val), abs=1e-5)
    assert float(val) == pytest.approx(float(jnp.max(new_min)), abs=1e-5)
