"""Property tests for score propagation (paper §4.2), randomized over seeds.

These pin the algebraic contract every query kind leans on:

* numeric propagation is a convex combination — permutation-equivariant,
  bounded by [min, max] of the rep scores, exact for constant rep scores;
* top-1 propagation is strictly monotone in the nearest rep's score, with
  distance only ever breaking ties within one score level;
* the vectorized categorical vote matches a brute-force per-record count.

Plain numpy randomization (seed-parametrized) rather than hypothesis, so the
suite runs identically with or without the optional dependency.
"""
import numpy as np
import pytest

from repro.core.propagation import (propagate_categorical, propagate_numeric,
                                    propagate_top1, top1_tie_break_eps)
from repro.kernels.distance_topk.ops import PAD_DIST

pytestmark = pytest.mark.tier1

SEEDS = range(10)


def _random_instance(seed, n_classes=None):
    rng = np.random.default_rng(seed)
    c = int(rng.integers(3, 40))
    n = int(rng.integers(5, 300))
    k = int(rng.integers(1, min(c, 8) + 1))
    if n_classes is None:
        rep_scores = rng.normal(size=c) * rng.uniform(0.1, 10)
    else:
        rep_scores = rng.integers(0, n_classes, size=c).astype(np.float64)
    ids = rng.integers(0, c, size=(n, k))
    d2 = rng.uniform(0.0, 9.0, size=(n, k))
    d2.sort(axis=1)  # index layout: ascending like the real cache
    return rep_scores, ids, d2, rng


@pytest.mark.parametrize("seed", SEEDS)
def test_numeric_permutation_equivariant(seed):
    """Permuting the records permutes the output the same way (no cross-
    record coupling), and relabeling the reps consistently changes nothing."""
    rep_scores, ids, d2, rng = _random_instance(seed)
    out = propagate_numeric(rep_scores, ids, d2)

    perm = rng.permutation(len(ids))
    out_perm = propagate_numeric(rep_scores, ids[perm], d2[perm])
    np.testing.assert_allclose(out_perm, out[perm], rtol=1e-12)

    relabel = rng.permutation(len(rep_scores))  # new id of each old rep
    rep_scores2 = np.empty_like(rep_scores)
    rep_scores2[relabel] = rep_scores
    out_relabel = propagate_numeric(rep_scores2, relabel[ids], d2)
    np.testing.assert_allclose(out_relabel, out, rtol=1e-12)


@pytest.mark.parametrize("seed", SEEDS)
def test_numeric_bounded_by_rep_scores(seed):
    rep_scores, ids, d2, _ = _random_instance(seed)
    out = propagate_numeric(rep_scores, ids, d2)
    used = rep_scores[ids]
    # bounded per record by its own k reps, hence globally too
    assert np.all(out <= used.max(axis=1) + 1e-9)
    assert np.all(out >= used.min(axis=1) - 1e-9)


@pytest.mark.parametrize("seed", SEEDS)
def test_numeric_constant_scores_propagate_exactly(seed):
    _, ids, d2, rng = _random_instance(seed)
    const = float(rng.normal() * 5)
    rep_scores = np.full(ids.max() + 1, const)
    out = propagate_numeric(rep_scores, ids, d2)
    np.testing.assert_allclose(out, const, rtol=1e-12)


@pytest.mark.parametrize("seed", SEEDS)
def test_top1_strictly_monotone_distance_breaks_ties(seed):
    """If record i's nearest rep scores strictly higher than record j's, the
    propagated order must agree no matter the distances; within one score
    level the closer record ranks higher."""
    rep_scores, ids, d2, _ = _random_instance(seed)
    out = propagate_top1(rep_scores, ids, d2)
    base = rep_scores[ids[:, 0]]
    d = np.sqrt(d2[:, 0])
    order = np.argsort(base, kind="stable")
    for a, b in zip(order[:-1], order[1:]):
        # the tie-break nudge is < 1e-6, so monotonicity is guaranteed for
        # any score gap the scorers actually produce (integers / {0,1})
        if base[b] > base[a] + 1e-5:
            assert out[b] > out[a], (base[a], base[b])
    # ties: smaller distance wins (strictly, unless distances tie too)
    levels, inverse = np.unique(base, return_inverse=True)
    for lvl in range(len(levels)):
        members = np.where(inverse == lvl)[0]
        if len(members) < 2:
            continue
        md, mo = d[members], out[members]
        closer = np.argsort(md, kind="stable")
        assert np.all(np.diff(mo[closer]) <= 1e-15)


def test_top1_tie_break_never_crosses_score_levels():
    """The distance nudge must stay smaller than any score gap: a far record
    whose rep scores 1.0 still beats a near record whose rep scores
    1.0 - the smallest gap the scorer can produce at float32 scale."""
    rep_scores = np.array([1.0, 1.0 - 1e-4])
    ids = np.array([[0], [1]])
    d2 = np.array([[1e6], [0.0]])  # record 0 is *very* far from its rep
    out = propagate_top1(rep_scores, ids, d2)
    assert out[0] > out[1]


@pytest.mark.parametrize("gap", [1e-7, 1e-9, 1e-12])
def test_top1_tie_break_respects_sub_eps_gaps(gap):
    """Regression: a fixed 1e-6 perturbation used to flip distinct rep
    scores whose gap was below it (common for probability-valued scores).
    The scale now stays strictly below the smallest nonzero gap."""
    rep_scores = np.array([0.5, 0.5 - gap])
    ids = np.array([[0], [1]])
    d2 = np.array([[1e6], [0.0]])
    out = propagate_top1(rep_scores, ids, d2)
    assert out[0] > out[1], f"gap {gap} flipped by the distance nudge"
    assert top1_tie_break_eps(rep_scores) < gap


def test_top1_empty_index_no_crash():
    """Regression: d.max() raised on a zero-record index."""
    out = propagate_top1(np.array([1.0, 2.0]),
                         np.zeros((0, 1), np.int64), np.zeros((0, 1)))
    assert out.shape == (0,)


def test_top1_constant_scores_rank_by_distance():
    """All reps at one score level: eps falls back to the 1e-6 cap and
    distance alone orders the records."""
    rep_scores = np.array([3.0, 3.0, 3.0])
    ids = np.array([[0], [1], [2]])
    d2 = np.array([[4.0], [0.0], [1.0]])
    out = propagate_top1(rep_scores, ids, d2)
    assert out[1] > out[2] > out[0]


@pytest.mark.parametrize("seed", SEEDS)
def test_padded_columns_are_weightless(seed):
    """Regression: k > n_reps padding used to tile the worst real entry,
    silently double-weighting that rep.  Sentinel-distance columns must now
    leave every propagation mode unchanged."""
    rep_scores, ids, d2, rng = _random_instance(seed)
    pad_ids = np.concatenate([ids, ids[:, -1:]], axis=1)
    pad_d2 = np.concatenate([d2, np.full((len(ids), 1), PAD_DIST)], axis=1)
    np.testing.assert_allclose(propagate_numeric(rep_scores, pad_ids, pad_d2),
                               propagate_numeric(rep_scores, ids, d2),
                               rtol=1e-12)
    np.testing.assert_allclose(propagate_top1(rep_scores, pad_ids, pad_d2),
                               propagate_top1(rep_scores, ids, d2),
                               rtol=1e-12)
    cls_scores = np.floor(np.abs(rep_scores)) % 4
    np.testing.assert_array_equal(
        propagate_categorical(cls_scores, pad_ids, pad_d2, n_classes=4),
        propagate_categorical(cls_scores, ids, d2, n_classes=4))


@pytest.mark.parametrize("seed", SEEDS)
def test_categorical_matches_brute_force(seed):
    n_classes = int(np.random.default_rng(seed + 1000).integers(2, 9))
    rep_scores, ids, d2, _ = _random_instance(seed, n_classes=n_classes)
    out = propagate_categorical(rep_scores, ids, d2, n_classes=n_classes)

    # brute force: per record, per class, sum the weights of voting reps
    eps = 1e-6
    w = 1.0 / (np.sqrt(np.maximum(d2, 0.0)) + eps)
    cls = rep_scores[ids].astype(np.int64)
    expect = np.empty(len(ids), np.int64)
    for i in range(len(ids)):
        votes = np.zeros(n_classes)
        for j in range(ids.shape[1]):
            votes[cls[i, j]] += w[i, j]
        expect[i] = int(np.argmax(votes))
    np.testing.assert_array_equal(out, expect)


@pytest.mark.parametrize("seed", SEEDS)
def test_categorical_unanimous_vote_is_exact(seed):
    rng = np.random.default_rng(seed)
    n_classes = 5
    label = int(rng.integers(0, n_classes))
    rep_scores = np.full(7, float(label))
    ids = rng.integers(0, 7, size=(50, 3))
    d2 = rng.uniform(0, 4, size=(50, 3))
    out = propagate_categorical(rep_scores, ids, d2, n_classes=n_classes)
    np.testing.assert_array_equal(out, label)
