"""Cross-path consistency: decode replay == parallel forward; chunked SSM /
xLSTM forms == their sequential recurrences; blocked attention == full
softmax.  These pin the serving path to the training path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention, lm, mamba, xlstm
from repro.models.common import init_params


@pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen3-1.7b", "xlstm-350m",
                                  "jamba-1.5-large-398b", "olmoe-1b-7b"])
def test_decode_replay_matches_parallel_forward(arch):
    cfg = get_config(arch).smoke()
    b, s = 2, 16
    params = lm.init_model(cfg, jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens}
    logits_par = lm.lm_logits(params, batch, cfg)

    caches = lm.init_cache(cfg, b, s)
    logs = []
    for t in range(s):
        lg, caches = lm.decode_step(params, caches, tokens[:, t:t + 1],
                                    jnp.int32(t), cfg)
        logs.append(lg[:, 0])
    logits_seq = jnp.stack(logs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_seq),
                               np.asarray(logits_par), rtol=2e-2, atol=2e-2)


def test_blocked_attention_matches_full_softmax():
    cfg = get_config("llama3.2-1b").smoke()
    b, s, h, hd = 2, 64, cfg.n_heads, cfg.resolved_head_dim
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, s, h, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.n_kv_heads, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, cfg.n_kv_heads, hd))
    from repro.kernels.flash_attention.ref import flash_attention_ref
    out_blocked = attention.blocked_attention(q, k, v, cfg, causal=True)
    out_full = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out_blocked), np.asarray(out_full),
                               rtol=1e-4, atol=1e-4)


def test_blocked_attention_sliding_window():
    cfg = get_config("h2o-danube-3-4b").smoke()
    b, s, h, hd = 1, 128, cfg.n_heads, cfg.resolved_head_dim
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.n_kv_heads, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, cfg.n_kv_heads, hd))
    from repro.kernels.flash_attention.ref import flash_attention_ref
    w = cfg.sliding_window
    assert w and w < s
    out_b = attention.blocked_attention(q, k, v, cfg, causal=True, window=w)
    out_f = flash_attention_ref(q, k, v, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_f),
                               rtol=1e-4, atol=1e-4)


def test_mamba_chunked_equals_stepwise():
    cfg = get_config("jamba-1.5-large-398b").smoke()
    b, s = 2, 32
    params = init_params(mamba.mamba_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model),
                          jnp.float32) * 0.5
    y_par = mamba.mamba_fwd(params, x, cfg)
    conv = jnp.zeros((b, cfg.ssm_conv_width - 1, cfg.d_inner))
    h = jnp.zeros((b, cfg.d_inner, cfg.ssm_state_dim))
    ys = []
    for t in range(s):
        y, conv, h = mamba.mamba_decode(params, x[:, t:t + 1], conv, h, cfg)
        ys.append(y[:, 0])
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_par),
                               rtol=2e-3, atol=2e-3)


def test_mlstm_chunked_equals_stepwise():
    cfg = get_config("xlstm-350m").smoke()
    b, s = 2, 32
    params = init_params(xlstm.mlstm_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model),
                          jnp.float32) * 0.5
    y_par = xlstm.mlstm_fwd(params, x, cfg)
    hh = cfg.n_heads
    hd = cfg.mlstm_inner // hh
    c = jnp.zeros((b, hh, hd, hd))
    n = jnp.zeros((b, hh, hd))
    ys = []
    for t in range(s):
        y, c, n = xlstm.mlstm_decode(params, x[:, t:t + 1], c, n, cfg)
        ys.append(y[:, 0])
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_par),
                               rtol=3e-3, atol=3e-3)


def test_slstm_fwd_equals_stepwise():
    cfg = get_config("xlstm-350m").smoke()
    b, s = 2, 16
    params = init_params(xlstm.slstm_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model),
                          jnp.float32) * 0.5
    y_par = xlstm.slstm_fwd(params, x, cfg)
    state = tuple(jnp.zeros((b, cfg.d_model)) for _ in range(4))
    ys = []
    for t in range(s):
        y, state = xlstm.slstm_decode(params, x[:, t:t + 1], state, cfg)
        ys.append(y[:, 0])
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_par),
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_and_aux():
    from repro.models import moe
    cfg = get_config("olmoe-1b-7b").smoke()
    params = init_params(moe.moe_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    out, aux = moe.moe_fwd(params, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(aux)) and float(aux) >= 0


def test_two_tier_decode_matches_plain():
    """Two-tier (frozen main + ring) decode == plain decode: the prompt is
    replayed into the MAIN cache with the plain path, then decode steps use
    the ring for new tokens (§Perf decode hillclimb)."""
    import dataclasses
    cfg = get_config("phi3-medium-14b").smoke()
    b, s, extra = 2, 16, 6
    cfg_ring = dataclasses.replace(cfg, decode_ring=8)
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s + extra), 0,
                                cfg.vocab_size)
    # plain reference over prompt + decode
    caches_a = lm.init_cache(cfg, b, s + extra)
    for t in range(s + extra):
        logits_a, caches_a = lm.decode_step(params, caches_a,
                                            tokens[:, t:t + 1],
                                            jnp.int32(t), cfg)
    # two-tier: prompt into main (plain path, capacity exactly s), then ring
    caches_m = lm.init_cache(cfg, b, s)
    for t in range(s):
        _, caches_m = lm.decode_step(params, caches_m, tokens[:, t:t + 1],
                                     jnp.int32(t), cfg)
    caches_r = lm.init_cache(cfg_ring, b, s)
    caches_r = jax.tree.map(lambda r, m: r if r.shape not in
                            [x.shape for x in jax.tree.leaves(caches_m)]
                            else m, caches_r, caches_r)
    # graft main k/v from the plain prompt caches
    grafted = []
    for pos_i in range(len(cfg.pattern)):
        layer = dict(caches_r[pos_i])
        layer["k"] = caches_m[pos_i]["k"]
        layer["v"] = caches_m[pos_i]["v"]
        grafted.append(layer)
    caches_b = tuple(grafted)
    for t in range(s, s + extra):
        logits_b, caches_b = lm.decode_step(params, caches_b,
                                            tokens[:, t:t + 1],
                                            jnp.int32(t), cfg_ring)
    np.testing.assert_allclose(np.asarray(logits_b), np.asarray(logits_a),
                               rtol=2e-3, atol=2e-3)


def test_swa_decode_replay_matches_parallel():
    """Sliding-window decode masking where the window actually binds
    (seq > window): replay == parallel forward for h2o-danube."""
    import dataclasses
    cfg = dataclasses.replace(get_config("h2o-danube-3-4b").smoke(),
                              sliding_window=8, attn_block_q=8,
                              attn_block_k=8)
    b, s = 2, 24
    params = lm.init_model(cfg, jax.random.PRNGKey(3))
    tokens = jax.random.randint(jax.random.PRNGKey(4), (b, s), 0,
                                cfg.vocab_size)
    logits_par = lm.lm_logits(params, {"tokens": tokens}, cfg)
    caches = lm.init_cache(cfg, b, s)
    logs = []
    for t in range(s):
        lg, caches = lm.decode_step(params, caches, tokens[:, t:t + 1],
                                    jnp.int32(t), cfg)
        logs.append(lg[:, 0])
    logits_seq = jnp.stack(logs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_seq),
                               np.asarray(logits_par), rtol=2e-2, atol=2e-2)
