"""Property-based validation of the paper's §5 theory with hypothesis.

Theorem 1 (zero loss): if the embedding equals the schema metric coordinates
(zero triplet loss by construction) and clustering is dense (max intra-cluster
embedding distance < m), then for any K_Q-Lipschitz query loss the gap is at
most M*K_Q.

Also: the per-example triplet loss dominance of Lemma 3 and monotonicity
invariants of the propagation operator.
"""
import numpy as np
import pytest

# optional dependency: skip (don't error collection) where it's absent —
# tests/test_propagation_properties.py carries the seeded equivalents
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.propagation import propagate_numeric
from repro.core.triplet import population_triplet_loss


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(50, 200),
    k_q=st.floats(0.1, 5.0),
    seed=st.integers(0, 10 ** 6),
)
def test_theorem1_zero_loss_bound(n, k_q, seed):
    rng = np.random.default_rng(seed)
    # records live in a 2D metric space; embedding == identity (zero loss)
    x = rng.uniform(0, 1, size=(n, 2)).astype(np.float32)
    emb = x.copy()
    # dense clustering: reps on a grid with spacing -> max dist m
    g = 8
    gx, gy = np.meshgrid(np.linspace(0, 1, g), np.linspace(0, 1, g))
    reps = np.stack([gx.ravel(), gy.ravel()], 1).astype(np.float32)
    d2 = ((emb[:, None] - reps[None]) ** 2).sum(-1)
    nearest = d2.argmin(1)
    m_dist = np.sqrt(d2.min(1).max())          # max intra-cluster distance
    # K_Q-Lipschitz query: f(x) = k_q * x[0]; loss |f - fhat|
    f = k_q * x[:, 0]
    f_reps = k_q * reps[:, 0]
    fhat = f_reps[nearest]
    gap = np.abs(f - fhat).mean()
    # Thm 1: gap <= M * K_Q with M = the metric radius containing each
    # cluster; here d == embedding distance so M = m_dist.
    assert gap <= m_dist * k_q + 1e-6


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10 ** 6), margin=st.floats(0.1, 2.0))
def test_lemma3_hinge_dominates_indicator(seed, margin):
    rng = np.random.default_rng(seed)
    a, p, n = rng.normal(size=(3, 8))
    d_ap = np.abs(rng.normal())
    d_an = np.abs(rng.normal())
    hinge = max(0.0, margin + d_ap - d_an) / margin
    indicator = 1.0 if d_an <= d_ap else 0.0
    assert hinge >= indicator - 1e-12


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_propagation_convex_combination(seed):
    """Propagated numeric scores stay inside [min, max] of rep scores."""
    rng = np.random.default_rng(seed)
    c, n, k = 20, 100, 4
    rep_scores = rng.normal(size=c)
    ids = rng.integers(0, c, size=(n, k))
    d2 = rng.uniform(0.01, 4.0, size=(n, k))
    out = propagate_numeric(rep_scores, ids, d2)
    assert np.all(out <= rep_scores.max() + 1e-9)
    assert np.all(out >= rep_scores.min() - 1e-9)


def test_population_triplet_loss_zero_for_perfect_embedding():
    rng = np.random.default_rng(0)
    coords = rng.uniform(0, 10, size=(80, 2))

    def dist_fn(i, j):
        return float(np.linalg.norm(coords[i] - coords[j]))

    # embedding = coords scaled so that the margin is always cleared between
    # inside-ball and outside-ball pairs
    emb = coords * 10.0
    ids = np.arange(80)
    loss = population_triplet_loss(emb, dist_fn, ids, m_radius=1.0,
                                   margin=1.0, n_samples=400)
    assert loss < 0.05


def test_population_triplet_loss_positive_for_random_embedding():
    rng = np.random.default_rng(0)
    coords = rng.uniform(0, 10, size=(80, 2))
    emb = rng.normal(size=(80, 8))

    def dist_fn(i, j):
        return float(np.linalg.norm(coords[i] - coords[j]))

    ids = np.arange(80)
    loss = population_triplet_loss(emb, dist_fn, ids, m_radius=1.0,
                                   margin=1.0, n_samples=400)
    assert loss > 0.2
