"""OraclePool + broker reservation-scheme tests: sharded flushes must be
indistinguishable from the single-oracle path (labels, order, accounting),
survive flaky replicas by retrying sub-batches on survivors, and keep
in-flight dedup exact while labeling happens outside the broker lock."""
import threading
import time

import numpy as np
import pytest

from repro.core.broker import OracleBroker
from repro.core.oracle_pool import OraclePool, OraclePoolError

pytestmark = pytest.mark.tier1


class SpyOracle:
    """annotate(ids) -> [2*i]; thread-safe record of every batch."""

    def __init__(self, delay: float = 0.0):
        self.batches = []
        self.delay = delay
        self._lock = threading.Lock()

    def __call__(self, ids):
        ids = np.asarray(ids, np.int64)
        with self._lock:
            self.batches.append(ids.tolist())
        if self.delay:
            time.sleep(self.delay)
        return [int(i) * 2 for i in ids]

    @property
    def n_labeled(self):
        with self._lock:
            return sum(len(b) for b in self.batches)


class FlakyOracle:
    """Raises until ``heal()`` (or always, if never healed)."""

    def __init__(self, name="flaky"):
        self.name = name
        self.calls = 0
        self.ok = False

    def heal(self):
        self.ok = True

    def __call__(self, ids):
        self.calls += 1
        if not self.ok:
            raise RuntimeError(f"{self.name} replica is down")
        return [int(i) * 2 for i in ids]


# ---------------------------------------------------------------------------
# pool basics
# ---------------------------------------------------------------------------
def test_pool_labels_everything_in_request_order():
    spy = SpyOracle()
    with OraclePool(spy, n_replicas=3) as pool:
        broker = OracleBroker(spy, max_batch=16, pool=pool)
        a = broker.account("a")
        ids = np.arange(100)
        fut = broker.request(ids, account=a)
        assert broker.flush() == 100
        assert fut.result() == [2 * i for i in ids]
        # publish order == pending insertion order, replica count or not
        assert a.labeled == list(range(100))
        assert (a.fresh, a.cached) == (100, 0)
        assert broker.stats["fresh"] == 100
        assert spy.n_labeled == 100  # no id labeled twice
        assert pool.snapshot()["batches"] == len(spy.batches)


def test_size_aware_sharding_fans_small_flushes_out():
    pool = OraclePool(SpyOracle(), n_replicas=4, oversub=2)
    try:
        # 40 ids, max_batch 64: a single-oracle flush would be ONE batch;
        # the pool shards it so every replica has work (and stealing slack)
        assert pool.chunk_size(40, 64) == 5
        # large flushes stay microbatch-shaped
        assert pool.chunk_size(10_000, 64) == 64
    finally:
        pool.close()


def test_work_stealing_routes_around_a_slow_replica():
    slow, fast = SpyOracle(delay=0.05), SpyOracle()
    with OraclePool(replicas=[slow, fast], oversub=4) as pool:
        labels, batches = pool.run(np.arange(64), max_batch=8)
    assert labels == {i: 2 * i for i in range(64)}
    assert batches == 8
    # the fast replica stole most of the queue while the slow one slept
    assert len(fast.batches) > len(slow.batches)


def test_pool_rejects_bad_construction():
    with pytest.raises(ValueError, match="n_replicas"):
        OraclePool(SpyOracle(), n_replicas=0)
    with pytest.raises(ValueError, match="annotate"):
        OraclePool()
    pool = OraclePool(SpyOracle(), n_replicas=1)
    pool.close()
    pool.close()  # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        pool.run([1], max_batch=4)


# ---------------------------------------------------------------------------
# determinism: N replicas == single oracle, byte for byte
# ---------------------------------------------------------------------------
def _scripted_run(broker):
    """A workload mixing request/prefetch/fetch, dup ids, and cache hits."""
    a = broker.account("a")
    b = broker.account("b")
    broker.prefetch(np.arange(0, 40), account=a)
    broker.request(np.arange(30, 60), account=b)
    broker.flush()
    out = []
    out.append(broker.fetch(np.arange(0, 50), account=a))
    out.append(broker.fetch([5, 5, 70, 71], account=b))
    out.append(broker.request(np.arange(60, 80), account=b).result())
    stats = {k: broker.stats[k] for k in
             ("requests", "fresh", "cached", "dedup_inflight", "flushes",
              "prefetched")}
    accounts = [(x.name, x.fresh, x.cached, list(x.labeled)) for x in (a, b)]
    return out, stats, accounts


def test_sharded_path_identical_to_single_oracle():
    single = _scripted_run(OracleBroker(SpyOracle(), max_batch=16))
    for n in (2, 4):
        spy = SpyOracle()
        with OraclePool(spy, n_replicas=n) as pool:
            sharded = _scripted_run(
                OracleBroker(spy, max_batch=16, pool=pool))
        assert sharded[0] == single[0], f"labels differ at {n} replicas"
        assert sharded[1] == single[1], f"broker stats differ at {n} replicas"
        assert sharded[2] == single[2], f"accounts differ at {n} replicas"


def test_engine_results_identical_across_replica_counts():
    from repro.core.engine import QueryEngine, QuerySpec
    from repro.core.index import TastiIndex
    from repro.core.schema import make_workload
    from repro.core.session import QuerySession

    wl = make_workload("night-street", n_frames=400)
    index = TastiIndex.build(wl.features, 60, wl.target_dnn_batch, k=4,
                             random_fraction=0.0, seed=0)
    specs = [QuerySpec(kind="aggregation", score="score_count", err=0.2),
             QuerySpec(kind="selection", score="score_has_object",
                       budget=50),
             QuerySpec(kind="limit", score="score_has_object", k_results=3)]

    def run(replicas):
        engine = QueryEngine(index, wl, oracle_replicas=replicas)
        out = QuerySession(engine, list(specs)).execute()
        rows = [(r.kind, r.estimate,
                 None if r.selected is None else r.selected.tolist(),
                 r.n_invocations, r.n_oracle_fresh, r.n_oracle_cached)
                for r in out.results]
        stats = {k: out.stats[k] for k in
                 ("fresh_total", "cached_total", "prefetch_labels")}
        engine.close()
        return rows, stats

    base = run(1)
    assert run(3) == base


# ---------------------------------------------------------------------------
# fault injection: flaky replicas, full failure, rollback
# ---------------------------------------------------------------------------
def test_flaky_replica_retries_on_survivor_accounts_exact():
    bad = FlakyOracle()
    # slow survivors: the flaky replica definitely pulls (and fails) work
    # while they are busy, so the retry path really runs
    good = SpyOracle(delay=0.01)
    with OraclePool(replicas=[bad, good, good]) as pool:
        broker = OracleBroker(good, max_batch=8, pool=pool)
        a = broker.account("a")
        broker.request(np.arange(48), account=a)
        assert broker.flush() == 48
        assert (a.fresh, a.cached) == (48, 0)
        assert a.labeled == list(range(48))
        assert broker.fetch(np.arange(48), account=a) == \
            [2 * i for i in range(48)]
        assert (a.fresh, a.cached) == (48, 48)
        snap = pool.snapshot()
    assert bad.calls >= 1              # the flaky replica was really tried
    assert snap["failures"] == bad.calls
    assert snap["retries"] >= 1        # its sub-batches moved to survivors
    assert snap["per_replica"][0] == 0
    assert good.n_labeled == 48        # every id labeled exactly once


def test_all_replicas_down_rolls_reservation_back_then_recovers():
    bad = FlakyOracle()
    with OraclePool(replicas=[bad, bad]) as pool:
        broker = OracleBroker(bad, max_batch=8, pool=pool)
        a = broker.account("a")
        broker.request(np.arange(10), account=a)
        with pytest.raises(OraclePoolError, match="failed on all"):
            broker.flush()
        # rollback: nothing published, nothing charged, ids pending again
        assert broker.n_pending == 10
        assert broker.snapshot()["n_inflight"] == 0
        assert (a.fresh, a.cached) == (0, 0) and broker.stats["fresh"] == 0
        bad.heal()
        assert broker.flush() == 10
        assert (a.fresh, a.cached) == (10, 0)
        assert a.labeled == list(range(10))


# ---------------------------------------------------------------------------
# reservation scheme: dedup and blocking while labeling is lock-free
# ---------------------------------------------------------------------------
class GatedOracle:
    """Blocks inside annotate() until released; signals entry."""

    def __init__(self):
        self.entered = threading.Event()
        self.gate = threading.Event()
        self.batches = []

    def __call__(self, ids):
        self.entered.set()
        assert self.gate.wait(10), "test gate never released"
        self.batches.append([int(i) for i in ids])
        return [int(i) * 2 for i in ids]


def test_request_dedups_against_inflight_reservation():
    gated = GatedOracle()
    broker = OracleBroker(gated, max_batch=64)
    a = broker.account("a")
    b = broker.account("b")
    broker.request([1, 2, 3], account=a)
    flusher = threading.Thread(target=broker.flush)
    flusher.start()
    assert gated.entered.wait(10)
    # the flush is mid-labeling and the broker lock is FREE: a concurrent
    # request rides the in-flight reservation instead of re-enqueueing
    fut = broker.request([2, 3, 4], account=b)
    assert broker.stats["dedup_inflight"] == 2
    assert broker.n_pending == 1          # only id 4 is newly pending
    gated.gate.set()
    flusher.join(timeout=10)
    assert fut.result() == [4, 6, 8]      # drains id 4, waits for 2 and 3
    assert (a.fresh, a.cached) == (3, 0)
    assert (b.fresh, b.cached) == (1, 2)
    assert sum(len(x) for x in gated.batches) == 4  # 2,3 labeled once


def test_blocking_read_waits_for_another_threads_publish():
    gated = GatedOracle()
    broker = OracleBroker(gated, max_batch=64)
    fut = broker.request([7, 8])
    flusher = threading.Thread(target=broker.flush)
    flusher.start()
    assert gated.entered.wait(10)
    # everything this future needs is reserved by the flusher: result()
    # must wait for the publish, not re-label
    threading.Timer(0.2, gated.gate.set).start()
    assert fut.result() == [14, 16]
    flusher.join(timeout=10)
    assert broker.stats["fresh"] == 2 and broker.stats["batches"] == 1


def test_close_drains_inflight_run_instead_of_stranding_it():
    gated = GatedOracle()
    pool = OraclePool(gated, n_replicas=2)
    broker = OracleBroker(gated, max_batch=4, pool=pool)
    broker.request(np.arange(8))
    out = {}

    def run_flush():
        out["n"] = broker.flush()

    flusher = threading.Thread(target=run_flush)
    flusher.start()
    assert gated.entered.wait(10)
    closer = threading.Thread(target=pool.close)   # close mid-flush
    closer.start()
    gated.gate.set()
    flusher.join(timeout=10)
    closer.join(timeout=10)
    assert not flusher.is_alive() and not closer.is_alive()
    assert out["n"] == 8                  # the in-flight flush completed
    assert broker.fetch(np.arange(8)) == [2 * i for i in range(8)]

    # a NEW flush against the closed pool falls back to inline labeling
    gated.gate.set()
    broker.request([100, 101])
    assert broker.flush() == 2
    assert broker.cache[100] == 200


def test_engine_resize_replicas_between_sessions():
    from repro.core.engine import QueryEngine
    from repro.core.index import TastiIndex
    from repro.core.schema import make_workload
    wl = make_workload("night-street", n_frames=200)
    index = TastiIndex.build(wl.features, 30, wl.target_dnn_batch, k=2,
                             random_fraction=0.0, seed=0)
    engine = QueryEngine(index, wl, oracle_replicas=2)
    assert engine.broker.pool is engine.oracle_pool is not None
    first = engine.broker.fetch(np.arange(20))
    engine.set_oracle_replicas(4)          # old pool closed, new one attached
    assert engine.oracle_pool.n_replicas == 4
    assert engine.broker.pool is engine.oracle_pool
    assert engine.broker.fetch(np.arange(20)) == first  # cache intact
    engine.set_oracle_replicas(1)          # back to inline
    assert engine.oracle_pool is None and engine.broker.pool is None
    engine.close()


def test_injected_broker_gets_the_replica_pool():
    from repro.core.engine import QueryEngine
    from repro.core.index import TastiIndex
    from repro.core.schema import make_workload
    wl = make_workload("night-street", n_frames=200)
    index = TastiIndex.build(wl.features, 30, wl.target_dnn_batch, k=2,
                             random_fraction=0.0, seed=0)
    shared = OracleBroker(wl.target_dnn_batch, max_batch=16)
    engine = QueryEngine(index, wl, broker=shared, oracle_replicas=3)
    # the sharding knob must not be silently ignored on a shared broker
    assert shared.pool is engine.oracle_pool
    assert engine.oracle_pool.n_replicas == 3
    engine.close()
    assert shared.pool is None


def test_write_through_sees_one_ordered_stream_per_flush():
    spy = SpyOracle()
    with OraclePool(spy, n_replicas=4) as pool:
        broker = OracleBroker(spy, max_batch=8, pool=pool)
        flushes = []
        broker.on_fresh(lambda labeled: flushes.append(list(labeled)))
        broker.request(np.arange(64))
        broker.flush()
        broker.request(np.arange(64, 80))
        broker.flush()
    # one callback per flush, ids in pending order despite sharded labeling
    assert flushes == [list(range(64)), list(range(64, 80))]
