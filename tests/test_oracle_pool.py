"""OraclePool + broker reservation-scheme tests: sharded flushes must be
indistinguishable from the single-oracle path (labels, order, accounting),
survive flaky replicas by retrying sub-batches on survivors, and keep
in-flight dedup exact while labeling happens outside the broker lock.

The determinism/retry/rollback suite is parametrized over both replica
backends.  Process-backend caveat for the test doubles below: forked
children get *copies* of a parent-side oracle (its call counts, events, and
``heal()`` are invisible across the fork), so backend-parametrized tests
assert through broker outputs and ``pool.snapshot()`` (parent-side driver
stats), and thread-only asserts on the doubles are gated on the backend."""
import os
import signal
import threading
import time

import numpy as np
import pytest

from repro.core.broker import OracleBroker
from repro.core.oracle_pool import OraclePool, OraclePoolError

pytestmark = pytest.mark.tier1

BACKENDS = ("thread", "process")


class SpyOracle:
    """annotate(ids) -> [2*i]; thread-safe record of every batch."""

    def __init__(self, delay: float = 0.0):
        self.batches = []
        self.delay = delay
        self._lock = threading.Lock()

    def __call__(self, ids):
        ids = np.asarray(ids, np.int64)
        with self._lock:
            self.batches.append(ids.tolist())
        if self.delay:
            time.sleep(self.delay)
        return [int(i) * 2 for i in ids]

    @property
    def n_labeled(self):
        with self._lock:
            return sum(len(b) for b in self.batches)


class FlakyOracle:
    """Raises until ``heal()`` (or always, if never healed)."""

    def __init__(self, name="flaky"):
        self.name = name
        self.calls = 0
        self.ok = False

    def heal(self):
        self.ok = True

    def __call__(self, ids):
        self.calls += 1
        if not self.ok:
            raise RuntimeError(f"{self.name} replica is down")
        return [int(i) * 2 for i in ids]


# ---------------------------------------------------------------------------
# pool basics
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_pool_labels_everything_in_request_order(backend):
    spy = SpyOracle()
    with OraclePool(spy, n_replicas=3, backend=backend) as pool:
        broker = OracleBroker(spy, max_batch=16, pool=pool)
        a = broker.account("a")
        ids = np.arange(100)
        fut = broker.request(ids, account=a)
        assert broker.flush() == 100
        assert fut.result() == [2 * i for i in ids]
        # publish order == pending insertion order, replica count or not
        assert a.labeled == list(range(100))
        assert (a.fresh, a.cached) == (100, 0)
        assert broker.stats["fresh"] == 100
        snap = pool.snapshot()
        assert sum(snap["per_replica_ids"]) == 100  # no id labeled twice
        if backend == "thread":
            assert spy.n_labeled == 100
            assert snap["batches"] == len(spy.batches)


def test_size_aware_sharding_fans_small_flushes_out():
    pool = OraclePool(SpyOracle(), n_replicas=4, oversub=2)
    try:
        # 40 ids, max_batch 64: a single-oracle flush would be ONE batch;
        # the pool shards it so every replica has work (and stealing slack)
        assert pool.chunk_size(40, 64) == 5
        # large flushes stay microbatch-shaped
        assert pool.chunk_size(10_000, 64) == 64
    finally:
        pool.close()


def test_work_stealing_routes_around_a_slow_replica():
    slow, fast = SpyOracle(delay=0.05), SpyOracle()
    with OraclePool(replicas=[slow, fast], oversub=4) as pool:
        labels, batches = pool.run(np.arange(64), max_batch=8)
    assert labels == {i: 2 * i for i in range(64)}
    assert batches == 8
    # the fast replica stole most of the queue while the slow one slept
    assert len(fast.batches) > len(slow.batches)


def test_ewma_sizing_shrinks_slices_for_a_slow_replica():
    slow, fast = SpyOracle(delay=0.03), SpyOracle()
    with OraclePool(replicas=[slow, fast], oversub=2) as pool:
        base = pool.chunk_size(128, 32)
        for _ in range(5):
            labels, _ = pool.run(np.arange(128), max_batch=32)
            assert labels == {i: 2 * i for i in range(128)}
        snap = pool.snapshot()
    # the first run dispatches blind (no rates yet): full base-size slice
    assert len(slow.batches[0]) == base
    # once its labels/s EWMA is known the slow replica gets shrunken slices
    # instead of straggling a full one
    assert all(len(b) < base for b in slow.batches[1:])
    assert snap["per_replica_rate_ewma"][1] > snap["per_replica_rate_ewma"][0]
    assert snap["per_replica_ids"][0] < snap["per_replica_ids"][1]


def test_heterogeneous_max_batches_cap_replica_slices():
    # replica 0 is capacity-limited (max_batch 4); replica 1 takes full
    # flush-sized slices — a heterogeneous fleet in one pool
    small, big = SpyOracle(delay=0.01), SpyOracle()
    with OraclePool(replicas=[small, big], max_batches=[4, 64],
                    oversub=2) as pool:
        labels, _ = pool.run(np.arange(96), max_batch=24)
        assert labels == {i: 2 * i for i in range(96)}
        snap = pool.snapshot()
    assert max(len(b) for b in small.batches) <= 4
    assert snap["per_replica_max_slice"][0] <= 4
    assert 4 < snap["per_replica_max_slice"][1] <= 24


def test_pool_rejects_bad_construction():
    with pytest.raises(ValueError, match="n_replicas"):
        OraclePool(SpyOracle(), n_replicas=0)
    with pytest.raises(ValueError, match="annotate"):
        OraclePool()
    with pytest.raises(ValueError, match="backend"):
        OraclePool(SpyOracle(), n_replicas=2, backend="greenlet")
    with pytest.raises(ValueError, match="handoff"):
        OraclePool(SpyOracle(), n_replicas=2, handoff="shm")
    with pytest.raises(ValueError, match="max_batches"):
        OraclePool(SpyOracle(), n_replicas=2, max_batches=[4])
    with pytest.raises(ValueError, match="max_batches"):
        OraclePool(SpyOracle(), n_replicas=2, max_batches=[4, 0])
    pool = OraclePool(SpyOracle(), n_replicas=1)
    pool.close()
    pool.close()  # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        pool.run([1], max_batch=4)


# ---------------------------------------------------------------------------
# determinism: N replicas == single oracle, byte for byte
# ---------------------------------------------------------------------------
def _scripted_run(broker):
    """A workload mixing request/prefetch/fetch, dup ids, and cache hits."""
    a = broker.account("a")
    b = broker.account("b")
    broker.prefetch(np.arange(0, 40), account=a)
    broker.request(np.arange(30, 60), account=b)
    broker.flush()
    out = []
    out.append(broker.fetch(np.arange(0, 50), account=a))
    out.append(broker.fetch([5, 5, 70, 71], account=b))
    out.append(broker.request(np.arange(60, 80), account=b).result())
    stats = {k: broker.stats[k] for k in
             ("requests", "fresh", "cached", "dedup_inflight", "flushes",
              "prefetched")}
    accounts = [(x.name, x.fresh, x.cached, list(x.labeled)) for x in (a, b)]
    return out, stats, accounts


@pytest.mark.parametrize("backend", BACKENDS)
def test_sharded_path_identical_to_single_oracle(backend):
    single = _scripted_run(OracleBroker(SpyOracle(), max_batch=16))
    for n in (2, 4):
        spy = SpyOracle()
        with OraclePool(spy, n_replicas=n, backend=backend) as pool:
            sharded = _scripted_run(
                OracleBroker(spy, max_batch=16, pool=pool))
        assert sharded[0] == single[0], f"labels differ at {n} replicas"
        assert sharded[1] == single[1], f"broker stats differ at {n} replicas"
        assert sharded[2] == single[2], f"accounts differ at {n} replicas"


@pytest.mark.parametrize("backend", BACKENDS)
def test_engine_results_identical_across_replica_counts(backend):
    from repro.core.engine import QueryEngine, QuerySpec
    from repro.core.index import TastiIndex
    from repro.core.schema import make_workload
    from repro.core.session import QuerySession

    wl = make_workload("night-street", n_frames=400)
    index = TastiIndex.build(wl.features, 60, wl.target_dnn_batch, k=4,
                             random_fraction=0.0, seed=0)
    specs = [QuerySpec(kind="aggregation", score="score_count", err=0.2),
             QuerySpec(kind="selection", score="score_has_object",
                       budget=50),
             QuerySpec(kind="limit", score="score_has_object", k_results=3)]

    def run(replicas, backend="thread"):
        engine = QueryEngine(index, wl, oracle_replicas=replicas,
                             oracle_backend=backend)
        out = QuerySession(engine, list(specs)).execute()
        rows = [(r.kind, r.estimate,
                 None if r.selected is None else r.selected.tolist(),
                 r.n_invocations, r.n_oracle_fresh, r.n_oracle_cached)
                for r in out.results]
        stats = {k: out.stats[k] for k in
                 ("fresh_total", "cached_total", "prefetch_labels")}
        engine.close()
        return rows, stats

    base = run(1)
    assert run(3, backend=backend) == base


# ---------------------------------------------------------------------------
# fault injection: flaky replicas, full failure, rollback
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_flaky_replica_retries_on_survivor_accounts_exact(backend):
    bad = FlakyOracle()
    # slow survivors: the flaky replica definitely pulls (and fails) work
    # while they are busy, so the retry path really runs
    good = SpyOracle(delay=0.01)
    with OraclePool(replicas=[bad, good, good], backend=backend) as pool:
        broker = OracleBroker(good, max_batch=8, pool=pool)
        a = broker.account("a")
        broker.request(np.arange(48), account=a)
        assert broker.flush() == 48
        assert (a.fresh, a.cached) == (48, 0)
        assert a.labeled == list(range(48))
        assert broker.fetch(np.arange(48), account=a) == \
            [2 * i for i in range(48)]
        assert (a.fresh, a.cached) == (48, 48)
        snap = pool.snapshot()
    assert snap["failures"] >= 1       # the flaky replica was really tried
    assert snap["failures"] == snap["per_replica_failures"][0]
    assert snap["retries"] >= 1        # its sub-batches moved to survivors
    assert snap["per_replica"][0] == 0
    assert sum(snap["per_replica_ids"]) == 48  # every id labeled once
    if backend == "thread":
        assert bad.calls == snap["failures"]
        assert good.n_labeled == 48


@pytest.mark.parametrize("backend", BACKENDS)
def test_all_replicas_down_rolls_reservation_back_then_recovers(backend):
    bad = FlakyOracle()
    with OraclePool(replicas=[bad, bad], backend=backend) as pool:
        broker = OracleBroker(bad, max_batch=8, pool=pool)
        a = broker.account("a")
        broker.request(np.arange(10), account=a)
        with pytest.raises(OraclePoolError, match="failed on all"):
            broker.flush()
        # rollback: nothing published, nothing charged, ids pending again
        assert broker.n_pending == 10
        assert broker.snapshot()["n_inflight"] == 0
        assert (a.fresh, a.cached) == (0, 0) and broker.stats["fresh"] == 0
        if backend == "thread":
            bad.heal()      # heals the in-process replicas directly
        else:
            # forked children hold their own broken copies (heal() cannot
            # reach them): recovery means swapping in a healthy pool
            pool.close()
            broker.pool = pool = OraclePool(SpyOracle(), n_replicas=2,
                                            backend=backend)
        assert broker.flush() == 10
        assert (a.fresh, a.cached) == (10, 0)
        assert a.labeled == list(range(10))
        pool.close()


def _kill_own_process(ids):
    """A replica whose worker dies mid-call (crash injection)."""
    os.kill(os.getpid(), signal.SIGKILL)


def test_process_worker_crash_retried_on_survivors():
    # a slow survivor guarantees the doomed replica claims (and dies on) a
    # slice instead of the survivor racing through the whole flush first
    good = SpyOracle(delay=0.02)
    with OraclePool(replicas=[_kill_own_process, good],
                    backend="process") as pool:
        broker = OracleBroker(good, max_batch=8, pool=pool)
        a = broker.account("a")
        broker.request(np.arange(32), account=a)
        assert broker.flush() == 32
        assert (a.fresh, a.cached) == (32, 0)
        assert a.labeled == list(range(32))
        snap = pool.snapshot()
        assert snap["per_replica_alive"] == [False, True]
        assert snap["per_replica"][0] == 0
        assert snap["per_replica_failures"][0] == 1
        assert sum(snap["per_replica_ids"]) == 32
        # the pool keeps serving on the survivor alone
        broker.request(np.arange(32, 40), account=a)
        assert broker.flush() == 8
        assert a.labeled == list(range(40))
    assert all(not r.proc.is_alive() for r in pool._replicas)


def test_process_all_workers_dead_fails_flush_then_pool():
    with OraclePool(replicas=[_kill_own_process, _kill_own_process],
                    backend="process") as pool:
        with pytest.raises(OraclePoolError, match="failed on all"):
            pool.run(np.arange(8), max_batch=4)
        assert pool.snapshot()["per_replica_alive"] == [False, False]
        # later flushes fail fast instead of hanging on dead workers
        with pytest.raises(OraclePoolError, match="dead"):
            pool.run(np.arange(8), max_batch=4)


def test_process_close_reaps_children():
    pool = OraclePool(SpyOracle(), n_replicas=3, backend="process")
    labels, _ = pool.run(np.arange(30), max_batch=8)
    assert labels == {i: 2 * i for i in range(30)}
    pids = [r.proc.pid for r in pool._replicas]
    assert all(pid is not None for pid in pids)
    pool.close()
    assert all(not r.proc.is_alive() for r in pool._replicas)
    assert not os.path.isdir(pool._spool)  # npz spool dir cleaned up too
    pool.close()  # idempotent after reaping


def test_process_npz_handoff_roundtrips_object_labels():
    # Scene-like object annotations must come back type-exact through the
    # spool-file handoff (the pipe path is covered by the parity tests)
    def annotate(ids):
        return [{"id": int(i), "boxes": np.full((2, 4), float(i))}
                for i in ids]

    with OraclePool(annotate, n_replicas=2, backend="process",
                    handoff="npz") as pool:
        labels, _ = pool.run(np.arange(12), max_batch=4)
    assert sorted(labels) == list(range(12))
    assert labels[7]["id"] == 7
    assert labels[7]["boxes"].shape == (2, 4)
    assert float(labels[7]["boxes"][0, 0]) == 7.0


# ---------------------------------------------------------------------------
# reservation scheme: dedup and blocking while labeling is lock-free
# ---------------------------------------------------------------------------
class GatedOracle:
    """Blocks inside annotate() until released; signals entry."""

    def __init__(self):
        self.entered = threading.Event()
        self.gate = threading.Event()
        self.batches = []

    def __call__(self, ids):
        self.entered.set()
        assert self.gate.wait(10), "test gate never released"
        self.batches.append([int(i) for i in ids])
        return [int(i) * 2 for i in ids]


def test_request_dedups_against_inflight_reservation():
    gated = GatedOracle()
    broker = OracleBroker(gated, max_batch=64)
    a = broker.account("a")
    b = broker.account("b")
    broker.request([1, 2, 3], account=a)
    flusher = threading.Thread(target=broker.flush)
    flusher.start()
    assert gated.entered.wait(10)
    # the flush is mid-labeling and the broker lock is FREE: a concurrent
    # request rides the in-flight reservation instead of re-enqueueing
    fut = broker.request([2, 3, 4], account=b)
    assert broker.stats["dedup_inflight"] == 2
    assert broker.n_pending == 1          # only id 4 is newly pending
    gated.gate.set()
    flusher.join(timeout=10)
    assert fut.result() == [4, 6, 8]      # drains id 4, waits for 2 and 3
    assert (a.fresh, a.cached) == (3, 0)
    assert (b.fresh, b.cached) == (1, 2)
    assert sum(len(x) for x in gated.batches) == 4  # 2,3 labeled once


def test_blocking_read_waits_for_another_threads_publish():
    gated = GatedOracle()
    broker = OracleBroker(gated, max_batch=64)
    fut = broker.request([7, 8])
    flusher = threading.Thread(target=broker.flush)
    flusher.start()
    assert gated.entered.wait(10)
    # everything this future needs is reserved by the flusher: result()
    # must wait for the publish, not re-label
    threading.Timer(0.2, gated.gate.set).start()
    assert fut.result() == [14, 16]
    flusher.join(timeout=10)
    assert broker.stats["fresh"] == 2 and broker.stats["batches"] == 1


def test_close_drains_inflight_run_instead_of_stranding_it():
    gated = GatedOracle()
    pool = OraclePool(gated, n_replicas=2)
    broker = OracleBroker(gated, max_batch=4, pool=pool)
    broker.request(np.arange(8))
    out = {}

    def run_flush():
        out["n"] = broker.flush()

    flusher = threading.Thread(target=run_flush)
    flusher.start()
    assert gated.entered.wait(10)
    closer = threading.Thread(target=pool.close)   # close mid-flush
    closer.start()
    gated.gate.set()
    flusher.join(timeout=10)
    closer.join(timeout=10)
    assert not flusher.is_alive() and not closer.is_alive()
    assert out["n"] == 8                  # the in-flight flush completed
    assert broker.fetch(np.arange(8)) == [2 * i for i in range(8)]

    # a NEW flush against the closed pool falls back to inline labeling
    gated.gate.set()
    broker.request([100, 101])
    assert broker.flush() == 2
    assert broker.cache[100] == 200


def test_engine_resize_replicas_between_sessions():
    from repro.core.engine import QueryEngine
    from repro.core.index import TastiIndex
    from repro.core.schema import make_workload
    wl = make_workload("night-street", n_frames=200)
    index = TastiIndex.build(wl.features, 30, wl.target_dnn_batch, k=2,
                             random_fraction=0.0, seed=0)
    engine = QueryEngine(index, wl, oracle_replicas=2)
    assert engine.broker.pool is engine.oracle_pool is not None
    first = engine.broker.fetch(np.arange(20))
    engine.set_oracle_replicas(4)          # old pool closed, new one attached
    assert engine.oracle_pool.n_replicas == 4
    assert engine.broker.pool is engine.oracle_pool
    assert engine.broker.fetch(np.arange(20)) == first  # cache intact
    engine.set_oracle_replicas(1)          # back to inline
    assert engine.oracle_pool is None and engine.broker.pool is None
    engine.close()


def test_oracle_backend_threads_through_engine_and_session():
    from repro.core.engine import QueryEngine, QuerySpec
    from repro.core.index import TastiIndex
    from repro.core.schema import make_workload
    from repro.core.session import QuerySession
    wl = make_workload("night-street", n_frames=200)
    index = TastiIndex.build(wl.features, 30, wl.target_dnn_batch, k=2,
                             random_fraction=0.0, seed=0)
    engine = QueryEngine(index, wl, oracle_replicas=2,
                         oracle_backend="process")
    first = engine.broker.fetch(np.arange(20))  # lazily builds the pool
    assert engine.oracle_pool.backend == "process"
    # switching backend at the same replica count swaps the pool
    engine.set_oracle_replicas(2, backend="thread")
    assert engine.oracle_pool.backend == "thread"
    assert engine.oracle_backend == "thread"
    assert engine.broker.fetch(np.arange(20)) == first  # cache intact
    # a session's oracle_backend reaches the engine (and the target-DNN
    # Scene annotations survive the process boundary end to end)
    spec = QuerySpec(kind="selection", score="score_has_object", budget=20)
    out = QuerySession(engine, [spec], oracle_replicas=2,
                       oracle_backend="process").execute()
    assert out.results[0].kind == "selection"
    assert engine.oracle_pool.backend == "process"
    engine.close()


def test_injected_broker_gets_the_replica_pool():
    from repro.core.engine import QueryEngine
    from repro.core.index import TastiIndex
    from repro.core.schema import make_workload
    wl = make_workload("night-street", n_frames=200)
    index = TastiIndex.build(wl.features, 30, wl.target_dnn_batch, k=2,
                             random_fraction=0.0, seed=0)
    shared = OracleBroker(wl.target_dnn_batch, max_batch=16)
    engine = QueryEngine(index, wl, broker=shared, oracle_replicas=3)
    # the sharding knob must not be silently ignored on a shared broker
    assert shared.pool is engine.oracle_pool
    assert engine.oracle_pool.n_replicas == 3
    engine.close()
    assert shared.pool is None


def test_write_through_sees_one_ordered_stream_per_flush():
    spy = SpyOracle()
    with OraclePool(spy, n_replicas=4) as pool:
        broker = OracleBroker(spy, max_batch=8, pool=pool)
        flushes = []
        broker.on_fresh(lambda labeled: flushes.append(list(labeled)))
        broker.request(np.arange(64))
        broker.flush()
        broker.request(np.arange(64, 80))
        broker.flush()
    # one callback per flush, ids in pending order despite sharded labeling
    assert flushes == [list(range(64)), list(range(64, 80))]
