"""GPipe-style pipeline parallelism over the ``pod`` axis.

For the multi-pod mesh (pod=2, data=16, model=16) the ``pod`` axis can either
fold into data parallelism (default; only the gradient all-reduce crosses the
DCN) or act as a 2-stage pipeline: layer blocks are split across pods, and
microbatches stream through with ``collective_permute`` at the stage boundary
(the DCN then carries activations instead of gradients — preferable when
activations/microbatch < gradients/step, i.e. large models with small global
batches).

Implementation: ``shard_map`` over ``pod``; each stage runs its slice of the
scanned blocks; a ``lax.scan`` over microbatches overlaps stage i's compute on
microbatch m with stage i+1's on m-1 (the classic 1F1B-ish schedule collapses
to GPipe for 2 stages).  Exposed as ``pipeline_fwd`` for the forward pass;
training composes it with jax.grad as usual.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.common import PyTree


def split_blocks(params_blocks: PyTree, n_stages: int, stage: jax.Array):
    """Slice the stacked (R, ...) block params into this stage's (R/s, ...)."""
    def one(a):
        r = a.shape[0]
        per = r // n_stages
        return jax.lax.dynamic_slice_in_dim(a, stage * per, per, axis=0)
    return jax.tree.map(one, params_blocks)


def pipeline_fwd(block_apply: Callable[[PyTree, jax.Array], jax.Array],
                 params_blocks: PyTree, h: jax.Array, mesh: Mesh,
                 n_microbatches: int, axis: str = "pod") -> jax.Array:
    """h (B, S, D) -> (B, S, D) through all stages.

    ``block_apply(stage_params, h_micro)`` runs this stage's blocks on one
    microbatch.  Stages = mesh.shape[axis]; B % n_microbatches == 0.
    """
    from jax.experimental.shard_map import shard_map

    n_stages = mesh.shape[axis]
    b = h.shape[0]
    assert b % n_microbatches == 0

    def stage_fn(params_local, h_all):
        stage = jax.lax.axis_index(axis)
        my_params = split_blocks(params_local, n_stages, stage)
        micro = h_all.reshape(n_microbatches, b // n_microbatches,
                              *h_all.shape[1:])
        n_ticks = n_microbatches + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            buf, out = carry  # buf: stage input slot (mb, S, D)
            m_idx = jnp.clip(t, 0, n_microbatches - 1)
            incoming = jnp.where(stage == 0,
                                 micro[m_idx], buf)
            y = block_apply(my_params, incoming)
            # pass activations downstream
            buf_next = jax.lax.ppermute(y, axis, perm)
            # last stage collects its result for microbatch t-(n_stages-1)
            done_idx = t - (n_stages - 1)
            write = (stage == n_stages - 1) & (done_idx >= 0)
            out = jnp.where(
                write,
                jax.lax.dynamic_update_index_in_dim(
                    out, y, jnp.clip(done_idx, 0, n_microbatches - 1), 0),
                out)
            return (buf_next, out), None

        buf0 = jnp.zeros_like(micro[0])
        out0 = jnp.zeros_like(micro)
        (_, out), _ = jax.lax.scan(tick, (buf0, out0), jnp.arange(n_ticks))
        # broadcast final activations from the last stage to all stages
        # (masked psum: ppermute cannot fan out from a single source)
        out = jax.lax.psum(
            jnp.where(stage == n_stages - 1, out, jnp.zeros_like(out)), axis)
        return out.reshape(b, *h_all.shape[1:])

    fn = shard_map(stage_fn, mesh=mesh,
                   in_specs=(P(), P()), out_specs=P(),
                   check_rep=False)
    return fn(params_blocks, h)
