"""Logical-axis -> mesh-axis sharding rules (DP / TP / EP / SP / FSDP).

Every ParamSpec carries logical axis names; these rules translate them into
``PartitionSpec``s for a given config + role:

* TP: flattened head/ffn/expert/inner dims -> ``model``.
* EP: MoE expert dim -> ``model``.
* DP: batch -> ``("pod","data")`` (pod folds into data parallelism).
* FSDP: when ``cfg.fsdp`` (jamba-398B) or when serving a model whose
  model-sharded bf16 weights exceed the per-device budget, the ``embed``
  (d_model) dim additionally shards over ``data`` (ZeRO-3 semantics: XLA
  all-gathers per layer inside the scan).
* SP (decode): KV caches shard the *sequence* dim over ``model``
  (flash-decoding); SSM/xLSTM state shards channels over ``model``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec, PyTree, is_spec_leaf

HBM_BYTES_BUDGET = 12 * 2 ** 30  # leave headroom of 16 GB HBM for activations


def axis_rules(cfg: ModelConfig, mesh: Mesh, fsdp: Optional[bool] = None
               ) -> Dict[str, Optional[str]]:
    use_fsdp = cfg.fsdp if fsdp is None else fsdp
    if cfg.shard_strategy in ("pure_dp", "seq_dp", "ep_seq"):
        # weights replicated: all parallelism comes from the batch/sequence
        # dims (ZeRO-1 shards the *optimizer state* separately via
        # opt_pspecs).  ep_seq keeps ONLY the expert dim sharded (EP): the
        # MoE weights are the bulk of the parameters; everything else is
        # small enough to replicate, and attention goes sequence-parallel.
        rules = {k: None for k in ("vocab", "heads", "kv_heads", "mlp",
                                   "experts", "mamba_inner", "mlstm_inner",
                                   "mlstm_inner2", "embed", "layers", None)}
        if cfg.shard_strategy == "ep_seq":
            rules["experts"] = "model"
        return rules
    return {
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "experts": "model",
        "mamba_inner": "model",
        "mlstm_inner": "model",
        "mlstm_inner2": None,
        "embed": (tuple(a for a in ("pod", "data") if a in mesh.axis_names)
                  if use_fsdp and "data" in mesh.axis_names else None),
        "layers": None,
        None: None,
    }


def opt_pspecs(specs: PyTree, cfg: ModelConfig, mesh: Mesh) -> PyTree:
    """Optimizer-moment shardings.  megatron: same as params.  pure_dp:
    ZeRO-1 — shard each moment over 'model' on its largest divisible dim."""
    if cfg.shard_strategy not in ("pure_dp", "seq_dp", "ep_seq"):
        return param_pspecs(specs, cfg, mesh)
    m = mesh.shape.get("model", 1)

    def one(s: ParamSpec) -> P:
        axes = [None] * len(s.shape)
        dims = sorted(range(len(s.shape)), key=lambda i: -s.shape[i])
        for i in dims:
            if s.shape[i] % m == 0 and s.shape[i] >= m:
                axes[i] = "model"
                break
        return P(*axes)

    return jax.tree.map(one, specs, is_leaf=is_spec_leaf)


def _axis_size(mesh: Mesh, mesh_axis) -> int:
    if isinstance(mesh_axis, tuple):
        n = 1
        for a in mesh_axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[mesh_axis]


def _pspec_for(spec: ParamSpec, rules: Dict[str, Optional[str]],
               mesh: Mesh) -> P:
    axes = []
    used = set()  # each mesh axis may appear at most once per spec
    for dim, logical in zip(spec.shape, spec.logical_axes):
        mesh_axis = rules.get(logical)
        members = (mesh_axis if isinstance(mesh_axis, tuple)
                   else (mesh_axis,)) if mesh_axis else ()
        if (mesh_axis is not None and not (set(members) & used)
                and dim % _axis_size(mesh, mesh_axis) == 0):
            axes.append(mesh_axis)
            used.update(members)
        else:
            axes.append(None)
    return P(*axes)


def param_pspecs(specs: PyTree, cfg: ModelConfig, mesh: Mesh,
                 fsdp: Optional[bool] = None) -> PyTree:
    rules = axis_rules(cfg, mesh, fsdp)
    return jax.tree.map(lambda s: _pspec_for(s, rules, mesh), specs,
                        is_leaf=is_spec_leaf)


def param_shardings(specs: PyTree, cfg: ModelConfig, mesh: Mesh,
                    fsdp: Optional[bool] = None) -> PyTree:
    return jax.tree.map(lambda p: NamedSharding(mesh, p),
                        param_pspecs(specs, cfg, mesh, fsdp))


def serve_needs_fsdp(cfg: ModelConfig, mesh: Mesh) -> bool:
    """Shard serving weights over data too when model-only TP does not fit."""
    bytes_per_dev = (cfg.param_count() * jnp.dtype(cfg.param_dtype).itemsize
                     / mesh.shape.get("model", 1))
    return bytes_per_dev > HBM_BYTES_BUDGET


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_pspec(mesh: Mesh, global_batch: int, extra_dims: int = 1,
                strategy: str = "megatron") -> P:
    axes = batch_axes(mesh)
    if strategy == "pure_dp" and "model" in mesh.axis_names:
        wide = axes + ("model",)
        n = 1
        for a in wide:
            n *= mesh.shape[a]
        if global_batch % n == 0:
            return P(wide, *([None] * extra_dims))
        # fall through to the narrower batch axes
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if axes and global_batch % n == 0:
        return P(axes, *([None] * extra_dims))
    return P(*([None] * (1 + extra_dims)))


# ---------------------------------------------------------------------------
# Decode cache shardings (SP)
# ---------------------------------------------------------------------------

_CACHE_SEQ_FIELDS = {"k", "v", "cross_k", "cross_v"}  # (R, B, S, Hk, hd)


def cache_pspecs(cache_specs: PyTree, cfg: ModelConfig, mesh: Mesh,
                 global_batch: int) -> PyTree:
    """Shard attention caches (R,B,S,Hk,hd): B over data, S over model; SSM and
    xLSTM channel states over model; long-context batch=1 shards S over both.
    """
    d_axes = batch_axes(mesh)
    dsize = 1
    for a in d_axes:
        dsize *= mesh.shape[a]
    b_ok = d_axes and global_batch % dsize == 0
    msize = mesh.shape.get("model", 1)

    def one(path, s: jax.ShapeDtypeStruct):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shape = s.shape
        if name in ("ring_k", "ring_v"):
            # recent-token ring (two-tier decode): batch over data; head_dim
            # over model where divisible (writes stay local; the score
            # contraction psums a tiny (B,H,1,W) tensor)
            axes = [None] * len(shape)
            if b_ok:
                axes[1] = d_axes
            if shape[-1] % msize == 0:
                axes[-1] = "model"
            return P(*axes)
        if name in _CACHE_SEQ_FIELDS:
            seq = shape[2]
            if b_ok:
                seq_axis = "model" if seq % msize == 0 else None
                return P(None, d_axes, seq_axis, None, None)
            # batch=1 long-context: sequence over every axis we have
            all_ax = tuple(d_axes) + ("model",)
            if seq % (dsize * msize) == 0:
                return P(None, None, all_ax, None, None)
            return P(None, None, "model" if seq % msize == 0 else None,
                     None, None)
        # SSM / xLSTM states: channel dims over model where divisible
        axes = [None] * len(shape)
        if b_ok:
            axes[1] = d_axes
        for i in range(2, len(shape)):
            if shape[i] % msize == 0 and "model" not in axes:
                axes[i] = "model"
                break
        return P(*axes)

    # jax.tree.map_with_path only exists on newer jax; the tree_util
    # spelling works everywhere
    return jax.tree_util.tree_map_with_path(one, cache_specs)


def cache_shardings(cache_specs: PyTree, cfg: ModelConfig, mesh: Mesh,
                    global_batch: int) -> PyTree:
    return jax.tree.map(lambda p: NamedSharding(mesh, p),
                        cache_pspecs(cache_specs, cfg, mesh, global_batch))
