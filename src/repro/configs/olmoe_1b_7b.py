"""OLMoE-1B-7B  [arXiv:2409.02060; hf].

16L, d=2048, 16H (kv=16), vocab=50304; MoE every layer: 64 experts, top-8,
expert hidden 1024 (the listed d_ff is the per-expert width).
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    pattern=(LayerSpec(mixer="attn", mlp="moe"),),
    n_experts=64,
    top_k=8,
    moe_d_ff=1024,
    rope_theta=10000.0,
    qk_norm=True,
)
