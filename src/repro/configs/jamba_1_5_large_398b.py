"""Jamba-1.5-Large (398B total / ~94B active)  [arXiv:2403.19887; hf].

Hybrid Mamba+attention 1:7 interleave (one attention layer per 8-layer block),
MoE (16 experts, top-2) every second layer.  72L, d=8192, 64H (GQA kv=8),
d_ff=24576, vocab=65536.
"""
from repro.configs.base import LayerSpec, ModelConfig

_PERIOD = tuple(
    LayerSpec(mixer=("attn" if i == 3 else "mamba"),
              mlp=("moe" if i % 2 == 1 else "dense"))
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    pattern=_PERIOD,
    n_experts=16,
    top_k=2,
    moe_d_ff=24576,
    rope_theta=10000.0,
    ssm_state_dim=16,
    ssm_expand=2,
    ssm_chunk=256,
    # 398B params: bf16 optimizer moments + fsdp sharding over (pod,data) are
    # required to fit 16 GB/chip HBM (see EXPERIMENTS.md §Dry-run).
    fsdp=True,
    opt_state_dtype="bfloat16",
)
