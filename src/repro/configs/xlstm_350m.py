"""xLSTM-350M  [arXiv:2405.04517].

24L, d=1024, 4 heads, vocab=50304, d_ff=0 (xLSTM blocks carry their own
projections).  7:1 mLSTM:sLSTM interleave per the paper's xLSTM[7:1] recipe.
"""
from repro.configs.base import LayerSpec, ModelConfig

_PERIOD = tuple(
    LayerSpec(mixer=("slstm" if i == 7 else "mlstm"), mlp="none")
    for i in range(8)
)

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    pattern=_PERIOD,
    xlstm_mlstm_expand=2,
    ssm_chunk=128,
)
