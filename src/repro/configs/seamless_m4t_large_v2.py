"""SeamlessM4T-large-v2 backbone  [arXiv:2308.11596; hf].

Encoder-decoder, 24L each, d=1024, 16H (kv=16), d_ff=8192, vocab=256206.
Audio frontend is a stub per the assignment: the encoder consumes precomputed
frame embeddings.  Context shapes split enc/dec 50/50 (DESIGN.md §6).
vocab 256206 is padded to 256256 (multiple of 256) for TP divisibility.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    encoder_decoder=True,
    n_encoder_layers=24,
    audio_frontend=True,
    rope_theta=10000.0,
)
