"""H2O-Danube3-4B  [arXiv:2401.16818 lineage].

24L, d=3840, 32H (GQA kv=8), d_ff=10240, vocab=32000; llama+mistral mix with
sliding-window attention (window 4096) -> sub-quadratic, runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    rope_theta=10000.0,
    sliding_window=4096,
)
