"""Config registry: ``get_config(name)`` / ``list_archs()``.

One module per assigned architecture (exact published config) plus the paper's
own TASTI embedder backbone.  Smoke variants via ``get_config(name).smoke()``.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (SHAPE_BY_NAME, SHAPES, LayerSpec, ModelConfig,
                                ShapeConfig, cell_is_runnable)
from repro.configs.h2o_danube3_4b import CONFIG as _danube
from repro.configs.jamba_1_5_large_398b import CONFIG as _jamba
from repro.configs.llama3_2_1b import CONFIG as _llama
from repro.configs.olmoe_1b_7b import CONFIG as _olmoe
from repro.configs.phi3_medium_14b import CONFIG as _phi3
from repro.configs.qwen2_vl_7b import CONFIG as _qwen2vl
from repro.configs.qwen3_1_7b import CONFIG as _qwen3
from repro.configs.qwen3_moe_30b_a3b import CONFIG as _qwen3moe
from repro.configs.seamless_m4t_large_v2 import CONFIG as _seamless
from repro.configs.tasti_embedder import CONFIG as _tasti_embedder
from repro.configs.xlstm_350m import CONFIG as _xlstm

_REGISTRY: Dict[str, ModelConfig] = {c.name: c for c in [
    _jamba, _llama, _phi3, _qwen3, _danube, _qwen2vl, _xlstm, _seamless,
    _olmoe, _qwen3moe, _tasti_embedder,
]}

ASSIGNED_ARCHS: List[str] = [
    "jamba-1.5-large-398b", "llama3.2-1b", "phi3-medium-14b", "qwen3-1.7b",
    "h2o-danube-3-4b", "qwen2-vl-7b", "xlstm-350m", "seamless-m4t-large-v2",
    "olmoe-1b-7b", "qwen3-moe-30b-a3b",
]


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> List[str]:
    return list(ASSIGNED_ARCHS)


__all__ = ["get_config", "list_archs", "ASSIGNED_ARCHS", "SHAPES",
           "SHAPE_BY_NAME", "ModelConfig", "ShapeConfig", "LayerSpec",
           "cell_is_runnable"]
