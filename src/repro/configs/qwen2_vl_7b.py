"""Qwen2-VL-7B backbone  [arXiv:2409.12191; hf].

28L, d=3584, 28H (GQA kv=4), d_ff=18944, vocab=152064, M-RoPE.  The vision
frontend is a stub per the assignment: ``input_specs`` provides 256 precomputed
patch embeddings on a 16x16 grid, merged into the first sequence positions.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    rope_theta=1000000.0,
    mrope_sections=(16, 24, 24),  # half-dim units, sum = head_dim//2
    vision_tokens=256,
    vision_grid=(16, 16),
)
