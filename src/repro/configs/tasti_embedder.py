"""The paper's embedding DNN as a TPU-native backbone.

Stands in for ResNet-18 / BERT (paper §6.1): a small transformer encoder over
record features; ``repro.core.embedder`` adds the projection head (embedding
size 128, paper default).  Runs at ~4000x fewer FLOPs per record than the
jamba-as-target-DNN, mirroring the paper's 3 fps vs 12,000 fps cost ratio.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tasti-embedder",
    family="dense",
    n_layers=4,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    head_dim=64,
    d_ff=1024,
    vocab_size=512,   # unused for continuous records; kept for LM pretraining
    rope_theta=10000.0,
    attn_block_q=128,
    attn_block_k=128,
    dtype="float32",
    param_dtype="float32",
    remat="none",
)
