"""Model / shape / mesh configuration dataclasses.

Every assigned architecture is expressed as a :class:`ModelConfig`.  The unified
model in ``repro.models.lm`` consumes these directly; nothing below imports jax
so configs are importable everywhere (including before device initialization in
``launch/dryrun.py``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class LayerSpec:
    """One position inside the repeating block pattern.

    mixer: "attn" | "mamba" | "mlstm" | "slstm"
    mlp:   "dense" | "moe" | "none"
    """

    mixer: str = "attn"
    mlp: str = "dense"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- repeating layer pattern (len(pattern) divides n_layers) ---
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden size (0 -> d_ff)
    capacity_factor: float = 1.25
    moe_group_size: int = 256  # tokens per dispatch group (GShard-style)
    router_aux_weight: float = 0.01

    # --- attention ---
    rope_theta: float = 10000.0
    qk_norm: bool = False
    sliding_window: int = 0  # 0 = full attention
    mrope_sections: Tuple[int, int, int] = ()  # M-RoPE (qwen2-vl); empty = off
    attn_block_q: int = 512  # blocked-attention tile sizes (XLA path)
    attn_block_k: int = 512

    # --- encoder-decoder (seamless) ---
    encoder_decoder: bool = False
    n_encoder_layers: int = 0

    # --- SSM (mamba) ---
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_dt_rank: int = 0  # 0 -> ceil(d_model / 16)

    # --- xLSTM ---
    xlstm_mlstm_expand: int = 2
    xlstm_slstm_proj: float = 4.0 / 3.0

    # --- modality frontend stubs ---
    vision_tokens: int = 0  # qwen2-vl: number of precomputed patch embeddings
    vision_grid: Tuple[int, int] = (16, 16)
    audio_frontend: bool = False  # seamless: encoder input = frame embeddings

    # --- numerics / training ---
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    tie_embeddings: bool = False
    vocab_pad_multiple: int = 256
    remat: str = "full"  # none | full (per-block jax.checkpoint)
    opt_state_dtype: str = "float32"
    fsdp: bool = False  # additionally shard params/opt-state over data axis
    # parallelism strategy (see parallel/sharding.py):
    #   megatron: TP over 'model' (baseline);
    #   pure_dp:  batch over (data x model), weights replicated, ZeRO-1 opt;
    #   seq_dp:   batch over data + sequence over 'model', weights replicated
    shard_strategy: str = "megatron"
    unroll_layers: bool = False  # validation: Python-loop layers (no scan)
    decode_cache_update: str = "masked"  # masked (ring where) | dus
    # two-tier decode cache: >0 = frozen main cache + ring of this many recent
    # tokens; per-step writes touch only the ring (decode hillclimb, §Perf)
    decode_ring: int = 0
    logit_softcap: float = 0.0

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_multiple)

    @property
    def n_repeats(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern period {len(self.pattern)}")
        return self.n_layers // len(self.pattern)

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or max(1, math.ceil(self.d_model / 16))

    @property
    def mlstm_inner(self) -> int:
        return self.xlstm_mlstm_expand * self.d_model

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.resolved_head_dim
        qd, kvd = self.n_heads * hd, self.n_kv_heads * hd
        total = self.padded_vocab * d  # embedding
        if not self.tie_embeddings:
            total += self.padded_vocab * d  # unembed

        def attn_params() -> int:
            return d * qd + 2 * d * kvd + qd * d

        def dense_mlp(dff: int) -> int:
            return 3 * d * dff

        def moe_mlp() -> int:
            dff = self.moe_d_ff or self.d_ff
            return self.n_experts * 3 * d * dff + d * self.n_experts

        def mamba_params() -> int:
            di, n, dtr = self.d_inner, self.ssm_state_dim, self.dt_rank
            return (d * 2 * di + di * self.ssm_conv_width
                    + di * (dtr + 2 * n) + dtr * di + di * n + di + di * d)

        def mlstm_params() -> int:
            di = self.mlstm_inner
            return (d * 2 * di + 3 * di * di // max(self.n_heads, 1) * 0
                    + 3 * di * di + di * d + 3 * di)

        def slstm_params() -> int:
            # block-diagonal (per-head) recurrent + input projections, 4 gates
            di = self.d_model
            hd_s = di // max(self.n_heads, 1)
            rec = 4 * self.n_heads * hd_s * hd_s
            inp = 4 * di * di
            up = int(di * di * self.xlstm_slstm_proj) * 2
            return rec + inp + up

        def layer_params(spec: LayerSpec) -> int:
            t = 0
            if spec.mixer == "attn":
                t += attn_params()
            elif spec.mixer == "mamba":
                t += mamba_params()
            elif spec.mixer == "mlstm":
                t += mlstm_params()
            elif spec.mixer == "slstm":
                t += slstm_params()
            if spec.mlp == "dense":
                t += dense_mlp(self.d_ff)
            elif spec.mlp == "moe":
                t += moe_mlp()
            t += 2 * d  # norms
            return t

        for spec in self.pattern:
            total += self.n_repeats * layer_params(spec)
        if self.encoder_decoder:
            enc = self.n_encoder_layers * (attn_params() + dense_mlp(self.d_ff) + 2 * d)
            cross = self.n_layers * attn_params()  # cross-attention in decoder
            total += enc + cross
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        dff = self.moe_d_ff or self.d_ff
        moe_layers = self.n_repeats * sum(1 for s in self.pattern if s.mlp == "moe")
        inactive = moe_layers * (self.n_experts - self.top_k) * 3 * self.d_model * dff
        return self.param_count() - inactive

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        period = len(self.pattern)
        hd = min(self.resolved_head_dim, 32)
        n_heads = min(self.n_heads, 4)
        n_kv = min(self.n_kv_heads, n_heads)
        updates = dict(
            name=self.name + "-smoke",
            n_layers=2 * period,
            d_model=64,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=hd,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=512,
            moe_d_ff=64 if self.n_experts else 0,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.n_experts else 0,
            moe_group_size=32,
            n_encoder_layers=2 if self.encoder_decoder else 0,
            vision_tokens=16 if self.vision_tokens else 0,
            vision_grid=(4, 4) if self.vision_tokens else (16, 16),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            attn_block_q=32,
            attn_block_k=32,
            ssm_chunk=16,
            ssm_dt_rank=8,
            vocab_pad_multiple=16,
            mrope_sections=(8, 4, 4) if self.mrope_sections else (),
            dtype="float32",
            param_dtype="float32",
            opt_state_dtype="float32",
            remat="none",
        )
        return replace(self, **updates)


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assignment: 4 per architecture)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}

# Architectures with sub-quadratic sequence mixing (SSM / hybrid / SWA) run
# long_500k; pure full-attention archs skip it (see DESIGN.md §6).
SUBQUADRATIC_ARCHS = frozenset({"jamba-1.5-large-398b", "xlstm-350m", "h2o-danube-3-4b"})


def cell_is_runnable(arch: str, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return arch in SUBQUADRATIC_ARCHS
    return True
