"""Qwen3-30B-A3B  [hf:Qwen/Qwen3-30B-A3B].

48L, d=2048, 32H (GQA kv=4), vocab=151936; MoE every layer: 128 experts,
top-8, expert hidden 768; qk-norm, head_dim=128.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    pattern=(LayerSpec(mixer="attn", mlp="moe"),),
    n_experts=128,
    top_k=8,
    moe_d_ff=768,
    rope_theta=1000000.0,
    qk_norm=True,
)
