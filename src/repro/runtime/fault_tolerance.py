"""Fault-tolerant training loop: checkpoint/restart, straggler detection,
preemption handling, elastic re-meshing on restart.

Single-host implementation of the control plane a 1000+-node deployment needs;
the failure channel is injectable so the whole machinery is unit-testable:

* ``run_resilient``: step loop that (a) periodically ``save_async``es,
  (b) catches step failures (injected or real), restores from the latest
  checkpoint and replays, (c) takes an *emergency* synchronous checkpoint on
  preemption signals, (d) gives up after ``max_restarts`` consecutive
  failures (crash-loop guard).
* ``StragglerMonitor``: per-step wall-time EWMA + deviation; flags steps
  slower than ``threshold`` x EWMA.  On real pods the flagged step triggers
  hot-spare swap / re-slice; here the decision log is the artifact.
* ``ElasticPolicy`` (runtime/elastic.py): maps surviving device count to the
  largest feasible (data, model) mesh and re-lowers; checkpoint restore does
  the resharding (checkpoints are sharding-agnostic).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.checkpoint.checkpointer import Checkpointer


@dataclass
class StragglerMonitor:
    alpha: float = 0.2
    threshold: float = 2.0
    warmup: int = 3
    ewma: Optional[float] = None
    events: List[Dict] = field(default_factory=list)
    _n: int = 0

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is flagged as a straggler."""
        self._n += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        flagged = self._n > self.warmup and dt > self.threshold * self.ewma
        if flagged:
            self.events.append({"step": step, "dt": dt, "ewma": self.ewma})
        # stragglers don't poison the baseline estimate
        if not flagged:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return flagged


class PreemptionSignal:
    """Injectable preemption flag (SIGTERM handler in deployment)."""

    def __init__(self):
        self._flag = False

    def set(self):
        self._flag = True

    def check_and_clear(self) -> bool:
        f = self._flag
        self._flag = False
        return f


@dataclass
class RunReport:
    steps_completed: int
    restarts: int
    straggler_events: List[Dict]
    emergency_checkpoints: int
    final_metrics: Optional[Dict] = None


def run_resilient(step_fn: Callable[[Any, int], Tuple[Any, Dict]],
                  init_state: Any,
                  n_steps: int,
                  ckpt: Checkpointer,
                  ckpt_every: int = 50,
                  max_restarts: int = 5,
                  preemption: Optional[PreemptionSignal] = None,
                  monitor: Optional[StragglerMonitor] = None,
                  time_fn: Callable[[], float] = time.monotonic) -> RunReport:
    """Run ``step_fn(state, step) -> (state, metrics)`` to ``n_steps`` with
    checkpoint/restart semantics.  ``step_fn`` may raise — each failure
    triggers restore-from-latest + replay."""
    monitor = monitor or StragglerMonitor()
    state = init_state
    step = 0
    restarts = 0
    consecutive_failures = 0
    emergencies = 0
    metrics: Dict = {}

    latest = ckpt.latest_step()
    if latest is not None:
        state, extra = ckpt.restore(latest, state)
        step = int(extra.get("next_step", latest))

    while step < n_steps:
        try:
            t0 = time_fn()
            state, metrics = step_fn(state, step)
            dt = time_fn() - t0
            monitor.observe(step, dt)
            consecutive_failures = 0
            step += 1
            if step % ckpt_every == 0:
                ckpt.save_async(step, state, extra={"next_step": step})
            if preemption is not None and preemption.check_and_clear():
                ckpt.wait()
                ckpt.save(step, state, extra={"next_step": step,
                                              "emergency": True})
                emergencies += 1
        except Exception:
            consecutive_failures += 1
            restarts += 1
            if consecutive_failures > max_restarts:
                raise
            ckpt.wait()
            latest = ckpt.latest_step()
            if latest is not None:
                state, extra = ckpt.restore(latest, state)
                step = int(extra.get("next_step", latest))
            else:
                state = init_state
                step = 0
    ckpt.wait()
    return RunReport(steps_completed=step, restarts=restarts,
                     straggler_events=monitor.events,
                     emergency_checkpoints=emergencies,
                     final_metrics=metrics)
