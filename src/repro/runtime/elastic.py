"""Elastic scaling: choose the best mesh for however many devices survive.

Checkpoints are sharding-agnostic (checkpoint/checkpointer.py), so a restart
after losing nodes only needs (1) a new mesh over the surviving devices,
(2) new shardings from the same logical-axis rules, (3) restore.  This module
picks the mesh: keep the model axis as close to the original TP degree as
still fits (TP degree must divide flattened weight dims), give the rest to
data parallelism, and drop stragglers to a power-of-two device count.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

try:
    from jax.sharding import AxisType
except ImportError:  # older jax: every mesh axis is Auto already
    AxisType = None


def largest_pow2_leq(n: int) -> int:
    return 1 << (n.bit_length() - 1)


def choose_mesh_shape(n_devices: int, preferred_model: int = 16,
                      min_model: int = 1) -> Tuple[int, int]:
    """(data, model) for n_devices (uses largest power of two <= n)."""
    usable = largest_pow2_leq(max(n_devices, 1))
    model = min(preferred_model, usable)
    while model > min_model and usable % model:
        model //= 2
    return usable // model, model


def make_elastic_mesh(n_devices: Optional[int] = None,
                      preferred_model: int = 16):
    devs = jax.devices()
    n = n_devices if n_devices is not None else len(devs)
    data, model = choose_mesh_shape(n, preferred_model)
    used = devs[: data * model]
    import numpy as np
    arr = np.array(used).reshape(data, model)
    from jax.sharding import Mesh
    if AxisType is None:
        return Mesh(arr, ("data", "model"))
    return Mesh(arr, ("data", "model"),
                axis_types=(AxisType.Auto, AxisType.Auto))
