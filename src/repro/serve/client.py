"""HTTP client for :class:`~repro.serve.server.QueryServer` (stdlib only).

Library use:

    from repro.serve import QueryClient
    c = QueryClient("http://127.0.0.1:8123")
    out = c.query([{"kind": "aggregation", "score": "score_count"}])
    out["results"][0]["estimate"], out["request"]["fresh"]

Connection-refused errors are retried with backoff for ``connect_wait``
seconds (default 10) — a client launched alongside the server does not need
a sleep to win the startup race.

CLI (mirrors ``repro.launch.query``'s spec flags; exits non-zero if
``--expect-fresh`` or ``--expect-workloads`` is violated, which the CI smoke
uses to assert that a warm-store repeat request costs zero target-DNN
invocations and that a multi-workload server mounted everything):

    PYTHONPATH=src python -m repro.serve.client --url http://127.0.0.1:8123 \\
        --wait-ready 60 --workload video \\
        --spec '{"kind": "aggregation", "score": "score_count", "err": 0.1}' \\
        --expect-fresh 0
"""
from __future__ import annotations

import argparse
import json
import random
import sys
import time
import urllib.error
import urllib.request
from typing import Any, Dict, List, Optional

from repro.obs import parse_prometheus_text, series_key


class ServerError(RuntimeError):
    """Non-2xx response from the query server (message = server's error).
    ``status`` carries the HTTP status code, so callers (the load
    generator's error-kind split) can tell a 4xx rejection from a 5xx
    server fault without string matching."""

    def __init__(self, message: str, status: Optional[int] = None):
        super().__init__(message)
        self.status = status


class ConnectRetriesExhausted(OSError):
    """Connection kept being refused for the whole ``connect_wait`` window.

    An ``OSError`` so existing ``except OSError`` connection handling (e.g.
    :meth:`QueryClient.healthy`) keeps working; the message carries the
    total time spent waiting and the attempt count, so a failed startup
    race is distinguishable from a server that was never there.
    """

    def __init__(self, url: str, waited_s: float, attempts: int,
                 cause: Exception):
        super().__init__(
            f"{url}: connection refused after {attempts} attempts over "
            f"{waited_s:.2f}s of backoff; last error: {cause}")
        self.waited_s = waited_s
        self.attempts = attempts


def _is_conn_refused(e: urllib.error.URLError) -> bool:
    return isinstance(getattr(e, "reason", None), ConnectionRefusedError)


class QueryClient:
    def __init__(self, url: str, timeout: float = 600.0,
                 connect_wait: float = 10.0):
        self.url = url.rstrip("/")
        self.timeout = float(timeout)
        self.connect_wait = float(connect_wait)

    def _call(self, path: str, payload: Optional[Any] = None,
              method: Optional[str] = None,
              retry_refused: bool = True, raw: bool = False) -> Any:
        data = None if payload is None else json.dumps(payload).encode()
        req = urllib.request.Request(
            self.url + path, data=data,
            headers={"Content-Type": "application/json"},
            method=method or ("POST" if data is not None else "GET"))
        started = time.monotonic()
        deadline = started + self.connect_wait
        backoff = 0.05
        attempts = 0
        while True:
            attempts += 1
            try:
                with urllib.request.urlopen(req, timeout=self.timeout) as resp:
                    body = resp.read().decode()
                    return body if raw else json.loads(body)
            except urllib.error.HTTPError as e:
                try:
                    detail = json.loads(e.read().decode()).get("error", str(e))
                except Exception:  # noqa: BLE001 - best-effort error detail
                    detail = str(e)
                raise ServerError(f"{path}: {detail}",
                                  status=e.code) from None
            except urllib.error.URLError as e:
                # the server may simply not have bound its port yet: retry
                # connection-refused with jittered exponential backoff (full
                # jitter, so a fleet of clients racing one startup does not
                # hammer the port in lockstep) instead of failing a race no
                # client can win deterministically
                if not (retry_refused and _is_conn_refused(e)):
                    raise
                sleep = backoff * random.uniform(0.5, 1.0)
                if time.monotonic() + sleep >= deadline:
                    raise ConnectRetriesExhausted(
                        self.url + path, time.monotonic() - started,
                        attempts, e) from None
                time.sleep(sleep)
                backoff = min(backoff * 2, 1.0)

    # -- api -----------------------------------------------------------------
    def query(self, specs: List[Any], budget: Optional[int] = None,
              workload: Optional[str] = None,
              priority: Optional[int] = None,
              deadline_ms: Optional[float] = None,
              trace_id: Optional[str] = None) -> Dict[str, Any]:
        """POST specs (dicts or ``QuerySpec`` s); returns the response JSON:
        ``results`` (per-spec rows), ``session``, and ``request`` totals
        (including the request's ``trace_id``).  ``workload`` routes the
        whole request to one mounted workload (specs may carry their own
        ``workload`` field instead); ``priority`` (0 = most urgent) and
        ``deadline_ms`` (relative to arrival) place the request in the
        server's scheduling order; ``trace_id`` names the request's trace
        (else the server generates one)."""
        raw = [s if isinstance(s, dict) else s.to_dict() for s in specs]
        body: Any = raw
        extras = {"budget": budget, "workload": workload,
                  "priority": priority, "deadline_ms": deadline_ms,
                  "trace_id": trace_id}
        extras = {k: v for k, v in extras.items() if v is not None}
        if extras:
            body = {"specs": raw, **extras}
        return self._call("/query", payload=body)

    def stats(self) -> Dict[str, Any]:
        return self._call("/stats")

    def metrics(self) -> str:
        """The raw ``/metrics`` Prometheus text exposition."""
        return self._call("/metrics", raw=True)

    def traces(self, trace_id: Optional[str] = None,
               fmt: Optional[str] = None,
               limit: Optional[int] = None) -> Dict[str, Any]:
        """``/debug/traces``: recent summaries, or one full trace by id
        (``fmt="chrome"`` for a chrome://tracing-loadable document)."""
        params = []
        if trace_id is not None:
            params.append(f"id={trace_id}")
        if fmt is not None:
            params.append(f"format={fmt}")
        if limit is not None:
            params.append(f"limit={int(limit)}")
        query = "?" + "&".join(params) if params else ""
        return self._call("/debug/traces" + query)

    def workloads(self) -> Dict[str, Any]:
        """What the server has mounted: ``{"default": ..., "workloads":
        [{"name", "default", "loaded", "records", ...}, ...]}``."""
        return self._call("/workloads")

    def healthy(self) -> bool:
        try:
            # single probe: wait_ready owns the polling cadence
            return bool(self._call("/healthz", retry_refused=False).get("ok"))
        except (ServerError, OSError):
            return False

    def wait_ready(self, timeout: float = 30.0, poll: float = 0.2) -> None:
        """Block until ``/healthz`` answers (server start + index build can
        take a while); raises ``TimeoutError`` otherwise."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self.healthy():
                return
            time.sleep(poll)
        raise TimeoutError(f"{self.url} not ready after {timeout}s")

    def shutdown(self) -> None:
        self._call("/shutdown", payload={}, method="POST")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="post QuerySpecs to a running repro.serve QueryServer")
    ap.add_argument("--url", required=True, help="server base url")
    ap.add_argument("--spec", action="append",
                    help="QuerySpec as JSON (repeatable)")
    ap.add_argument("--specs-file", default=None,
                    help="file holding a JSON list of QuerySpecs")
    ap.add_argument("--budget", type=int, default=None,
                    help="session budget for this request (never coalesced)")
    ap.add_argument("--workload", default=None,
                    help="mounted workload to route this request to "
                         "(default: the server's default workload)")
    ap.add_argument("--priority", type=int, default=None,
                    help="scheduling class for this request (0 = most "
                         "urgent; default: the server's default class)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="soft latency target in ms; orders same-class "
                         "requests earliest-deadline-first")
    ap.add_argument("--wait-ready", type=float, default=0.0,
                    help="poll /healthz for up to this many seconds first")
    ap.add_argument("--connect-wait", type=float, default=10.0,
                    help="retry connection-refused with backoff for up to "
                         "this many seconds (startup race, no sleep needed)")
    ap.add_argument("--stats", action="store_true", help="print /stats")
    ap.add_argument("--list-workloads", action="store_true",
                    help="print /workloads")
    ap.add_argument("--expect-workloads", default=None,
                    help="comma-separated workload names; exit non-zero "
                         "unless /workloads lists every one (CI assertion)")
    ap.add_argument("--shutdown", action="store_true",
                    help="stop the server (after any query)")
    ap.add_argument("--expect-fresh", type=int, default=None,
                    help="exit non-zero unless the request's fresh-label "
                         "total equals this (CI assertion)")
    ap.add_argument("--dump-trace", default=None, metavar="PATH",
                    help="after the query, fetch its trace from "
                         "/debug/traces and write it as a Chrome trace-"
                         "event JSON file (load in chrome://tracing)")
    ap.add_argument("--check-metrics", action="store_true",
                    help="scrape /metrics before and after the query; "
                         "exit non-zero unless the exposition parses and "
                         "the workload's oracle_fresh_total advanced by "
                         "exactly the request's fresh count (assumes no "
                         "concurrent traffic, as in the CI smoke)")
    args = ap.parse_args(argv)

    client = QueryClient(args.url, connect_wait=args.connect_wait)
    if args.wait_ready > 0:
        client.wait_ready(timeout=args.wait_ready)

    specs: List[dict] = []
    if args.specs_file:
        with open(args.specs_file) as f:
            specs.extend(json.load(f))
    for s in args.spec or []:
        specs.append(json.loads(s))

    if specs:
        before = parse_prometheus_text(client.metrics()) \
            if args.check_metrics else None
        out = client.query(specs, budget=args.budget, workload=args.workload,
                           priority=args.priority,
                           deadline_ms=args.deadline_ms)
        print(json.dumps(out, indent=2))
        if args.expect_fresh is not None:
            got = out["request"]["fresh"]
            if got != args.expect_fresh:
                print(f"expected {args.expect_fresh} fresh labels, got {got}",
                      file=sys.stderr)
                sys.exit(1)
        if args.check_metrics:
            after = parse_prometheus_text(client.metrics())
            if not after:
                print("/metrics exposition is empty or unparseable",
                      file=sys.stderr)
                sys.exit(1)
            key = series_key("oracle_fresh_total",
                             workload=out["request"]["workload"])
            delta = after.get(key, 0.0) - before.get(key, 0.0)
            fresh = out["request"]["fresh"]
            if int(delta) != fresh:
                print(f"{key} advanced by {int(delta)} but the request "
                      f"paid {fresh} fresh labels", file=sys.stderr)
                sys.exit(1)
            print(f"[client] /metrics ok: {len(after)} series, "
                  f"{key} +{int(delta)} == request fresh", file=sys.stderr)
        if args.dump_trace:
            trace_id = out["request"].get("trace_id")
            if not trace_id:
                print("no trace_id in the response (server observability "
                      "disabled?); cannot --dump-trace", file=sys.stderr)
                sys.exit(1)
            doc = client.traces(trace_id=trace_id, fmt="chrome")
            with open(args.dump_trace, "w") as f:
                json.dump(doc, f)
            print(f"[client] trace {trace_id} "
                  f"({len(doc.get('traceEvents', []))} spans) -> "
                  f"{args.dump_trace}", file=sys.stderr)
    elif args.expect_fresh is not None:
        ap.error("--expect-fresh needs --spec/--specs-file")
    elif args.check_metrics or args.dump_trace:
        ap.error("--check-metrics/--dump-trace need --spec/--specs-file")

    if args.list_workloads or args.expect_workloads:
        wls = client.workloads()
        if args.list_workloads:
            print(json.dumps(wls, indent=2))
        if args.expect_workloads:
            mounted = {w["name"] for w in wls["workloads"]}
            missing = [n for n in args.expect_workloads.split(",")
                       if n and n not in mounted]
            if missing:
                print(f"expected workloads {missing} not mounted "
                      f"(mounted: {sorted(mounted)})", file=sys.stderr)
                sys.exit(1)
    if args.stats:
        print(json.dumps(client.stats(), indent=2))
    if args.shutdown:
        client.shutdown()


if __name__ == "__main__":
    main()
