"""HTTP client for :class:`~repro.serve.server.QueryServer` (stdlib only).

Library use:

    from repro.serve import QueryClient
    c = QueryClient("http://127.0.0.1:8123")
    out = c.query([{"kind": "aggregation", "score": "score_count"}])
    out["results"][0]["estimate"], out["request"]["fresh"]

CLI (mirrors ``repro.launch.query``'s spec flags; exits non-zero if
``--expect-fresh`` is violated, which the CI smoke uses to assert that a
warm-store repeat request costs zero target-DNN invocations):

    PYTHONPATH=src python -m repro.serve.client --url http://127.0.0.1:8123 \\
        --wait-ready 60 \\
        --spec '{"kind": "aggregation", "score": "score_count", "err": 0.1}' \\
        --expect-fresh 0
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import urllib.error
import urllib.request
from typing import Any, Dict, List, Optional


class ServerError(RuntimeError):
    """Non-2xx response from the query server (message = server's error)."""


class QueryClient:
    def __init__(self, url: str, timeout: float = 600.0):
        self.url = url.rstrip("/")
        self.timeout = float(timeout)

    def _call(self, path: str, payload: Optional[Any] = None,
              method: Optional[str] = None) -> Dict[str, Any]:
        data = None if payload is None else json.dumps(payload).encode()
        req = urllib.request.Request(
            self.url + path, data=data,
            headers={"Content-Type": "application/json"},
            method=method or ("POST" if data is not None else "GET"))
        try:
            with urllib.request.urlopen(req, timeout=self.timeout) as resp:
                return json.loads(resp.read().decode())
        except urllib.error.HTTPError as e:
            try:
                detail = json.loads(e.read().decode()).get("error", str(e))
            except Exception:  # noqa: BLE001 - best-effort error detail
                detail = str(e)
            raise ServerError(f"{path}: {detail}") from None

    # -- api -----------------------------------------------------------------
    def query(self, specs: List[Any],
              budget: Optional[int] = None) -> Dict[str, Any]:
        """POST specs (dicts or ``QuerySpec`` s); returns the response JSON:
        ``results`` (per-spec rows), ``session``, and ``request`` totals."""
        raw = [s if isinstance(s, dict) else s.to_dict() for s in specs]
        body: Any = raw if budget is None else {"specs": raw, "budget": budget}
        return self._call("/query", payload=body)

    def stats(self) -> Dict[str, Any]:
        return self._call("/stats")

    def healthy(self) -> bool:
        try:
            return bool(self._call("/healthz").get("ok"))
        except (ServerError, OSError):
            return False

    def wait_ready(self, timeout: float = 30.0, poll: float = 0.2) -> None:
        """Block until ``/healthz`` answers (server start + index build can
        take a while); raises ``TimeoutError`` otherwise."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self.healthy():
                return
            time.sleep(poll)
        raise TimeoutError(f"{self.url} not ready after {timeout}s")

    def shutdown(self) -> None:
        self._call("/shutdown", payload={}, method="POST")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="post QuerySpecs to a running repro.serve QueryServer")
    ap.add_argument("--url", required=True, help="server base url")
    ap.add_argument("--spec", action="append",
                    help="QuerySpec as JSON (repeatable)")
    ap.add_argument("--specs-file", default=None,
                    help="file holding a JSON list of QuerySpecs")
    ap.add_argument("--budget", type=int, default=None,
                    help="session budget for this request (never coalesced)")
    ap.add_argument("--wait-ready", type=float, default=0.0,
                    help="poll /healthz for up to this many seconds first")
    ap.add_argument("--stats", action="store_true", help="print /stats")
    ap.add_argument("--shutdown", action="store_true",
                    help="stop the server (after any query)")
    ap.add_argument("--expect-fresh", type=int, default=None,
                    help="exit non-zero unless the request's fresh-label "
                         "total equals this (CI assertion)")
    args = ap.parse_args(argv)

    client = QueryClient(args.url)
    if args.wait_ready > 0:
        client.wait_ready(timeout=args.wait_ready)

    specs: List[dict] = []
    if args.specs_file:
        with open(args.specs_file) as f:
            specs.extend(json.load(f))
    for s in args.spec or []:
        specs.append(json.loads(s))

    if specs:
        out = client.query(specs, budget=args.budget)
        print(json.dumps(out, indent=2))
        if args.expect_fresh is not None:
            got = out["request"]["fresh"]
            if got != args.expect_fresh:
                print(f"expected {args.expect_fresh} fresh labels, got {got}",
                      file=sys.stderr)
                sys.exit(1)
    elif args.expect_fresh is not None:
        ap.error("--expect-fresh needs --spec/--specs-file")

    if args.stats:
        print(json.dumps(client.stats(), indent=2))
    if args.shutdown:
        client.shutdown()


if __name__ == "__main__":
    main()
