"""Persistent oracle-label store: the label cache that outlives the process.

TASTI's economics price everything in target-DNN invocations, and one index
is meant to amortize labels across *many* queries (paper §5-6) — so letting
the broker's label cache die with the process throws the amortization away.
A :class:`LabelStore` persists ``{record id: target-DNN annotation}`` next to
the saved index, with the same format discipline as
:meth:`~repro.core.index.TastiIndex.save`:

* a compacted **snapshot** — ids in ``<stem>.labels.npz``, annotations in a
  versioned ``<stem>.labels.json`` (the index's JSON annotation codec, no
  pickle), both written atomically via the shared
  :func:`~repro.core.persist.atomic_write` helper;
* an append-only **journal** (``<stem>.labels.jsonl``) — every broker flush
  appends one line of just-labeled records, O(batch) not O(store), so
  write-through stays cheap under the broker lock and even a SIGKILLed
  server keeps every label it paid for; :meth:`save` folds the journal into
  the snapshot and truncates it;
* a **lineage check** — the store records both the ``TastiIndex.version``
  (crack counter) and a content :func:`index_fingerprint` of the embeddings
  it was cached against; :meth:`open` discards a store whose lineage no
  longer matches (labels are re-derivable, a wrong-dataset cache served at
  zero fresh cost is silently-wrong answers);
* :meth:`attach` seeds an :class:`~repro.core.broker.OracleBroker` cache and
  registers the write-through, so a restarted server answers repeat queries
  with **zero** fresh labels.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import threading
from typing import Any, Dict, Optional

import numpy as np

from repro.core.index import _decode_annotation, _encode_annotation
from repro.core.persist import atomic_write


def index_fingerprint(index) -> str:
    """A cheap content identity for the dataset behind ``index``: sha256
    over the embedding array's shape/dtype and a strided byte sample.
    Stable across cracking (cracks add representatives, never touch
    embeddings), different across datasets — the check that stops a reused
    ``--store`` path from serving another workload's labels."""
    emb = np.ascontiguousarray(index.embeddings)
    h = hashlib.sha256()
    h.update(repr((emb.shape, str(emb.dtype))).encode())
    flat = emb.view(np.uint8).ravel()
    h.update(flat[::max(1, len(flat) // 65536)].tobytes())
    return h.hexdigest()[:32]


class LabelStore:
    """A dict of oracle labels with a JSON+npz+journal on-disk form.

        store = LabelStore.for_index("/tmp/tasti/ns", index)
        store.attach(engine.broker, engine)   # seed + write-through
        ... queries run; every broker flush lands in the journal ...
        store.save()                          # compact (shutdown does this)
    """

    FORMAT_VERSION = 1

    def __init__(self, path: str, index_version: int = 0,
                 fingerprint: Optional[str] = None,
                 labels: Optional[Dict[int, Any]] = None):
        self.path = pathlib.Path(path)
        self.index_version = int(index_version)
        self.fingerprint = fingerprint
        self.labels: Dict[int, Any] = dict(labels or {})
        self._lock = threading.RLock()
        self.stats: Dict[str, int] = {
            "journal_appends": 0,   # write-through batches journaled
            "journal_records": 0,   # labels across those batches
            "compactions": 0,       # save() calls (journal folded+truncated)
        }
        # does the on-disk snapshot carry THIS store's lineage?  attach()
        # compacts first when it does not (fresh stem, or a stale store
        # from another index generation that must not be appended to)
        self._snapshot_valid = False

    # suffixes are appended (not substituted) so dotted stems survive
    def _sib(self, suffix: str) -> pathlib.Path:
        return self.path.parent / (self.path.name + suffix)

    @property
    def json_path(self) -> pathlib.Path:
        return self._sib(".labels.json")

    @property
    def npz_path(self) -> pathlib.Path:
        return self._sib(".labels.npz")

    @property
    def journal_path(self) -> pathlib.Path:
        return self._sib(".labels.jsonl")

    def __len__(self) -> int:
        return len(self.labels)

    def _lineage(self) -> Dict[str, Any]:
        return {"format_version": self.FORMAT_VERSION,
                "index_version": self.index_version,
                "fingerprint": self.fingerprint}

    def _lineage_matches(self, meta: Dict[str, Any]) -> bool:
        if int(meta.get("index_version", -1)) != self.index_version:
            return False
        stored = meta.get("fingerprint")
        if self.fingerprint is not None and stored != self.fingerprint:
            return False
        return True

    # -- disk ----------------------------------------------------------------
    @classmethod
    def for_index(cls, path: str, index) -> "LabelStore":
        """The store next to ``path``, validated against ``index``'s full
        lineage (crack version + embedding fingerprint)."""
        return cls.open(path, index.version,
                        fingerprint=index_fingerprint(index))

    @classmethod
    def open(cls, path: str, index_version: int,
             fingerprint: Optional[str] = None) -> "LabelStore":
        """The store at ``path`` if present *and* cached against the given
        index lineage; otherwise a fresh empty store.

        A lineage mismatch (the index was cracked and re-saved after the
        store was written, rolled back, or the stem was reused for another
        dataset) invalidates the store: it comes back empty and the stale
        files are overwritten on the next save.  The snapshot is loaded
        first, then the journal of post-snapshot flushes is replayed (a
        torn final line — crash mid-append — stops the replay there).
        """
        store = cls(path, index_version=index_version, fingerprint=fingerprint)
        if store.json_path.exists() and store.npz_path.exists():
            with open(store.json_path) as f:
                meta = json.load(f)
            fv = int(meta.get("format_version", -1))
            if fv > cls.FORMAT_VERSION:
                raise ValueError(
                    f"{store.json_path} has format_version {fv}; this build "
                    f"reads <= {cls.FORMAT_VERSION}")
            if store._lineage_matches(meta):
                ids = np.load(store.npz_path)["ids"]
                anns = [_decode_annotation(a) for a in meta["annotations"]]
                if len(ids) != len(anns):
                    raise ValueError(
                        f"label store {store.path} is torn: {len(ids)} ids "
                        f"vs {len(anns)} annotations")
                store.labels = {int(i): a for i, a in zip(ids, anns)}
                store._snapshot_valid = True
        store._replay_journal()
        return store

    def _replay_journal(self) -> int:
        """Fold journal lines (post-snapshot flushes) into ``labels``.
        The header line must match this store's lineage, else the whole
        journal is ignored (it belongs to another index generation)."""
        if not self.journal_path.exists():
            return 0
        replayed = 0
        with open(self.journal_path) as f:
            for n, line in enumerate(f):
                try:
                    entry = json.loads(line)
                except json.JSONDecodeError:
                    break  # torn tail from a crash mid-append: keep the rest
                if n == 0:
                    if not self._lineage_matches(entry):
                        return 0
                    continue
                for i, a in zip(entry["ids"], entry["annotations"]):
                    self.labels[int(i)] = _decode_annotation(a)
                    replayed += 1
        return replayed

    def _append_journal(self, labeled: Dict[int, Any]) -> None:
        """O(batch) durable append; creates the journal (with a lineage
        header) on first use after a compaction."""
        ids = [int(i) for i in labeled]
        entry = {"ids": ids,
                 "annotations": [_encode_annotation(labeled[i]) for i in ids]}
        new = not self.journal_path.exists()
        self.journal_path.parent.mkdir(parents=True, exist_ok=True)
        with open(self.journal_path, "a") as f:
            if new:
                f.write(json.dumps(self._lineage()) + "\n")
            f.write(json.dumps(entry) + "\n")
            f.flush()
            os.fsync(f.fileno())
        self.stats["journal_appends"] += 1
        self.stats["journal_records"] += len(ids)

    def save(self) -> None:
        """Compact: atomically persist the full snapshot (both files
        temp-file+renamed), then truncate the journal it subsumes."""
        with self._lock:
            ids = np.asarray(sorted(self.labels), np.int64)
            meta = {**self._lineage(),
                    "annotations": [_encode_annotation(self.labels[int(i)])
                                    for i in ids]}
            meta_body = json.dumps(meta)  # encode before touching any file
            with atomic_write(self.npz_path, "wb") as f:
                np.savez(f, ids=ids)
            with atomic_write(self.json_path, "w") as f:
                f.write(meta_body)
            self.journal_path.unlink(missing_ok=True)
            self._snapshot_valid = True
            self.stats["compactions"] += 1

    # -- broker integration --------------------------------------------------
    def update(self, labeled: Dict[int, Any]) -> int:
        """Merge freshly labeled records (memory only; returns how many were
        new).  Persistence happens via the attached write-through journal
        or an explicit :meth:`save`."""
        with self._lock:
            new = 0
            for i, a in labeled.items():
                i = int(i)
                if i not in self.labels:
                    new += 1
                self.labels[i] = a
            return new

    def attach(self, broker, engine=None) -> int:
        """Seed ``broker.cache`` from this store and journal every flush.
        With ``engine`` given, a mid-serving crack re-stamps the lineage the
        store is cached against (and compacts), so its labels stay loadable
        against the re-saved index.  Returns the labels seeded."""
        seeded = broker.seed(self.labels)
        if not self._snapshot_valid:
            # fresh stem, or stale files from another index generation:
            # compact now so the on-disk lineage (snapshot + any journal
            # header written later) is unambiguously this store's
            self.save()

        def _write_through(labeled: Dict[int, Any]) -> None:
            with self._lock:
                self.update(labeled)
                self._append_journal(labeled)

        broker.on_fresh(_write_through)
        if engine is not None:
            def _restamp(_added: int) -> None:
                with self._lock:
                    self.index_version = engine.index.version
                    self.save()

            engine.on_crack(_restamp)
        return seeded
