"""Query-serving subsystem: a long-lived HTTP endpoint over the query engine.

* :mod:`repro.serve.store` — :class:`LabelStore`, the persistent oracle-label
  cache that lives next to a saved :class:`~repro.core.index.TastiIndex` and
  survives process restarts;
* :mod:`repro.serve.registry` — :class:`WorkloadRegistry` /
  :class:`WorkloadSpec`, the mounting table that puts many workloads (each
  its own index + engine + store + oracle pool) behind one server, loaded
  lazily from a manifest;
* :mod:`repro.serve.scheduler` — :class:`QueryScheduler`, the SLO-aware
  waiting/running-queue scheduler (priority classes, EDF, weighted shares,
  per-workload caps, preemption at oracle-slice boundaries);
* :mod:`repro.serve.server` — :class:`QueryServer`, a stdlib
  ``ThreadingHTTPServer`` that routes specs to workloads, schedules them
  through the :class:`QueryScheduler`, and coalesces concurrent requests
  per workload into shared :class:`~repro.core.session.QuerySession` s;
* :mod:`repro.serve.client` — :class:`QueryClient` plus a small CLI.

(The JSON wire form of a ``QueryResult`` is :mod:`repro.core.codec` — shared
with the ``repro.launch.query`` CLI.)
"""
__all__ = ["LabelStore", "QueryClient", "QueryScheduler", "QueryServer",
           "ScheduledTask", "WorkloadRegistry", "WorkloadSpec"]

_HOMES = {"LabelStore": "repro.serve.store",
          "QueryClient": "repro.serve.client",
          "QueryScheduler": "repro.serve.scheduler",
          "QueryServer": "repro.serve.server",
          "ScheduledTask": "repro.serve.scheduler",
          "WorkloadRegistry": "repro.serve.registry",
          "WorkloadSpec": "repro.serve.registry"}


def __getattr__(name):
    # lazy (PEP 562) so `python -m repro.serve.client` does not import the
    # client module twice (once via the package, once as __main__)
    if name in _HOMES:
        import importlib
        return getattr(importlib.import_module(_HOMES[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
