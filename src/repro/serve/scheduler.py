"""SLO-aware query scheduler: the layer between admission and execution.

The pre-scheduler server admitted work through per-workload lanes with a
fixed coalescing delay and handed batches to an unordered thread pool — so
under mixed traffic one 10k-budget limit scan could hold a worker for
seconds while a stream of cheap aggregations queued behind it.
:class:`QueryScheduler` replaces those lanes with iteration-level
scheduling in the style of Sarathi-Serve:

* **waiting / running queues** — every admitted request becomes a
  :class:`ScheduledTask` in the waiting queue; ``max_workers`` *logical
  slots* bound how many tasks execute concurrently.  Each task runs on its
  own thread (threads are cheap and plentiful — the HTTP front end already
  spawns one per connection); the slots, not the threads, are the scarce
  resource, which is what makes preemption possible: a paused task blocks
  on its checkpoint *without* holding a slot;
* **priority classes + EDF** — tasks are ordered by ``(priority class,
  deadline)``: strictly by class first (0 = most urgent), then earliest
  deadline first within a class (``deadline_ms`` on the spec or request,
  relative to arrival); tasks without a deadline sort after those with one
  and fall back to weighted fair sharing, then arrival order;
* **weighted shares + per-workload caps** — among equally urgent work,
  the workload with the smallest ``active_slots / share`` ratio is served
  next, and a workload at its ``cap`` cannot take another slot no matter
  how urgent its queue is (a noisy tenant cannot monopolize the pool);
* **preemption at slice boundaries** — every session executes with a
  *checkpoint* callback that the engine invokes between oracle-microbatch-
  sized slices of every scan (see ``QueryEngine._make_oracle``).  When a
  strictly higher-class task is waiting and no slot is free, the scheduler
  flags the worst running task; at its next checkpoint that task releases
  its slot, re-enters the waiting queue (keeping its class, deadline, and
  arrival order), and blocks until re-granted.  Slicing never changes
  which ids are requested, in what order, or on which account — labels
  and :class:`~repro.core.broker.OracleAccount` fresh/cached charges are
  byte-identical to unscheduled execution;
* **coalescing preserved** — with ``admission_window > 0``, an unbudgeted
  task becomes runnable only ``admission_window`` seconds after arrival,
  and when granted it absorbs every waiting same-workload, same-class,
  unbudgeted task into its shared session (the paper's cross-query
  amortization).  ``admission_window=0`` disables sharing entirely, same
  as the pre-scheduler lanes.

The scheduler is deliberately mechanism, not policy host: it knows nothing
about HTTP or sessions.  The server injects three callbacks — ``load``
(resolve the workload entry, possibly paying a lazy index build), ``run``
(execute the task's merged submissions), and ``fail`` (error out every
submission) — and the scheduler owns ordering, slots, merging, preemption,
and the queue-wait accounting surfaced at ``/stats``.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.obs import NULL_SCOPE
from repro.obs.trace import add_timed_span

#: Scheduling class for specs/requests that do not set one.  Lower is more
#: urgent; 0 is the conventional interactive class, leaving room on both
#: sides of the default.
DEFAULT_PRIORITY = 1

_WL_KEYS = ("admitted", "merged", "preempted", "waits",
            "wait_total_s", "wait_max_s")


@dataclass(eq=False)  # identity semantics: tasks live in queues and sets
class ScheduledTask:
    """One admitted request (or several, once merged) moving through the
    waiting -> running (-> paused -> running)* -> done lifecycle."""

    workload: str
    submissions: List[Any]            # server-side _Submission objects
    priority: int = DEFAULT_PRIORITY
    deadline: Optional[float] = None  # absolute time.monotonic() seconds
    budget: Optional[int] = None      # budgeted tasks are never merged
    enqueued_at: float = 0.0
    ready_at: float = 0.0             # arrival + admission window (coalescible)
    seq: int = 0                      # admission order (final tie-break)
    # scheduler-managed state, guarded by the scheduler's condition lock
    state: str = "waiting"            # waiting|running|paused|done
    started: bool = False             # first slot grant happened
    absorbed: bool = False            # merged into another task's session
    pause_requested: bool = False
    preemptions: int = 0
    first_grant_at: Optional[float] = None

    def sort_key(self, active_per_share: float):
        """(class, EDF, weighted-fair underservice, arrival order)."""
        return (self.priority,
                self.deadline if self.deadline is not None else float("inf"),
                active_per_share,
                self.seq)


@dataclass
class _WorkloadSched:
    """Per-workload scheduling config + counters."""
    share: float = 1.0
    cap: Optional[int] = None
    active: int = 0
    stats: Dict[str, float] = field(
        default_factory=lambda: dict.fromkeys(_WL_KEYS, 0))
    h_wait: Any = None  # sched_queue_wait_seconds{workload=...} histogram


class QueryScheduler:
    """Waiting/running queues with shares, caps, EDF, and preemption.

        sched = QueryScheduler(load, run, fail, max_workers=4,
                               shares={"video": 3.0}, caps={"text": 1},
                               admission_window=0.05, preempt=True)
        sched.submit(task)          # returns immediately; task runs async
        ...
        sched.shutdown()            # drain running, shed waiting (503)

    ``preempt_slice`` sets the ids-per-slice granularity of the checkpoint
    contract (None = each workload engine's oracle microbatch size, which
    keeps broker batch counts identical to unscheduled runs).
    """

    def __init__(self,
                 load: Callable[[ScheduledTask], Any],
                 run: Callable[[ScheduledTask, Any], None],
                 fail: Callable[[ScheduledTask, Exception, int], None],
                 max_workers: int = 4,
                 shares: Optional[Dict[str, float]] = None,
                 caps: Optional[Dict[str, int]] = None,
                 admission_window: float = 0.0,
                 preempt: bool = True,
                 preempt_slice: Optional[int] = None,
                 obs=None):
        if max_workers < 1:
            raise ValueError(f"max_workers must be >= 1, got {max_workers}")
        self._load = load
        self._run = run
        self._fail = fail
        self._obs = obs if obs is not None else NULL_SCOPE
        # one counter child per grant reason, resolved once (lock-cheap inc
        # is the only hot-path cost; disabled obs makes these no-ops)
        self._c_grants = {
            reason: self._obs.counter(
                "sched_grants_total",
                help="slot grants by reason (first|resume|drain)",
                reason=reason)
            for reason in ("first", "resume", "drain")}
        self.max_workers = int(max_workers)
        self.admission_window = float(admission_window)
        self.preempt = bool(preempt)
        self.preempt_slice = preempt_slice
        self._cond = threading.Condition()
        self._waiting: List[ScheduledTask] = []
        self._running_tasks: set = set()  # tasks currently holding a slot
        self._wl: Dict[str, _WorkloadSched] = {}
        for name, share in (shares or {}).items():
            if share <= 0:
                raise ValueError(f"share for {name!r} must be > 0, "
                                 f"got {share}")
            self._wl_state(name).share = float(share)
        for name, cap in (caps or {}).items():
            if cap < 1:
                raise ValueError(f"cap for {name!r} must be >= 1, got {cap}")
            self._wl_state(name).cap = int(cap)
        self._n_active = 0
        self._n_paused = 0
        self._seq = 0
        self._closed = False
        self._draining = False
        self._threads: Dict[int, threading.Thread] = {}
        self.stats: Dict[str, int] = {
            "submitted": 0,    # tasks entering the waiting queue
            "granted": 0,      # first slot grants (excludes resumes)
            "merged": 0,       # tasks absorbed into another's session
            "preemptions": 0,  # pause-at-checkpoint events
            "slices": 0,       # checkpoint calls (execution progress beats)
            "shed": 0,         # waiting tasks failed by shutdown
        }

    # -- helpers (call with self._cond held) ---------------------------------
    def _wl_state(self, name: str) -> _WorkloadSched:
        ws = self._wl.get(name)
        if ws is None:
            ws = self._wl[name] = _WorkloadSched()
            ws.h_wait = self._obs.histogram(
                "sched_queue_wait_seconds",
                help="enqueue-to-first-grant wait per workload",
                workload=name)
        return ws

    def _best(self, now: float) -> Optional[ScheduledTask]:
        """The waiting task that should run next: min over eligible tasks of
        (priority, deadline, active/share, seq).  A workload at its cap has
        no eligible tasks regardless of urgency."""
        best: Optional[ScheduledTask] = None
        best_key = None
        for t in self._waiting:
            if t.absorbed or now < t.ready_at:
                continue
            ws = self._wl_state(t.workload)
            if ws.cap is not None and ws.active >= ws.cap:
                continue
            key = t.sort_key(ws.active / ws.share)
            if best_key is None or key < best_key:
                best, best_key = t, key
        return best

    def _request_preemption(self, task: ScheduledTask) -> None:
        """Flag the worst strictly-lower-class running task to pause at its
        next checkpoint (idempotent; the victim may finish first, which
        frees the slot just the same)."""
        victim: Optional[ScheduledTask] = None
        victim_key = None
        for t in self._running_tasks:
            if t.priority <= task.priority:
                continue
            key = (t.priority,
                   t.deadline if t.deadline is not None else float("inf"),
                   t.seq)
            if victim_key is None or key > victim_key:
                victim, victim_key = t, key
        if victim is not None:
            victim.pause_requested = True

    def _grant(self, task: ScheduledTask, now: float) -> None:
        self._waiting.remove(task)
        ws = self._wl_state(task.workload)
        ws.active += 1
        self._n_active += 1
        self._running_tasks.add(task)
        if task.state == "paused":
            self._n_paused -= 1
        task.state = "running"
        reason = ("drain" if self._draining
                  else "resume" if task.started else "first")
        self._c_grants[reason].inc()
        if not task.started:
            task.started = True
            task.first_grant_at = now
            self.stats["granted"] += 1
            self._record_wait(ws, now - task.enqueued_at)
            # coalesce at grant: absorb every waiting same-workload,
            # same-class, unbudgeted stranger into this task's session —
            # admission_window=0 disables sharing, same as the old lanes
            if self.admission_window > 0 and task.budget is None:
                for t in list(self._waiting):
                    if (t.workload == task.workload and not t.started
                            and not t.absorbed and t.budget is None
                            and t.priority == task.priority):
                        t.absorbed = True
                        self._waiting.remove(t)
                        task.submissions.extend(t.submissions)
                        if t.deadline is not None:
                            task.deadline = (t.deadline if task.deadline is None
                                             else min(task.deadline, t.deadline))
                        self.stats["merged"] += 1
                        ws.stats["merged"] += 1
                        self._record_wait(ws, now - t.enqueued_at)
        ws.stats["admitted"] += 1
        self._cond.notify_all()

    @staticmethod
    def _record_wait(ws: _WorkloadSched, wait: float) -> None:
        ws.stats["waits"] += 1
        ws.stats["wait_total_s"] += wait
        ws.stats["wait_max_s"] = max(ws.stats["wait_max_s"], wait)
        if ws.h_wait is not None:
            ws.h_wait.observe(wait)

    # -- task lifecycle ------------------------------------------------------
    def submit(self, task: ScheduledTask) -> ScheduledTask:
        """Enqueue a task and start its thread.  Non-blocking; after
        shutdown the task fails 503 on its own thread (never stranded)."""
        now = time.monotonic()
        task.enqueued_at = now
        if task.budget is None and self.admission_window > 0:
            task.ready_at = now + self.admission_window
        else:
            task.ready_at = now
        with self._cond:
            self._seq += 1
            task.seq = self._seq
            self.stats["submitted"] += 1
            self._wl_state(task.workload)  # materialize stats row
            self._waiting.append(task)
            thread = threading.Thread(target=self._task_main, args=(task,),
                                      name=f"query-sched-{task.seq}",
                                      daemon=True)
            self._threads[task.seq] = thread
            self._cond.notify_all()
        thread.start()
        return task

    def _task_main(self, task: ScheduledTask) -> None:
        try:
            try:
                # lazy workloads pay their index build/load HERE, before the
                # task competes for a slot: a cold build never occupies a
                # slot another workload's sessions need (and a memoized
                # failed load fails every later task fast)
                entry = self._load(task)
            except Exception as e:  # noqa: BLE001 - mount faults
                self._discard(task)
                self._fail(task, e, 500)
                return
            verdict = self._acquire(task)
            if verdict == "absorbed":
                return  # another task's session answers our submissions
            if verdict == "shutdown":
                self._fail(task, RuntimeError("server is shutting down"), 503)
                return
            try:
                self._run(task, entry)
            finally:
                self._release(task)
        except BaseException as e:  # noqa: BLE001 - never strand a client
            undone = [s for s in task.submissions if not s.done.is_set()]
            if undone:
                self._fail(task, e if isinstance(e, Exception)
                           else RuntimeError(repr(e)), 500)
        finally:
            with self._cond:
                self._threads.pop(task.seq, None)

    def _discard(self, task: ScheduledTask) -> None:
        with self._cond:
            if task in self._waiting:
                self._waiting.remove(task)
            self._cond.notify_all()

    def _acquire(self, task: ScheduledTask) -> str:
        """Block until this task is granted a slot ("granted"), merged into
        another task's session ("absorbed"), or shed by shutdown
        ("shutdown").  Also the re-entry point for preempted tasks."""
        with self._cond:
            while True:
                if task.absorbed:
                    return "absorbed"
                if self._closed and not task.started:
                    if task in self._waiting:
                        self._waiting.remove(task)
                    self.stats["shed"] += 1
                    self._cond.notify_all()
                    return "shutdown"
                now = time.monotonic()
                if self._draining and task.started:
                    # shutdown drain: paused sessions finish unconditionally
                    self._grant(task, now)
                    return "granted"
                best = self._best(now)
                if best is task:
                    if self._n_active < self.max_workers:
                        self._grant(task, now)
                        return "granted"
                    if self.preempt:
                        self._request_preemption(task)
                timeout = 0.25
                if now < task.ready_at:
                    timeout = min(timeout, task.ready_at - now)
                self._cond.wait(timeout)

    def _release(self, task: ScheduledTask) -> None:
        with self._cond:
            task.state = "done"
            task.pause_requested = False
            self._running_tasks.discard(task)
            self._wl_state(task.workload).active -= 1
            self._n_active -= 1
            self._cond.notify_all()

    def checkpoint(self, task: ScheduledTask) -> None:
        """The preemption slice boundary: sessions call this between
        oracle-slice fetches.  Returns immediately unless this task was
        flagged for preemption, in which case it releases its slot, rejoins
        the waiting queue with its original class/deadline/arrival order,
        and blocks here until re-granted."""
        with self._cond:
            self.stats["slices"] += 1
            if (not task.pause_requested or self._draining
                    or task.state != "running"):
                task.pause_requested = False
                return
            task.pause_requested = False
            task.state = "paused"
            task.preemptions += 1
            self.stats["preemptions"] += 1
            ws = self._wl_state(task.workload)
            ws.stats["preempted"] += 1
            ws.active -= 1
            self._n_active -= 1
            self._n_paused += 1
            self._running_tasks.discard(task)
            self._waiting.append(task)
            self._cond.notify_all()
        t0 = time.perf_counter()
        self._acquire(task)  # started tasks always resume (never shed)
        add_timed_span("sched.preempt_pause", t0, time.perf_counter(),
                       workload=task.workload, preemption=task.preemptions)

    # -- lifecycle -----------------------------------------------------------
    def shutdown(self, wait: bool = True, timeout: float = 30.0) -> None:
        """Stop granting to new tasks (their threads shed them with a 503),
        let running and paused sessions drain, and join task threads."""
        with self._cond:
            self._closed = True
            self._draining = True
            self._cond.notify_all()
            threads = list(self._threads.values())
        if wait:
            deadline = time.monotonic() + timeout
            for t in threads:
                t.join(timeout=max(0.1, deadline - time.monotonic()))

    # -- introspection -------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Global counters + per-workload queue depth / wait-time stats
        (the ``/stats`` scheduler section)."""
        with self._cond:
            depth: Dict[str, int] = {}
            for t in self._waiting:
                if not t.absorbed:
                    depth[t.workload] = depth.get(t.workload, 0) + 1
            per_wl: Dict[str, Dict[str, Any]] = {}
            for name, ws in self._wl.items():
                waits = ws.stats["waits"]
                per_wl[name] = {
                    "depth": depth.get(name, 0),
                    "active": ws.active,
                    "share": ws.share,
                    "cap": ws.cap,
                    "admitted": int(ws.stats["admitted"]),
                    "merged": int(ws.stats["merged"]),
                    "preempted": int(ws.stats["preempted"]),
                    "wait_mean_s": (ws.stats["wait_total_s"] / waits
                                    if waits else 0.0),
                    "wait_max_s": ws.stats["wait_max_s"],
                }
            return {
                **self.stats,
                "max_workers": self.max_workers,
                "preempt": self.preempt,
                "waiting": sum(depth.values()),
                "active": self._n_active,
                "paused": self._n_paused,
                "workloads": per_wl,
            }

    def workload_snapshot(self, name: str) -> Dict[str, Any]:
        """One workload's queue section (depth + wait counters)."""
        return self.snapshot()["workloads"].get(name, {
            "depth": 0, "active": 0, "share": 1.0, "cap": None,
            "admitted": 0, "merged": 0, "preempted": 0,
            "wait_mean_s": 0.0, "wait_max_s": 0.0})
