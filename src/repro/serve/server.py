"""``QueryServer``: concurrent query sessions over one engine, via HTTP.

The system's first long-lived, multi-client layer.  Clients POST JSON
``QuerySpec`` lists (the same schema as ``repro.launch.query``); the server

* **coalesces** — requests arriving within one *admission window* are merged
  into a single shared :class:`~repro.core.session.QuerySession`, so strangers'
  queries share joint planning, the stratified sample, and one combined
  oracle flush (the whole point of sessions, paper §4/§5);
* **runs sessions concurrently** — batches execute on a worker pool over one
  :class:`~repro.core.engine.QueryEngine` /
  :class:`~repro.core.broker.OracleBroker`, whose locks make concurrent
  sessions produce results identical to isolated runs; with
  ``--oracle-replicas N`` every session's flushes shard across the engine's
  one :class:`~repro.core.oracle_pool.OraclePool` of target-DNN replicas
  (stopped by :meth:`QueryServer.shutdown` after the last session drains);
* **persists** — with a :class:`~repro.serve.store.LabelStore` attached to
  the broker, every flush is written through to disk, so a restarted server
  answers repeat queries with zero fresh target-DNN invocations.

Endpoints (all JSON):

* ``POST /query`` — body is either a list of spec dicts or
  ``{"specs": [...], "budget": int}``; responds with per-spec result rows
  plus session- and request-level label accounting;
* ``GET /stats`` — server counters, engine/broker stats, per-account
  fresh/cached counters, store and index info;
* ``GET /healthz`` — readiness probe;
* ``POST /shutdown`` — clean stop (also available as ``server.shutdown()``).
"""
from __future__ import annotations

import json
import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, List, Optional

from repro.core.codec import result_row
from repro.core.engine import QueryEngine, QuerySpec
from repro.core.session import QuerySession

_STOP = object()  # admission-queue sentinel


@dataclass
class _Submission:
    """One client request, from admission to response."""
    specs: List[QuerySpec]
    budget: Optional[int]
    done: threading.Event = field(default_factory=threading.Event)
    rows: Optional[List[dict]] = None
    session: Optional[Dict[str, Any]] = None
    error: Optional[str] = None
    status: int = 200


class QueryServer:
    """Serves ``QuerySpec`` lists over HTTP against one shared engine.

        server = QueryServer(engine, store=store, admission_window=0.05)
        server.start()           # returns once the port is bound
        print(server.url)        # http://127.0.0.1:<port>
        ...
        server.shutdown()

    ``admission_window`` (seconds) is how long the first arrival of a batch
    waits for co-travelers; ``max_workers`` caps concurrently executing
    sessions.  Submissions carrying their own ``budget`` are never coalesced
    (a combined budget across strangers has no owner to answer to).
    """

    def __init__(self, engine: QueryEngine, host: str = "127.0.0.1",
                 port: int = 0, admission_window: float = 0.05,
                 max_workers: int = 4, store=None,
                 request_timeout: float = 600.0, session_kw: Optional[dict] = None):
        self.engine = engine
        self.host = host
        self.port = int(port)          # 0 = ephemeral; real port set by start()
        self.admission_window = float(admission_window)
        self.max_workers = int(max_workers)
        self.store = store
        self.request_timeout = float(request_timeout)
        self.session_kw = dict(session_kw or {})
        self.stats: Dict[str, int] = {
            "requests": 0,     # POST /query submissions admitted
            "specs": 0,        # specs across all submissions
            "sessions": 0,     # QuerySessions executed
            "coalesced": 0,    # submissions that shared another's session
            "errors": 0,       # sessions that raised
        }
        self._stats_lock = threading.Lock()
        self._queue: "queue.Queue" = queue.Queue()
        self._pool: Optional[ThreadPoolExecutor] = None
        self._http: Optional[ThreadingHTTPServer] = None
        self._threads: List[threading.Thread] = []
        self._started = False
        self._done = threading.Event()

    # -- lifecycle -----------------------------------------------------------
    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "QueryServer":
        if self._started:
            raise RuntimeError("server already started")
        self._started = True
        self._pool = ThreadPoolExecutor(
            max_workers=self.max_workers,
            thread_name_prefix="query-session")
        server = self

        class Handler(_Handler):
            owner = server

        self._http = ThreadingHTTPServer((self.host, self.port), Handler)
        self._http.daemon_threads = True
        self.port = self._http.server_address[1]
        self._admit_thread = threading.Thread(
            target=self._admission_loop, name="query-admit", daemon=True)
        self._http_thread = threading.Thread(
            target=self._http.serve_forever, name="query-http", daemon=True)
        self._threads = [self._admit_thread, self._http_thread]
        for t in self._threads:
            t.start()
        return self

    def shutdown(self) -> None:
        """Stop accepting, drain in-flight sessions, persist the store."""
        with self._stats_lock:
            if not self._started:
                return
            self._started = False
        self._queue.put(_STOP)
        if self._http is not None:
            self._http.shutdown()
            self._http.server_close()
        # the admission loop must be DONE handing batches to the pool before
        # the pool stops accepting, or an admitted batch dies on submit()
        # with its clients left waiting
        for t in self._threads:
            t.join(timeout=30.0)
        if self._pool is not None:
            self._pool.shutdown(wait=True)
        # sessions are drained: stop the engine's target-DNN replica pool
        # (no-op when sharding is off or the pool is externally owned)
        self.engine.close()
        if self.store is not None:
            self.store.save()
        self._done.set()

    def wait(self) -> None:
        """Block (interruptibly) until :meth:`shutdown` has fully finished —
        including the final store save.  The serving CLI parks on this."""
        while not self._done.wait(timeout=0.5):
            pass

    # -- admission -----------------------------------------------------------
    def submit(self, specs: List[QuerySpec],
               budget: Optional[int] = None) -> _Submission:
        """Enqueue one submission for the admission loop (HTTP-free entry
        point; the handler and in-process tests both use it).  Raises
        ``RuntimeError`` once shutdown has begun — callers must not be left
        waiting on a submission no loop will ever pick up."""
        sub = _Submission(specs=specs, budget=budget)
        with self._stats_lock:
            if not self._started:
                raise RuntimeError("server is shutting down")
            self.stats["requests"] += 1
            self.stats["specs"] += len(specs)
            # under the same lock shutdown() flips _started: either this
            # submission is enqueued before _STOP, or submit() raises
            self._queue.put(sub)
        return sub

    def _admission_loop(self) -> None:
        while True:
            sub = self._queue.get()
            if sub is _STOP:
                self._drain_on_stop()
                return
            batch = [sub]
            if sub.budget is None and self.admission_window > 0:
                deadline = time.monotonic() + self.admission_window
                while True:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    try:
                        nxt = self._queue.get(timeout=remaining)
                    except queue.Empty:
                        break
                    if nxt is _STOP:
                        self._queue.put(_STOP)  # handled next iteration
                        break
                    if nxt.budget is not None:
                        # budgeted submissions run alone (their cap is theirs)
                        self._dispatch([nxt])
                    else:
                        batch.append(nxt)
            self._dispatch(batch)

    def _dispatch(self, batch: List[_Submission]) -> None:
        try:
            self._pool.submit(self._run_batch, batch)
        except RuntimeError:  # pool already shut down: fail, don't strand
            for sub in batch:
                sub.error = "server is shutting down"
                sub.status = 503
                sub.done.set()

    def _drain_on_stop(self) -> None:
        """Fail any submission that raced in behind the _STOP sentinel
        instead of leaving its client blocked until request_timeout."""
        while True:
            try:
                sub = self._queue.get_nowait()
            except queue.Empty:
                return
            if sub is _STOP:
                continue
            sub.error = "server is shutting down"
            sub.status = 503
            sub.done.set()

    # -- execution -----------------------------------------------------------
    def _fail_batch(self, batch: List[_Submission], e: Exception,
                    status: int) -> None:
        with self._stats_lock:
            self.stats["errors"] += 1
        for sub in batch:
            sub.error = f"{type(e).__name__}: {e}"
            sub.status = status
            sub.done.set()

    def _run_batch(self, batch: List[_Submission]) -> None:
        specs = [s for sub in batch for s in sub.specs]
        budget = batch[0].budget if len(batch) == 1 else None
        session = QuerySession(self.engine, specs, budget=budget,
                               **self.session_kw)
        try:
            # plan separately first: it spends no oracle budget, and its
            # failures (malformed knobs, bad score names, impossible
            # budgets) are the CLIENT's — 400
            session.plan()
        except Exception as e:  # noqa: BLE001 - fault barrier per batch
            self._fail_batch(batch, e, 400)
            return
        try:
            out = session.execute()
        except Exception as e:  # noqa: BLE001 - execution faults are OURS
            self._fail_batch(batch, e, 500)
            return
        rows = [result_row(r) for r in out.results]
        session = {**out.stats,
                   "coalesced_requests": len(batch),
                   "coalesced_specs": len(specs)}
        pos = 0
        for sub in batch:
            sub.rows = rows[pos:pos + len(sub.specs)]
            pos += len(sub.specs)
            sub.session = session
            sub.done.set()
        with self._stats_lock:
            self.stats["sessions"] += 1
            self.stats["coalesced"] += len(batch) - 1

    # -- introspection -------------------------------------------------------
    def stats_payload(self) -> Dict[str, Any]:
        engine, broker = self.engine, self.engine.broker
        snapshot = broker.snapshot()
        with self._stats_lock:
            server_stats = dict(self.stats)
        payload: Dict[str, Any] = {
            "server": {**server_stats,
                       "admission_window_s": self.admission_window,
                       "max_workers": self.max_workers},
            "engine": dict(engine.stats),
            "broker": snapshot,
            "accounts": {
                # all-time totals come from the broker (the per-account ring
                # is bounded); "recent" is the last few specs' accounts
                "fresh_total": snapshot["fresh"],
                "cached_total": snapshot["cached"],
                "recent": broker.account_stats()[-32:],
            },
            "index": {"records": engine.index.n_records,
                      "reps": engine.index.n_reps,
                      "version": engine.index.version},
        }
        pool = engine.oracle_pool
        if pool is not None:
            payload["oracle_pool"] = pool.snapshot()
        if self.store is not None:
            payload["store"] = {"path": str(self.store.path),
                                "n_labels": len(self.store),
                                "index_version": self.store.index_version}
        return payload


class _Handler(BaseHTTPRequestHandler):
    owner: QueryServer = None  # bound per-server by QueryServer.start()

    def log_message(self, *args) -> None:  # quiet: stats are at /stats
        pass

    def _reply(self, status: int, payload: Dict[str, Any]) -> None:
        body = json.dumps(payload).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self) -> None:
        if self.path == "/healthz":
            self._reply(200, {"ok": True})
        elif self.path == "/stats":
            self._reply(200, self.owner.stats_payload())
        else:
            self._reply(404, {"error": f"unknown path {self.path}"})

    def do_POST(self) -> None:
        if self.path == "/shutdown":
            self._reply(200, {"ok": True, "shutting_down": True})
            # a fresh NON-daemon thread: shutdown() joins the serving threads
            # and must survive the main thread exiting (its final store.save
            # must not be killed mid-write)
            threading.Thread(target=self.owner.shutdown).start()
            return
        if self.path != "/query":
            self._reply(404, {"error": f"unknown path {self.path}"})
            return
        try:
            length = int(self.headers.get("Content-Length", 0))
            body = json.loads(self.rfile.read(length) or b"null")
            if isinstance(body, list):
                raw_specs, budget = body, None
            elif isinstance(body, dict):
                raw_specs = body.get("specs")
                budget = body.get("budget")
            else:
                raise ValueError("body must be a JSON list of specs or "
                                 "{'specs': [...], 'budget': int}")
            if not raw_specs:
                raise ValueError("no specs in request")
            specs = [QuerySpec.from_dict(d) for d in raw_specs]
        except (ValueError, TypeError, json.JSONDecodeError) as e:
            self._reply(400, {"error": f"{type(e).__name__}: {e}"})
            return
        try:
            sub = self.owner.submit(specs, budget=budget)
        except RuntimeError as e:
            self._reply(503, {"error": str(e)})
            return
        if not sub.done.wait(timeout=self.owner.request_timeout):
            self._reply(504, {"error": "query timed out in the session pool"})
            return
        if sub.error is not None:
            self._reply(sub.status, {"error": sub.error})
            return
        self._reply(200, {
            "results": sub.rows,
            "session": sub.session,
            "request": {
                "n_specs": len(sub.rows),
                "fresh": sum(r["n_oracle_fresh"] for r in sub.rows),
                "cached": sum(r["n_oracle_cached"] for r in sub.rows),
            },
        })
