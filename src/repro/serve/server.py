"""``QueryServer``: concurrent query sessions over many workloads, via HTTP.

The system's first long-lived, multi-client layer.  Clients POST JSON
``QuerySpec`` lists (the same schema as ``repro.launch.query``); the server

* **routes** — a :class:`~repro.serve.registry.WorkloadRegistry` mounts N
  workloads, each with its own :class:`~repro.core.index.TastiIndex`,
  :class:`~repro.core.engine.QueryEngine`, label store, and oracle replica
  pool; specs carry an optional ``workload`` field (or the request body a
  ``workload`` key) and default to the registry's default workload, so a
  single-workload server keeps today's API unchanged;
* **schedules** — every submission becomes a task in the
  :class:`~repro.serve.scheduler.QueryScheduler`'s waiting queue, ordered
  by priority class (``priority`` on specs or the request body, 0 = most
  urgent) and earliest deadline first within a class (``deadline_ms``),
  with per-workload weighted ``shares`` and hard ``caps`` on concurrent
  slots.  Long scans execute in oracle-slice-sized chunks, so a
  higher-class arrival preempts a running scan at its next slice boundary
  — labels and accounting stay byte-identical to unscheduled runs;
* **coalesces per workload** — with ``admission_window > 0``, unbudgeted
  requests arriving within the window are merged into a single shared
  :class:`~repro.core.session.QuerySession` at grant time, so strangers'
  queries share joint planning, the stratified sample, and one combined
  oracle flush (the whole point of sessions, paper §4/§5);
* **persists per workload** — with a :class:`~repro.serve.store.LabelStore`
  attached, every flush is written through to disk, so a restarted server
  answers repeats on *every* mounted workload with zero fresh target-DNN
  invocations.

Endpoints (all JSON):

* ``POST /query`` — body is either a list of spec dicts or
  ``{"specs": [...], "budget": int, "workload": str, "priority": int,
  "deadline_ms": float}``; responds with per-spec result rows plus
  session- and request-level label accounting;
* ``GET /stats`` — global server counters, a ``scheduler`` section
  (queues, slices, preemptions), plus a per-workload ``workloads`` map
  (engine/broker stats, queue depth and wait-time counters, store and
  index info); the default workload's sections are mirrored at top level
  for single-workload compatibility;
* ``GET /workloads`` — what is mounted: per workload name, default flag,
  loaded state, records/reps, store size, request count;
* ``GET /healthz`` — readiness probe (with per-workload loaded flags);
* ``GET /metrics`` — Prometheus text exposition: real counters/histograms
  (flush latency/size, queue wait, sub-batch latency, request latency,
  grants by reason) plus scrape-time samples derived from every layer's
  plain-dict counters (broker, engine, pool, resident, store, scheduler);
* ``GET /debug/traces`` — the flight recorder: recent trace summaries;
  ``?id=<trace_id>`` for one full trace, ``&format=chrome`` for a
  ``chrome://tracing`` / Perfetto-loadable document;
* ``POST /shutdown`` — clean stop (also available as ``server.shutdown()``).

Observability is ON by default (its disabled form is a set of no-op
objects; pass ``obs=False`` to measure the difference — the
``obs_overhead`` benchmark leg gates it at <= 5%).  Every request gets a
trace id (client-chosen via a body ``trace_id`` or ``X-Trace-Id`` header,
else generated) whose span tree runs admission -> scheduler queue ->
session plan/execute -> broker flush -> per-replica oracle sub-batches,
so each fresh label is attributable to exactly one span chain.
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, List, Optional, Tuple, Union
from urllib.parse import parse_qs, urlparse

from repro.core.codec import result_row
from repro.core.engine import QueryEngine, QuerySpec
from repro.core.session import QuerySession
from repro.obs import Observability, Sample
from repro.obs.trace import activate, chrome_trace
from repro.obs.trace import span as trace_span
from repro.serve.registry import DEFAULT_WORKLOAD, WorkloadEntry, WorkloadRegistry
from repro.serve.scheduler import DEFAULT_PRIORITY, QueryScheduler, ScheduledTask

_WL_COUNTERS = ("requests", "specs", "sessions", "coalesced", "errors")


class UnknownWorkload(ValueError):
    """A submission named a workload the registry has not mounted."""


@dataclass
class _Submission:
    """One client request, from admission to response."""
    specs: List[QuerySpec]
    budget: Optional[int]
    workload: str = DEFAULT_WORKLOAD
    done: threading.Event = field(default_factory=threading.Event)
    rows: Optional[List[dict]] = None
    session: Optional[Dict[str, Any]] = None
    error: Optional[str] = None
    status: int = 200
    trace: Any = None        # obs Trace (NULL_TRACE when tracing is off)
    queue_span: Any = None   # admission -> grant span, ended at grant
    created_at: float = 0.0  # monotonic admission time (latency histogram)


class QueryServer:
    """Serves ``QuerySpec`` lists over HTTP against mounted workloads.

        server = QueryServer(registry, admission_window=0.05)
        server.start()           # returns once the port is bound
        print(server.url)        # http://127.0.0.1:<port>
        ...
        server.shutdown()

    The first argument is either a :class:`WorkloadRegistry` (multi-workload)
    or a bare :class:`QueryEngine` — the legacy single-engine form, wrapped
    into a one-entry registry under the default workload name (``store``
    may only be passed in that form; registry entries carry their own).

    ``admission_window`` (seconds) is how long an unbudgeted request stays
    queued before it can run, during which co-travelers *on the same
    workload and priority class* merge into its session; 0 disables
    sharing.  ``max_workers`` caps concurrently executing sessions across
    all workloads.  Submissions carrying their own ``budget`` are never
    coalesced (a combined budget across strangers has no owner to answer
    to).

    Scheduling knobs: ``shares`` maps workload names to weighted-fair-share
    weights (default 1.0 each), ``workload_caps`` to hard per-workload
    concurrency caps; ``preempt`` lets strictly higher-class arrivals pause
    running scans at oracle-slice boundaries (``preempt_slice`` ids per
    slice, default: the workload engine's oracle microbatch size);
    ``default_priority`` is the class assigned to requests that set none.
    """

    def __init__(self, source: Union[QueryEngine, WorkloadRegistry],
                 host: str = "127.0.0.1",
                 port: int = 0, admission_window: float = 0.05,
                 max_workers: int = 4, store=None,
                 request_timeout: float = 600.0,
                 session_kw: Optional[dict] = None,
                 shares: Optional[Dict[str, float]] = None,
                 workload_caps: Optional[Dict[str, int]] = None,
                 preempt: bool = True,
                 preempt_slice: Optional[int] = None,
                 default_priority: int = DEFAULT_PRIORITY,
                 obs: Union[Observability, bool, None] = None):
        if isinstance(source, WorkloadRegistry):
            if store is not None:
                raise ValueError("store= only applies to the single-engine "
                                 "form; registry entries carry their own "
                                 "stores")
            self.registry = source
        else:
            self.registry = WorkloadRegistry()
            self.registry.register(DEFAULT_WORKLOAD, source, store=store)
        if not self.registry.names():
            raise ValueError("registry has no workloads mounted")
        self.host = host
        self.port = int(port)          # 0 = ephemeral; real port set by start()
        self.admission_window = float(admission_window)
        self.max_workers = int(max_workers)
        self.request_timeout = float(request_timeout)
        self.session_kw = dict(session_kw or {})
        self.shares = dict(shares or {})
        self.workload_caps = dict(workload_caps or {})
        self.preempt = bool(preempt)
        self.preempt_slice = preempt_slice
        self.default_priority = int(default_priority)
        self.stats: Dict[str, int] = {
            "requests": 0,     # POST /query submissions admitted
            "specs": 0,        # specs across all submissions
            "sessions": 0,     # QuerySessions executed
            "coalesced": 0,    # submissions that shared another's session
            "errors": 0,       # sessions that raised
        }
        self._stats_lock = threading.Lock()
        self._wl_stats: Dict[str, Dict[str, int]] = {}
        # observability: ON by default (None/True); obs=False serves with
        # the all-no-op bundle; an Observability instance is adopted as-is
        # (shared recorder/registry across servers, custom trace_buffer)
        if obs is None or obs is True:
            obs = Observability(enabled=True)
        elif obs is False:
            obs = Observability(enabled=False)
        self.obs: Observability = obs
        self.registry.set_obs(obs)
        obs.metrics.add_collector(self._collect_derived)
        self._h_latency: Dict[str, Any] = {}  # per-workload request latency
        self._scheduler: Optional[QueryScheduler] = None
        self._http: Optional[ThreadingHTTPServer] = None
        self._http_thread: Optional[threading.Thread] = None
        self._started = False
        self._done = threading.Event()

    # -- single-workload conveniences (legacy API; tests and benchmarks) -----
    @property
    def engine(self) -> QueryEngine:
        """The default workload's engine (loads it if still lazy)."""
        return self.registry.get().engine

    @property
    def store(self):
        """The default workload's label store (loads it if still lazy)."""
        return self.registry.get().store

    @property
    def scheduler(self) -> Optional[QueryScheduler]:
        """The live scheduler (None before :meth:`start`)."""
        return self._scheduler

    # -- lifecycle -----------------------------------------------------------
    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "QueryServer":
        if self._started:
            raise RuntimeError("server already started")
        self._started = True
        self._done.clear()   # a restarted server's wait() must block again
        self._scheduler = QueryScheduler(
            load=self._load_entry, run=self._run_batch, fail=self._fail_task,
            max_workers=self.max_workers, shares=self.shares,
            caps=self.workload_caps, admission_window=self.admission_window,
            preempt=self.preempt, preempt_slice=self.preempt_slice,
            obs=self.obs)
        server = self

        class Handler(_Handler):
            owner = server

        self._http = ThreadingHTTPServer((self.host, self.port), Handler)
        self._http.daemon_threads = True
        self.port = self._http.server_address[1]
        self._http_thread = threading.Thread(
            target=self._http.serve_forever, name="query-http", daemon=True)
        self._http_thread.start()
        return self

    def shutdown(self) -> None:
        """Stop accepting, shed the waiting queue (503), drain running and
        paused sessions, stop every engine's replica pool, persist every
        store."""
        with self._stats_lock:
            if not self._started:
                return
            self._started = False
            scheduler = self._scheduler
        if self._http is not None:
            self._http.shutdown()
            self._http.server_close()
        if self._http_thread is not None:
            self._http_thread.join(timeout=30.0)
        # the scheduler fails every waiting task fast and drains running and
        # paused sessions to completion before the registry sweep below
        if scheduler is not None:
            scheduler.shutdown(wait=True)
        # sessions are drained: per workload, stop the engine's target-DNN
        # replica pool and save the label store
        self.registry.close()
        self._done.set()

    def wait(self) -> None:
        """Block (interruptibly) until :meth:`shutdown` has fully finished —
        including the final store saves.  The serving CLI parks on this."""
        while not self._done.wait(timeout=0.5):
            pass

    # -- admission -----------------------------------------------------------
    def _resolve_workload(self, specs: List[QuerySpec],
                          workload: Optional[str]) -> str:
        """One submission routes to one workload: the request-level name
        (which covers every spec), else the specs' unanimous ``workload``
        fields, else the default.  Partial spec-level routing without a
        request-level name is rejected — silently dragging an unrouted
        spec onto its neighbor's index would answer it from the wrong
        workload."""
        explicit = {s.workload for s in specs if s.workload is not None}
        if len(explicit) > 1:
            raise ValueError(
                f"one request routes to one workload, got "
                f"{sorted(explicit)}; split the request per workload")
        if workload is not None:
            name = workload
            if explicit and explicit != {workload}:
                raise ValueError(
                    f"request routes to {workload!r} but a spec names "
                    f"{explicit.pop()!r}")
        elif explicit:
            name = explicit.pop()
            if any(s.workload is None for s in specs):
                raise ValueError(
                    "some specs carry a workload and others none; set the "
                    "request-level 'workload' or stamp every spec")
        else:
            name = self.registry.default
        if name not in self.registry:
            raise UnknownWorkload(
                f"unknown workload {name!r}; mounted: "
                f"{sorted(self.registry.names())}")
        return name

    def _resolve_priority(self, specs: List[QuerySpec],
                          priority: Optional[int]) -> int:
        """The submission's class: the most urgent (minimum) of the
        request-level value and any spec-level values; the server default
        when none is set."""
        values = []
        for v in [priority] + [s.priority for s in specs]:
            if v is None:
                continue
            if not isinstance(v, int) or isinstance(v, bool) or v < 0:
                raise ValueError(f"priority must be a non-negative integer, "
                                 f"got {v!r}")
            values.append(v)
        return min(values) if values else self.default_priority

    @staticmethod
    def _resolve_deadline(specs: List[QuerySpec],
                          deadline_ms: Optional[float]) -> Optional[float]:
        """The submission's EDF key: the tightest deadline named by the
        request or any spec, in milliseconds relative to arrival."""
        values = []
        for v in [deadline_ms] + [s.deadline_ms for s in specs]:
            if v is None:
                continue
            v = float(v)
            if v <= 0:
                raise ValueError(f"deadline_ms must be > 0, got {v}")
            values.append(v)
        return min(values) if values else None

    def submit(self, specs: List[QuerySpec], budget: Optional[int] = None,
               workload: Optional[str] = None,
               priority: Optional[int] = None,
               deadline_ms: Optional[float] = None,
               trace_id: Optional[str] = None) -> _Submission:
        """Enqueue one submission with the scheduler (HTTP-free entry point;
        the handler and in-process tests both use it).  Raises
        :class:`UnknownWorkload` for unmounted names, ``ValueError`` for
        bad priority/deadline values, and ``RuntimeError`` once shutdown
        has begun — callers must not be left waiting on a submission no
        scheduler will ever pick up."""
        name = self._resolve_workload(specs, workload)
        prio = self._resolve_priority(specs, priority)
        deadline_rel = self._resolve_deadline(specs, deadline_ms)
        sub = _Submission(specs=specs, budget=budget, workload=name)
        sub.created_at = time.monotonic()
        # the root of this request's span tree; the queue span runs from
        # admission until _run_batch/_fail_batch closes it at grant/failure
        sub.trace = self.obs.tracer.start(
            "request", trace_id=trace_id, workload=name, priority=prio,
            n_specs=len(specs))
        sub.queue_span = sub.trace.new_span("sched.queue")
        task = ScheduledTask(workload=name, submissions=[sub], priority=prio,
                             budget=budget)
        with self._stats_lock:
            if not self._started:
                raise RuntimeError("server is shutting down")
            self.stats["requests"] += 1
            self.stats["specs"] += len(specs)
            ws = self._wl_stats.setdefault(name,
                                           dict.fromkeys(_WL_COUNTERS, 0))
            ws["requests"] += 1
            ws["specs"] += len(specs)
            scheduler = self._scheduler
        # the relative deadline becomes absolute against the same monotonic
        # clock the scheduler orders by
        if deadline_rel is not None:
            task.deadline = time.monotonic() + deadline_rel / 1e3
        scheduler.submit(task)
        return sub

    # -- scheduler callbacks -------------------------------------------------
    def _load_entry(self, task: ScheduledTask) -> WorkloadEntry:
        return self.registry.get(task.workload)

    def _fail_task(self, task: ScheduledTask, e: Exception,
                   status: int) -> None:
        self._fail_batch(task.workload, task.submissions, e, status)

    # -- execution -----------------------------------------------------------
    def _bump(self, workload: str, **deltas: int) -> None:
        with self._stats_lock:
            ws = self._wl_stats.setdefault(workload,
                                           dict.fromkeys(_WL_COUNTERS, 0))
            for k, v in deltas.items():
                self.stats[k] += v
                ws[k] += v

    def _finish_trace(self, sub: _Submission, **attrs: Any) -> None:
        """Close a submission's trace into the flight recorder (no-op for
        trace-free submissions and disabled observability)."""
        trace = sub.trace
        if trace is None:
            return
        if sub.queue_span is not None:
            sub.queue_span.end()
        trace.set(**attrs)
        self.obs.tracer.finish(trace)

    def _fail_batch(self, workload: str, batch: List[_Submission],
                    e: Exception, status: int) -> None:
        self._bump(workload, errors=1)
        for sub in batch:
            sub.error = f"{type(e).__name__}: {e}"
            sub.status = status
            self._finish_trace(sub, error=sub.error, status=status)
            sub.done.set()

    def _run_batch(self, task: ScheduledTask, entry: WorkloadEntry) -> None:
        workload, batch = task.workload, task.submissions
        specs = [s for sub in batch for s in sub.specs]
        budget = batch[0].budget if len(batch) == 1 else None
        # the merged batch executes under the FIRST submission's trace;
        # absorbed co-travelers close their queue span here and their root
        # points at the primary trace that answered them
        primary_trace = batch[0].trace
        for sub in batch:
            if sub.queue_span is not None:
                sub.queue_span.end()
        if primary_trace is not None:
            for sub in batch[1:]:
                if sub.trace is not None:
                    sub.trace.set(coalesced_into=primary_trace.trace_id)
        scheduler = self._scheduler
        kw = dict(self.session_kw)
        if scheduler is not None:
            # the preemption contract: the session yields to the scheduler
            # between oracle slices; the scheduler may park it there
            kw.setdefault("checkpoint", lambda: scheduler.checkpoint(task))
            if scheduler.preempt_slice is not None:
                kw.setdefault("slice_size", scheduler.preempt_slice)
        # activate: every span opened below this thread (session prefetch,
        # broker flush, oracle sub-batches, preempt pauses) lands on the
        # primary trace without any layer holding a trace object
        with activate(primary_trace):
            session = QuerySession(entry.engine, specs, budget=budget, **kw)
            try:
                # plan separately first: it spends no oracle budget, and its
                # failures (malformed knobs, bad score names, impossible
                # budgets) are the CLIENT's — 400
                with trace_span("session.plan", n_specs=len(specs)):
                    session.plan()
            except Exception as e:  # noqa: BLE001 - fault barrier per batch
                self._fail_batch(workload, batch, e, 400)
                return
            try:
                with trace_span("session.execute") as esp:
                    out = session.execute()
            except Exception as e:  # noqa: BLE001 - execution faults are OURS
                self._fail_batch(workload, batch, e, 500)
                return
        rows = [result_row(r, workload=workload) for r in out.results]
        esp.set(fresh=out.stats.get("n_oracle_fresh"),
                cached=out.stats.get("n_oracle_cached"))
        session = {**out.stats,
                   "workload": workload,
                   "priority": task.priority,
                   "queue_wait_s": round(
                       (task.first_grant_at or task.enqueued_at)
                       - task.enqueued_at, 6),
                   "preemptions": task.preemptions,
                   "coalesced_requests": len(batch),
                   "coalesced_specs": len(specs)}
        now = time.monotonic()
        pos = 0
        for sub in batch:
            sub.rows = rows[pos:pos + len(sub.specs)]
            pos += len(sub.specs)
            sub.session = session
            self._finish_trace(
                sub, status=200,
                fresh=sum(r["n_oracle_fresh"] for r in sub.rows),
                cached=sum(r["n_oracle_cached"] for r in sub.rows),
                preemptions=task.preemptions,
                coalesced_requests=len(batch))
            self._latency_hist(workload).observe(
                now - (sub.created_at or task.enqueued_at))
            sub.done.set()
        self._bump(workload, sessions=1, coalesced=len(batch) - 1)

    # -- observability -------------------------------------------------------
    def _latency_hist(self, workload: str):
        """The per-workload request-latency histogram, resolved once.  A
        racing double-create is benign: the registry's family child() is
        get-or-create, both racers receive the same instrument."""
        h = self._h_latency.get(workload)
        if h is None:
            h = self.obs.histogram(
                "request_latency_seconds",
                help="submission admission-to-response latency",
                workload=workload)
            self._h_latency[workload] = h
        return h

    def _collect_derived(self) -> List[Sample]:
        """Scrape-time collector: every layer keeps plain-dict counters
        (zero registry traffic on its hot path); one pass here turns
        consistent snapshots of them (broker counters+accounts under one
        lock, scheduler under its condition) into Prometheus samples."""
        out: List[Sample] = []

        def c(name: str, value, help: str = "", **labels) -> None:
            out.append(Sample(name, float(value), "counter",
                              labels or None, help))

        def g(name: str, value, help: str = "", **labels) -> None:
            out.append(Sample(name, float(value), "gauge",
                              labels or None, help))

        with self._stats_lock:
            wl_stats = {k: dict(v) for k, v in self._wl_stats.items()}
            scheduler = self._scheduler
        for name, ws in wl_stats.items():
            for key, v in ws.items():
                c(f"server_{key}_total", v, workload=name)
        if scheduler is not None:
            snap = scheduler.snapshot()
            per_wl = snap.pop("workloads", {})
            c("sched_submitted_total", snap["submitted"])
            c("sched_slices_total", snap["slices"])
            c("sched_shed_total", snap["shed"])
            g("sched_active", snap["active"])
            g("sched_waiting", snap["waiting"])
            g("sched_paused", snap["paused"])
            for name, ws in per_wl.items():
                g("sched_queue_depth", ws["depth"], workload=name)
                c("sched_admitted_total", ws["admitted"], workload=name)
                c("sched_merged_total", ws["merged"], workload=name)
                c("sched_preempted_total", ws["preempted"], workload=name)
                g("sched_wait_max_seconds", ws["wait_max_s"], workload=name)
        for entry in self.registry.entries():
            if not entry.loaded:  # scraping must never trigger a lazy load
                continue
            name = entry.name
            engine = entry.engine
            broker_gauges = {"cache_size", "n_pending", "n_inflight",
                             "max_pending"}
            for key, v in engine.broker.observe(
                    recent_accounts=1)["stats"].items():
                if key in broker_gauges:
                    g(f"oracle_{key}", v, workload=name)
                else:
                    c(f"oracle_{key}_total", v, workload=name)
            for key, v in engine.stats.items():
                c(f"engine_{key}_total", v, workload=name)
            pool = engine.oracle_pool
            if pool is not None:
                ps = pool.snapshot()
                for key in ("flushes", "dispatched", "batches", "retries",
                            "failures", "steals"):
                    c(f"oracle_pool_{key}_total", ps[key], workload=name)
                for i, v in enumerate(ps["per_replica"]):
                    c("oracle_pool_replica_batches_total", v,
                      workload=name, replica=i)
                for i, v in enumerate(ps["per_replica_latency_ewma_s"]):
                    g("oracle_pool_replica_latency_ewma_seconds", v,
                      workload=name, replica=i)
                for i, v in enumerate(ps["per_replica_rate_ewma"]):
                    g("oracle_pool_replica_rate_ewma_labels_per_second", v,
                      workload=name, replica=i)
                for i, alive in enumerate(ps["per_replica_alive"]):
                    g("oracle_pool_replica_alive", 1 if alive else 0,
                      workload=name, replica=i)
            resident = getattr(engine, "resident", None)
            if resident is not None:
                for key, v in resident.stats.items():
                    c(f"resident_{key}_total", v, workload=name)
                g("resident_enabled", 1 if resident.enabled else 0,
                  workload=name)
            if entry.store is not None:
                tiers = entry.store.observe()
                g("label_store_labels", tiers["n_labels"], workload=name)
                g("label_store_tier_bytes", tiers["hot"]["bytes"],
                  "resident bytes per store tier",
                  workload=name, tier="hot")
                g("label_store_tier_bytes", tiers["warm"]["bytes"],
                  workload=name, tier="warm")
                g("label_store_tier_bytes", tiers["journal"]["bytes"],
                  workload=name, tier="journal")
                g("label_store_tier_entries", tiers["hot"]["entries"],
                  workload=name, tier="hot")
                g("label_store_tier_entries", tiers["warm"]["entries"],
                  workload=name, tier="warm")
                if tiers["hot"]["budget"] is not None:
                    g("label_store_hot_budget_bytes",
                      tiers["hot"]["budget"], workload=name)
                g("label_store_hot_pinned", tiers["hot"]["pinned"],
                  "hot entries not yet evictable (dirty or journal-only)",
                  workload=name)
                c("label_store_hits_total", tiers["hits"]["hot"],
                  "broker cache hits answered per store tier",
                  workload=name, tier="hot")
                c("label_store_hits_total", tiers["hits"]["warm"],
                  workload=name, tier="warm")
                g("label_store_warm_segments",
                  tiers["warm"]["segments"], workload=name)
                g("label_store_journal_segments",
                  tiers["journal"]["segments"], workload=name)
                g("label_store_journal_oldest_age_seconds",
                  tiers["journal"]["oldest_age_s"],
                  "age of the oldest un-compacted journal byte",
                  workload=name)
                for key, v in tiers["counters"].items():
                    if key.startswith("hits_"):
                        continue  # exported above, tier-labeled
                    c(f"label_store_{key}_total", v, workload=name)
            g("index_records", engine.index.n_records, workload=name)
            g("index_reps", engine.index.n_reps, workload=name)
            g("index_version", engine.index.version, workload=name)
        recorder = self.obs.recorder
        if recorder is not None:
            c("traces_recorded_total", recorder.recorded)
            g("traces_buffered", len(recorder))
        return out

    def metrics_payload(self) -> str:
        """The Prometheus text exposition (``GET /metrics`` body)."""
        return self.obs.metrics.render()

    def traces_payload(self, trace_id: Optional[str] = None,
                       fmt: Optional[str] = None,
                       limit: int = 32) -> Tuple[Dict[str, Any], int]:
        """(payload, status) for ``GET /debug/traces``: recent trace
        summaries, one full trace by id, or its Chrome-trace export."""
        recorder = self.obs.recorder
        if recorder is None:
            return {"error": "observability is disabled"}, 404
        if trace_id is None:
            summaries = recorder.summaries()
            if limit > 0:
                summaries = summaries[-limit:]
            return {"recorded": recorder.recorded,
                    "buffered": len(recorder),
                    "traces": summaries}, 200
        trace = recorder.find(trace_id)
        if trace is None:
            return {"error": f"trace {trace_id!r} is not in the flight "
                             f"recorder (capacity {recorder.capacity})"}, 404
        if fmt == "chrome":
            return chrome_trace(trace), 200
        return trace.to_dict(), 200

    # -- introspection -------------------------------------------------------
    @staticmethod
    def _entry_payload(entry: WorkloadEntry) -> Dict[str, Any]:
        """Engine/broker/accounts/index/store/pool sections for one loaded
        workload (the pre-registry /stats body, now per workload)."""
        engine = entry.engine
        broker = engine.broker
        # counters AND account rows under one broker lock pass: a scrape
        # racing a flush can never pair totals and accounts from different
        # instants (the flush publish phase bumps both atomically)
        observed = broker.observe(recent_accounts=32)
        snapshot = observed["stats"]
        payload: Dict[str, Any] = {
            "engine": dict(engine.stats),
            "broker": snapshot,
            "accounts": {
                # all-time totals come from the broker (the per-account ring
                # is bounded); "recent" is the last few specs' accounts
                "fresh_total": snapshot["fresh"],
                "cached_total": snapshot["cached"],
                "recent": observed["accounts"],
            },
            "index": {"records": engine.index.n_records,
                      "reps": engine.index.n_reps,
                      "version": engine.index.version},
        }
        pool = engine.oracle_pool
        if pool is not None:
            payload["oracle_pool"] = pool.snapshot()
        if entry.store is not None:
            tiers = entry.store.observe()
            payload["store"] = {"path": str(entry.store.path),
                                "n_labels": tiers["n_labels"],
                                "index_version": entry.store.index_version,
                                "tiers": tiers}
        return payload

    def stats_payload(self) -> Dict[str, Any]:
        default = self.registry.default
        with self._stats_lock:
            server_stats = dict(self.stats)
            wl_stats = {k: dict(v) for k, v in self._wl_stats.items()}
            scheduler = self._scheduler
        sched_snap = scheduler.snapshot() if scheduler is not None else {}
        sched_wl = sched_snap.pop("workloads", {})
        recorder = self.obs.recorder
        payload: Dict[str, Any] = {
            "server": {**server_stats,
                       "admission_window_s": self.admission_window,
                       "max_workers": self.max_workers,
                       "default_workload": default,
                       "scheduler": sched_snap,
                       "observability": {
                           "enabled": self.obs.enabled,
                           "traces_recorded": (recorder.recorded
                                               if recorder else 0),
                           "traces_buffered": (len(recorder)
                                               if recorder else 0)}},
            "workloads": {},
        }
        for entry in self.registry.entries():
            wp: Dict[str, Any] = {"loaded": entry.loaded}
            if entry.loaded:
                wp.update(self._entry_payload(entry))
            wp["server"] = wl_stats.get(entry.name,
                                        dict.fromkeys(_WL_COUNTERS, 0))
            # per-workload queue observability: depth + wait-time counters
            wp["queue"] = sched_wl.get(entry.name, {
                "depth": 0, "active": 0, "share": 1.0, "cap": None,
                "admitted": 0, "merged": 0, "preempted": 0,
                "wait_mean_s": 0.0, "wait_max_s": 0.0})
            payload["workloads"][entry.name] = wp
        # single-workload compatibility: the default workload's sections are
        # mirrored at top level (exactly the pre-registry payload shape) —
        # the SAME dict objects, one broker snapshot, so the mirror can
        # never disagree with the per-workload section within one response
        mirror = payload["workloads"].get(default)
        if mirror is not None and mirror["loaded"]:
            payload.update({k: v for k, v in mirror.items()
                            if k not in ("loaded", "server", "queue")})
        return payload

    def workloads_payload(self) -> Dict[str, Any]:
        with self._stats_lock:
            wl_stats = {k: dict(v) for k, v in self._wl_stats.items()}
        rows = self.registry.describe()
        for row in rows:
            row["requests"] = wl_stats.get(row["name"], {}).get("requests", 0)
        return {"default": self.registry.default, "workloads": rows}

    def health_payload(self) -> Dict[str, Any]:
        workloads = {}
        for e in self.registry.entries():
            w: Dict[str, Any] = {"loaded": e.loaded}
            if e.load_error is not None:
                w["error"] = str(e.load_error)
            workloads[e.name] = w
        # ok means the server itself is serving; a dead mount is visible
        # per workload (its requests fail fast with the memoized error)
        return {"ok": True, "workloads": workloads}


class _Handler(BaseHTTPRequestHandler):
    owner: QueryServer = None  # bound per-server by QueryServer.start()

    def log_message(self, *args) -> None:  # quiet: stats are at /stats
        pass

    def _reply(self, status: int, payload: Dict[str, Any]) -> None:
        body = json.dumps(payload).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _reply_text(self, status: int, text: str) -> None:
        body = text.encode()
        self.send_response(status)
        self.send_header("Content-Type",
                         "text/plain; version=0.0.4; charset=utf-8")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self) -> None:
        parsed = urlparse(self.path)
        path = parsed.path
        if path == "/healthz":
            self._reply(200, self.owner.health_payload())
        elif path == "/stats":
            self._reply(200, self.owner.stats_payload())
        elif path == "/workloads":
            self._reply(200, self.owner.workloads_payload())
        elif path == "/metrics":
            self._reply_text(200, self.owner.metrics_payload())
        elif path == "/debug/traces":
            q = parse_qs(parsed.query)
            try:
                limit = int(q.get("limit", ["32"])[0])
            except ValueError:
                self._reply(400, {"error": "limit must be an integer"})
                return
            payload, status = self.owner.traces_payload(
                trace_id=q.get("id", [None])[0],
                fmt=q.get("format", [None])[0],
                limit=limit)
            self._reply(status, payload)
        else:
            self._reply(404, {"error": f"unknown path {self.path}"})

    def do_POST(self) -> None:
        if self.path == "/shutdown":
            self._reply(200, {"ok": True, "shutting_down": True})
            # a fresh NON-daemon thread: shutdown() joins the serving threads
            # and must survive the main thread exiting (its final store save
            # must not be killed mid-write)
            threading.Thread(target=self.owner.shutdown).start()
            return
        if self.path != "/query":
            self._reply(404, {"error": f"unknown path {self.path}"})
            return
        try:
            length = int(self.headers.get("Content-Length", 0))
            body = json.loads(self.rfile.read(length) or b"null")
            workload = priority = deadline_ms = None
            trace_id = self.headers.get("X-Trace-Id")
            if isinstance(body, list):
                raw_specs, budget = body, None
            elif isinstance(body, dict):
                raw_specs = body.get("specs")
                budget = body.get("budget")
                workload = body.get("workload")
                priority = body.get("priority")
                deadline_ms = body.get("deadline_ms")
                trace_id = body.get("trace_id", trace_id)
            else:
                raise ValueError(
                    "body must be a JSON list of specs or {'specs': [...], "
                    "'budget': int, 'workload': str, 'priority': int, "
                    "'deadline_ms': float}")
            if not raw_specs:
                raise ValueError("no specs in request")
            specs = [QuerySpec.from_dict(d) for d in raw_specs]
        except (ValueError, TypeError, json.JSONDecodeError) as e:
            self._reply(400, {"error": f"{type(e).__name__}: {e}"})
            return
        try:
            sub = self.owner.submit(specs, budget=budget, workload=workload,
                                    priority=priority,
                                    deadline_ms=deadline_ms,
                                    trace_id=trace_id)
        except ValueError as e:  # unknown workload / bad priority or deadline
            self._reply(400, {"error": str(e)})
            return
        except RuntimeError as e:
            self._reply(503, {"error": str(e)})
            return
        if not sub.done.wait(timeout=self.owner.request_timeout):
            self._reply(504, {"error": "query timed out in the session pool"})
            return
        if sub.error is not None:
            self._reply(sub.status, {"error": sub.error})
            return
        self._reply(200, {
            "results": sub.rows,
            "session": sub.session,
            "request": {
                "workload": sub.workload,
                "n_specs": len(sub.rows),
                "fresh": sum(r["n_oracle_fresh"] for r in sub.rows),
                "cached": sum(r["n_oracle_cached"] for r in sub.rows),
                # "" when tracing is off (NULL_TRACE) -> omit as None
                "trace_id": getattr(sub.trace, "trace_id", None) or None,
            },
        })
