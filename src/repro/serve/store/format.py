"""On-disk format constants and small pure helpers for the tiered store.

Everything here is deliberately dependency-light: path naming, the format
version, the approximate-bytes estimator the hot tier budgets with, the
bloom-style per-segment id membership filter, and human byte-size parsing
for ``--store-budget``.  See ``docs/api/label-store.md`` for the format
table these constants pin down.
"""
from __future__ import annotations

import pathlib
import sys
from typing import Optional

import numpy as np

#: Version 2 is the tiered layout: a manifest (``.labels.json``) naming warm
#: segment files, a global id index (``.labels.npz``), rotating journal
#: segments.  Version 1 (one inline snapshot + one journal) is still READ —
#: its labels load pinned-hot and migrate to v2 on the next compaction.
FORMAT_VERSION = 2

#: Rotate the active journal once it crosses this many bytes (override per
#: store; a hot budget shrinks it so pinned journal backlog stays small).
DEFAULT_JOURNAL_ROTATE_BYTES = 256 << 10
#: Kick a background compaction once this many sealed journals accumulate.
DEFAULT_COMPACT_AFTER = 4
#: Fold every warm segment into one when a compaction would exceed this.
DEFAULT_MAX_SEGMENTS = 8

_SUFFIX_MANIFEST = ".labels.json"
_SUFFIX_IDS = ".labels.npz"
_SUFFIX_JOURNAL = ".labels.jsonl"


def sib(stem: pathlib.Path, suffix: str) -> pathlib.Path:
    """Sibling file of a store stem.  Suffixes are appended (not
    substituted) so dotted stems survive."""
    return stem.parent / (stem.name + suffix)


def manifest_path(stem: pathlib.Path) -> pathlib.Path:
    return sib(stem, _SUFFIX_MANIFEST)


def ids_path(stem: pathlib.Path) -> pathlib.Path:
    return sib(stem, _SUFFIX_IDS)


def journal_path(stem: pathlib.Path) -> pathlib.Path:
    return sib(stem, _SUFFIX_JOURNAL)


def sealed_journal_path(stem: pathlib.Path, seq: int) -> pathlib.Path:
    return sib(stem, f".labels.jnl-{seq:06d}.jsonl")


def segment_ids_path(stem: pathlib.Path, seq: int) -> pathlib.Path:
    return sib(stem, f".labels.seg-{seq:06d}.npz")


def segment_ann_path(stem: pathlib.Path, seq: int) -> pathlib.Path:
    return sib(stem, f".labels.seg-{seq:06d}.ann.jsonl")


def sealed_journals(stem: pathlib.Path) -> list:
    """Sealed journal files next to ``stem``, ascending by sequence."""
    return sorted(stem.parent.glob(stem.name + ".labels.jnl-*.jsonl"))


def store_files(stem: pathlib.Path) -> list:
    """Every file the store owns at ``stem`` (for orphan cleanup)."""
    out = [manifest_path(stem), ids_path(stem), journal_path(stem)]
    out += sealed_journals(stem)
    out += sorted(stem.parent.glob(stem.name + ".labels.seg-*"))
    return out


def log(msg: str) -> None:
    """Operator-facing store event line (lineage invalidation, corrupt-file
    degradation, compaction) — one grep-able prefix, documented in
    ``docs/runbook.md``."""
    print(f"[label-store] {msg}", file=sys.stderr)


# ---------------------------------------------------------------------------
# Approximate in-memory footprint of an annotation.  The hot tier budgets
# tracked bytes, not entry counts; this estimator only has to be consistent
# and monotone in payload size, not exact to the allocator.
# ---------------------------------------------------------------------------
def approx_nbytes(a) -> int:
    boxes = getattr(a, "boxes", None)  # schema.Scene without the import;
    if boxes is not None:              # first: the dominant video payload
        if isinstance(boxes, np.ndarray):
            return 112 + int(boxes.nbytes)
        return 112 + int(np.asarray(boxes).nbytes)
    if a is None or isinstance(a, (bool, int, float, np.integer, np.floating)):
        return 16
    if isinstance(a, str):
        return 56 + len(a)
    if isinstance(a, np.ndarray):
        return int(a.nbytes) + 112
    if isinstance(a, (list, tuple)):
        return 64 + sum(approx_nbytes(x) for x in a)
    if isinstance(a, dict):
        return 64 + sum(approx_nbytes(k) + approx_nbytes(v)
                        for k, v in a.items())
    return 64  # TextRecord and other small schema records


def parse_bytes(value) -> Optional[int]:
    """``--store-budget`` / manifest spelling of a byte count: an int, or a
    string with an optional k/m/g suffix (``"64k"``, ``"1.5m"``)."""
    if value is None:
        return None
    if isinstance(value, bool):
        raise ValueError(f"byte size must be a number, got {value!r}")
    if isinstance(value, (int, float, np.integer)):
        n = int(value)
    else:
        s = str(value).strip().lower()
        mult = 1
        for suffix, m in (("g", 1 << 30), ("m", 1 << 20), ("k", 1 << 10),
                          ("b", 1)):
            if s.endswith(suffix):
                s, mult = s[:-len(suffix)], m
                break
        try:
            n = int(float(s) * mult)
        except ValueError:
            raise ValueError(f"cannot parse byte size {value!r} "
                             "(want e.g. 1048576, '64k', '1.5m')") from None
    if n <= 0:
        raise ValueError(f"byte size must be positive, got {value!r}")
    return n


# ---------------------------------------------------------------------------
# Bloom-style id membership, vectorized over numpy int64 ids.  Three mixed
# hashes into a byte-aligned bitset; false positives only cost a wasted
# searchsorted, so ~8 bits/id keeps them rare without mattering if not.
# ---------------------------------------------------------------------------
_BLOOM_BITS_PER_ID = 8
_BLOOM_SEEDS = (0x9E3779B97F4A7C15, 0xC2B2AE3D27D4EB4F, 0x165667B19E3779F9)


def _bloom_positions(ids: np.ndarray, n_bits: int) -> np.ndarray:
    x = np.asarray(ids, np.int64).astype(np.uint64)
    rows = []
    with np.errstate(over="ignore"):
        for seed in _BLOOM_SEEDS:
            h = (x + np.uint64(seed)) * np.uint64(0xFF51AFD7ED558CCD)
            h ^= h >> np.uint64(33)
            h *= np.uint64(0xC4CEB9FE1A85EC53)
            h ^= h >> np.uint64(33)
            rows.append(h % np.uint64(n_bits))
    return np.stack(rows)


def bloom_build(ids: np.ndarray) -> np.ndarray:
    """uint8 bitset with every id's bloom bits set."""
    n_bits = max(64, 8 * ((len(ids) * _BLOOM_BITS_PER_ID + 7) // 8))
    bits = np.zeros(n_bits // 8, np.uint8)
    pos = _bloom_positions(ids, n_bits).ravel()
    np.bitwise_or.at(bits, (pos >> np.uint64(3)).astype(np.intp),
                     np.left_shift(np.uint8(1), (pos & np.uint64(7)).astype(np.uint8)))
    return bits


def bloom_maybe_contains(bits: np.ndarray, ids: np.ndarray) -> np.ndarray:
    """Boolean mask: which of ``ids`` MAY be members (no false negatives)."""
    n_bits = len(bits) * 8
    pos = _bloom_positions(ids, n_bits)
    byte = (pos >> np.uint64(3)).astype(np.intp)
    mask = np.left_shift(np.uint8(1), (pos & np.uint64(7)).astype(np.uint8))
    hit = (bits[byte] & mask) != 0
    return hit.all(axis=0)
