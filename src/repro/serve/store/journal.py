"""Rotating write-ahead journal for the label store.

The *active* journal keeps the v1 contract byte-for-byte: JSONL at
``<stem>.labels.jsonl``, a lineage header on line 0, one fsync'd
``{"ids": [...], "annotations": [...]}`` line per broker flush — O(batch),
crash-safe up to a torn final line.  What is new is **rotation**: once the
active file crosses ``rotate_bytes`` it is sealed by a single atomic
rename to ``<stem>.labels.jnl-N.jsonl`` (crash-safe at the boundary: the
rename either happened or it did not, and replay reads sealed files in
sequence order then the active file, applying the torn-tail rule to each
independently).  Sealed journals are immutable; compaction folds them into
warm segments and unlinks them.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.serve.store import format as fmt

_SEALED_SEQ = re.compile(r"\.labels\.jnl-(\d+)\.jsonl$")


class JournalWriter:
    """Appends + rotation for one store stem; the owning store locks."""

    def __init__(self, stem: pathlib.Path, lineage: Callable[[], Dict],
                 rotate_bytes: int = fmt.DEFAULT_JOURNAL_ROTATE_BYTES):
        self.stem = stem
        self.path = fmt.journal_path(stem)
        self.rotate_bytes = int(rotate_bytes)
        self._lineage = lineage
        self._active_since: Optional[float] = None
        self.sealed: List[pathlib.Path] = fmt.sealed_journals(stem)

    def next_seq(self) -> int:
        seqs = [int(m.group(1)) for p in self.sealed
                if (m := _SEALED_SEQ.search(p.name))]
        return (max(seqs) + 1) if seqs else 1

    def append(self, ids: List[int], encoded: List[Any]) -> bool:
        """Durably append one batch; returns True when the append sealed
        the active file (rotation happened)."""
        entry = {"ids": ids, "annotations": encoded}
        new = not self.path.exists()
        self.path.parent.mkdir(parents=True, exist_ok=True)
        with open(self.path, "a") as f:
            if new:
                f.write(json.dumps(self._lineage()) + "\n")
                self._active_since = time.time()
            f.write(json.dumps(entry) + "\n")
            f.flush()
            os.fsync(f.fileno())
            size = f.tell()
        if size >= self.rotate_bytes:
            self.rotate()
            return True
        return False

    def rotate(self) -> Optional[pathlib.Path]:
        """Seal the active journal (atomic rename); no-op when empty."""
        if not self.path.exists():
            return None
        sealed = fmt.sealed_journal_path(self.stem, self.next_seq())
        os.replace(self.path, sealed)
        self.sealed.append(sealed)
        self._active_since = None
        return sealed

    def drop(self, paths: List[pathlib.Path]) -> None:
        """Forget + unlink sealed journals a compaction subsumed."""
        for p in paths:
            p.unlink(missing_ok=True)
        gone = set(paths)
        self.sealed = [p for p in self.sealed if p not in gone]

    def clear(self) -> None:
        """Unlink everything (a full save subsumed all journal content)."""
        self.drop(list(self.sealed))
        self.path.unlink(missing_ok=True)
        self._active_since = None

    def nbytes(self) -> int:
        total = 0
        for p in [*self.sealed, self.path]:
            try:
                total += p.stat().st_size
            except OSError:
                pass
        return total

    def oldest_age_s(self) -> float:
        """Seconds since the oldest un-compacted journal byte was written
        (0 when no journal exists) — the 'how far behind is compaction'
        gauge."""
        oldest: Optional[float] = None
        for p in self.sealed:
            try:
                m = p.stat().st_mtime
            except OSError:
                continue
            oldest = m if oldest is None else min(oldest, m)
        if oldest is None and self._active_since is not None:
            oldest = self._active_since
        return max(0.0, time.time() - oldest) if oldest is not None else 0.0


def read_journal(path: pathlib.Path,
                 lineage_matches: Callable[[Dict], bool]
                 ) -> Tuple[Dict[int, Any], int]:
    """``({id: ENCODED annotation}, n_records)`` from one journal file.

    Line 0 must be a lineage header matching this store, else the whole
    file belongs to another index generation and is ignored.  A torn line
    (crash mid-append) stops the replay of *this* file; later files (and
    the active journal) are read independently.
    """
    out: Dict[int, Any] = {}
    n = 0
    if not path.exists():
        return out, 0
    with open(path) as f:
        for lineno, line in enumerate(f):
            try:
                entry = json.loads(line)
            except json.JSONDecodeError:
                break  # torn tail: keep everything before it
            if lineno == 0:
                if not lineage_matches(entry):
                    return {}, 0
                continue
            for i, a in zip(entry["ids"], entry["annotations"]):
                out[int(i)] = a
                n += 1
    return out, n
