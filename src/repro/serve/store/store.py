"""Tiered persistent oracle-label store: hot RAM -> warm segments -> oracle.

TASTI's economics price everything in target-DNN invocations, and one index
amortizes labels across *many* queries (paper §5-6) — so the label cache
must outlive the process AND scale past RAM.  A :class:`LabelStore` keeps
``{record id: target-DNN annotation}`` in three places:

* the **hot tier** (:mod:`repro.serve.store.hot`) — an in-memory LRU map
  bounded by *tracked approximate bytes* (``hot_budget``; unbounded when
  None).  Only entries that are also readable from the warm tier are
  evictable, so budget pressure can never lose a paid label;
* the **warm tier** (:mod:`repro.serve.store.segments`) — immutable
  compacted segment files (sorted-id npz + offset-addressed JSONL
  annotations, min/max-id fences + bloom membership, mmap-backed reads);
* the **journal** (:mod:`repro.serve.store.journal`) — the rotating
  write-ahead log every broker flush lands in, fsync'd and O(batch);
  sealed journal segments are folded into warm segments by background
  compaction (or synchronously under budget pressure, or by :meth:`save`).

:meth:`attach` hands the broker a dict-like **tiered cache view** instead
of seeding a plain dict: a broker miss falls through hot -> warm -> oracle,
warm hits are promoted (then the LRU rebalances), and every fresh flush is
journaled write-through.  The **lineage check** is unchanged from v1: the
store records the index's crack ``version`` and an embedding-content
:func:`index_fingerprint`, and :meth:`open` discards (with a logged
warning, never a crash — labels are re-derivable) anything whose lineage
or bytes do not check out.
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import threading
from typing import Any, Dict, Iterable, Optional

import numpy as np

from repro.core.index import _decode_annotation, _encode_annotation
from repro.core.persist import atomic_write
from repro.serve.store import format as fmt
from repro.serve.store.hot import CLEAN, DIRTY, PINNED, HotTier
from repro.serve.store.journal import JournalWriter, read_journal
from repro.serve.store.segments import WarmSegment, WarmTier, write_segment


def index_fingerprint(index) -> str:
    """A cheap content identity for the dataset behind ``index``: sha256
    over the embedding array's shape/dtype and a strided byte sample.
    Stable across cracking (cracks add representatives, never touch
    embeddings), different across datasets — the check that stops a reused
    ``--store`` path from serving another workload's labels."""
    emb = np.ascontiguousarray(index.embeddings)
    h = hashlib.sha256()
    h.update(repr((emb.shape, str(emb.dtype))).encode())
    flat = emb.view(np.uint8).ravel()
    h.update(flat[::max(1, len(flat) // 65536)].tobytes())
    return h.hexdigest()[:32]


class _TieredCacheView:
    """The dict-like object :meth:`LabelStore.attach` installs as
    ``broker.cache``: membership and reads fall through hot -> warm (with
    promotion), writes land in the hot tier, and :meth:`record_hit` is the
    broker's counted per-charge probe (tier attribution for the
    ``label_store_hits_total{tier=}`` accounting)."""

    __slots__ = ("_store",)

    def __init__(self, store: "LabelStore"):
        self._store = store

    def __contains__(self, i: int) -> bool:
        return i in self._store

    def __len__(self) -> int:
        return len(self._store)

    def __getitem__(self, i: int) -> Any:
        return self._store.broker_get(i)

    def __setitem__(self, i: int, a: Any) -> None:
        self._store.update({i: a})

    def update(self, labeled) -> None:
        if labeled:
            self._store.update(dict(labeled))

    def record_hit(self, i: int) -> None:
        self._store.record_hit(i)


class LabelStore:
    """Tiered label store with v1-compatible surface.

        store = LabelStore.for_index("/tmp/tasti/ns", index,
                                     hot_budget=64 << 20)
        store.attach(engine.broker, engine)   # tiered cache + write-through
        ... queries run; every flush journals; compaction folds to warm ...
        store.save()                          # full compact (shutdown does)
    """

    FORMAT_VERSION = fmt.FORMAT_VERSION

    def __init__(self, path: str, index_version: int = 0,
                 fingerprint: Optional[str] = None,
                 labels: Optional[Dict[int, Any]] = None,
                 hot_budget: Optional[int] = None,
                 journal_rotate_bytes: Optional[int] = None,
                 compact_after: int = fmt.DEFAULT_COMPACT_AFTER,
                 max_segments: int = fmt.DEFAULT_MAX_SEGMENTS,
                 auto_compact: bool = True):
        self.path = pathlib.Path(path)
        self.index_version = int(index_version)
        self.fingerprint = fingerprint
        hot_budget = fmt.parse_bytes(hot_budget)
        if journal_rotate_bytes is None:
            # with a budget, keep the journal backlog (pinned, unevictable)
            # a fraction of it so compaction — not pinning — absorbs pressure
            journal_rotate_bytes = fmt.DEFAULT_JOURNAL_ROTATE_BYTES
            if hot_budget is not None:
                journal_rotate_bytes = min(journal_rotate_bytes,
                                           max(4096, hot_budget // 4))
        self._hot = HotTier(budget=hot_budget)
        self._warm = WarmTier(self.path)
        self._journal = JournalWriter(self.path, self._lineage,
                                      rotate_bytes=journal_rotate_bytes)
        self._compact_after = int(compact_after)
        self._max_segments = int(max_segments)
        self._auto_compact = bool(auto_compact)
        self._compacting = False
        self._next_seg_seq = 1
        self._n = 0                 # distinct ids across hot + warm
        self._lock = threading.RLock()
        self.stats: Dict[str, int] = {
            "journal_appends": 0,    # write-through batches journaled
            "journal_records": 0,    # labels across those batches
            "journal_rotations": 0,  # active-journal seals
            "compactions": 0,        # journal/segment folds (incl. save())
            "evictions": 0,          # hot entries dropped to budget
            "hits_hot": 0,           # tier-attributed broker cache hits
            "hits_warm": 0,
        }
        # does the on-disk state carry THIS store's lineage in v2 form?
        # attach() compacts first when it does not (fresh stem, stale
        # lineage, or a v1 snapshot awaiting migration)
        self._disk_valid = False
        if labels:
            self.update(labels)

    # -- paths (v1-compatible names) -----------------------------------------
    @property
    def json_path(self) -> pathlib.Path:
        """The manifest (v2) / snapshot (v1) file."""
        return fmt.manifest_path(self.path)

    @property
    def npz_path(self) -> pathlib.Path:
        """The global sorted-id index over every warm segment."""
        return fmt.ids_path(self.path)

    @property
    def journal_path(self) -> pathlib.Path:
        """The ACTIVE journal; sealed rotations live at
        ``<stem>.labels.jnl-N.jsonl`` until compaction folds them."""
        return fmt.journal_path(self.path)

    def __len__(self) -> int:
        return self._n

    def __contains__(self, i) -> bool:
        i = int(i)
        with self._lock:
            return i in self._hot or self._warm.contains(i)

    @property
    def labels(self) -> Dict[int, Any]:
        """Every label, materialized across tiers (warm overlaid by hot).
        A full-store read — tests and small tools, not the serving path."""
        with self._lock:
            out = self._warm.load_all()
            out.update(self._hot.items())
            return out

    def _lineage(self) -> Dict[str, Any]:
        return {"format_version": self.FORMAT_VERSION,
                "index_version": self.index_version,
                "fingerprint": self.fingerprint}

    def _lineage_matches(self, meta: Dict[str, Any]) -> bool:
        if int(meta.get("index_version", -1)) != self.index_version:
            return False
        stored = meta.get("fingerprint")
        if self.fingerprint is not None and stored != self.fingerprint:
            return False
        return True

    # -- open ----------------------------------------------------------------
    @classmethod
    def for_index(cls, path: str, index, **config) -> "LabelStore":
        """The store next to ``path``, validated against ``index``'s full
        lineage (crack version + embedding fingerprint)."""
        return cls.open(path, index.version,
                        fingerprint=index_fingerprint(index), **config)

    @classmethod
    def open(cls, path: str, index_version: int,
             fingerprint: Optional[str] = None, **config) -> "LabelStore":
        """The store at ``path`` if present *and* cached against the given
        index lineage; otherwise a fresh empty store.

        A lineage mismatch (the index was cracked and re-saved after the
        store was written, rolled back, or the stem was reused for another
        dataset) invalidates the store: it comes back empty and the stale
        files are overwritten on the next save.  Corrupt or torn files
        (half-written v1 snapshot, missing segment) **degrade** the same
        way with a logged warning instead of failing startup — labels are
        re-derivable; a crashed server is not.  After the manifest, sealed
        journal segments replay in sequence order, then the active journal
        (a torn final line — crash mid-append — stops that file's replay
        there)."""
        store = cls(path, index_version=index_version,
                    fingerprint=fingerprint, **config)
        store._load_disk()
        store._replay_journals()
        with store._lock:
            store._enforce_budget(allow_compact=False)
        return store

    def _load_disk(self) -> None:
        if not self.json_path.exists():
            return
        try:
            with open(self.json_path) as f:
                meta = json.load(f)
        except (json.JSONDecodeError, OSError) as e:
            fmt.log(f"{self.json_path} is unreadable ({e}); opening empty — "
                    "labels will be re-derived")
            return
        fv = int(meta.get("format_version", -1))
        if fv > self.FORMAT_VERSION:
            raise ValueError(
                f"{self.json_path} has format_version {fv}; this build "
                f"reads <= {self.FORMAT_VERSION}")
        if not self._lineage_matches(meta):
            fmt.log(f"{self.json_path}: index lineage changed (stored "
                    f"index_version={meta.get('index_version')} "
                    f"fingerprint={str(meta.get('fingerprint'))[:12]}…, "
                    f"expected index_version={self.index_version} "
                    f"fingerprint={str(self.fingerprint)[:12]}…); opening "
                    "empty — cached labels belong to another index "
                    "generation and will be re-derived")
            return
        if fv >= 2:
            self._load_v2(meta)
        else:
            self._load_v1(meta)

    def _load_v2(self, meta: Dict[str, Any]) -> None:
        segments = []
        degraded = False
        for m in meta.get("segments", []):
            seg = WarmSegment(self.path, int(m["seq"]), m)
            if not (seg.ids_path.exists() and seg.ann_path.exists()):
                fmt.log(f"segment {seg.seq} of {self.path} is missing; "
                        "skipping it — its labels will be re-derived")
                degraded = True
                continue
            segments.append(seg)
        ids = None
        if not degraded and self.npz_path.exists():
            try:
                with np.load(self.npz_path) as z:
                    ids = np.asarray(z["ids"], np.int64)
            except Exception:
                ids = None  # stale/corrupt global index: rebuild by union
        try:
            self._warm.adopt(segments, ids=ids)
        except Exception as e:
            fmt.log(f"warm tier of {self.path} is unreadable ({e}); opening "
                    "empty — labels will be re-derived")
            self._warm.adopt([])
            degraded = True
        self._n = self._warm.n
        self._next_seg_seq = 1 + max((s.seq for s in self._warm.segments),
                                     default=0)
        # a degraded open must rewrite the manifest before journaling again
        self._disk_valid = not degraded

    def _load_v1(self, meta: Dict[str, Any]) -> None:
        """Read a version-1 snapshot (inline annotations + ids npz) into the
        hot tier, pinned; the next compaction migrates it to the tiered v2
        layout.  Torn snapshots degrade to empty instead of raising."""
        anns = meta.get("annotations", [])
        try:
            with np.load(self.npz_path) as z:
                ids = np.asarray(z["ids"], np.int64)
        except Exception as e:
            fmt.log(f"{self.npz_path} is unreadable ({e}); opening empty — "
                    "labels will be re-derived")
            return
        if len(ids) != len(anns):
            fmt.log(f"label store {self.path} is torn: {len(ids)} ids vs "
                    f"{len(anns)} annotations; opening empty — labels will "
                    "be re-derived")
            return
        for i, a in zip(ids, anns):
            self._insert(int(i), _decode_annotation(a), PINNED)
        if len(ids):
            fmt.log(f"{self.json_path}: v1 snapshot ({len(ids)} labels) "
                    "loads pinned-hot; the next compaction migrates it to "
                    f"the tiered v{self.FORMAT_VERSION} layout")

    def _replay_journals(self) -> int:
        replayed = 0
        with self._lock:
            for p in [*self._journal.sealed, self.journal_path]:
                encoded, n = read_journal(p, self._lineage_matches)
                for i, enc in encoded.items():
                    state = CLEAN if self._warm.contains(i) else PINNED
                    self._insert(i, _decode_annotation(enc), state)
                replayed += n
        return replayed

    # -- memory tier plumbing (all under self._lock) -------------------------
    def _insert(self, i: int, a: Any, state: int) -> bool:
        novel = i not in self._hot and not self._warm.contains(i)
        self._hot.put(i, a, state)
        if novel:
            self._n += 1
        return novel

    def _evict(self) -> None:
        self.stats["evictions"] += self._hot.evict()

    def _enforce_budget(self, allow_compact: bool = True) -> None:
        budget = self._hot.budget
        if budget is None:
            return
        self._evict()
        if self._hot.bytes > budget and allow_compact \
                and self._hot.pinned_count():
            # budget pressure has outrun background compaction: the LRU
            # can only shed CLEAN entries, so fold journals -> warm NOW
            # (pins become clean) and sweep again.  This is the mechanism
            # behind "tracked hot bytes never exceed the budget".
            self._save_locked()
            self._evict()

    # -- reads ---------------------------------------------------------------
    def broker_get(self, i: int) -> Any:
        """Uncounted tiered read with promotion (``broker.cache[i]``).
        Tier-hit attribution happens in :meth:`record_hit` at the broker's
        charge points, not here — a future's result pass re-reads fresh ids
        and must not inflate hit counters."""
        i = int(i)
        with self._lock:
            a, ok = self._hot.get(i)
            if ok:
                return a
            a, ok = self._warm.get_one(i)
            if not ok:
                raise KeyError(i)
            hot = self._hot
            hot.put(i, a, CLEAN)
            if hot.budget is not None and hot.bytes > hot.budget:
                self._enforce_budget()
            return a

    def record_hit(self, i: int) -> None:
        """Attribute one broker cache charge to the tier that answered it
        (and promote a warm answer while at it).  Called by the broker
        exactly once per ``cached``-charged id, so per workload
        ``hits_hot + hits_warm + dedup_inflight == broker cached``."""
        i = int(i)
        with self._lock:
            hot = self._hot
            _, ok = hot.get(i)  # LRU-touching probe
            if ok:
                self.stats["hits_hot"] += 1
                return
            a, ok = self._warm.get_one(i)
            if ok:
                self.stats["hits_warm"] += 1
                hot.put(i, a, CLEAN)
                if hot.budget is not None and hot.bytes > hot.budget:
                    self._enforce_budget()

    def get_many(self, ids: Iterable[int],
                 promote: bool = True) -> Dict[int, Any]:
        """Tier-aware bulk read: hot hits, then one batched warm lookup for
        the rest (fence/bloom-gated per segment).  ``promote=False`` reads
        the warm tier without disturbing the hot LRU (benchmarks measure
        the tiers separately with it)."""
        with self._lock:
            out, missing = self._hot.get_many(
                (int(i) for i in ids), touch=promote)
            self.stats["hits_hot"] += len(out)
            if missing:
                found = self._warm.get_many(missing)
                self.stats["hits_warm"] += len(found)
                out.update(found)
                if promote:
                    for i, a in found.items():
                        self._hot.put(i, a, CLEAN)
                    self._enforce_budget()
            return out

    # -- writes --------------------------------------------------------------
    def update(self, labeled: Dict[int, Any]) -> int:
        """Merge freshly labeled records (memory only; returns how many were
        new).  Persistence happens via the attached write-through journal
        or an explicit :meth:`save`."""
        with self._lock:
            new = 0
            for i, a in labeled.items():
                if self._insert(int(i), a, DIRTY):
                    new += 1
            self._evict()
            return new

    def _write_through(self, labeled: Dict[int, Any]) -> None:
        """The broker ``on_fresh`` listener: merge, journal (fsync'd,
        O(batch)), mark journal-durable, then rebalance the budget and
        maybe kick compaction.  Runs under the broker lock — everything
        here is O(batch) except a rare budget-pressure synchronous fold."""
        with self._lock:
            ids = [int(i) for i in labeled]
            # encode FIRST: a non-serializable annotation must abort before
            # any state or file is touched
            encoded = [_encode_annotation(labeled[i]) for i in labeled]
            for i in ids:
                self._insert(i, labeled[i], DIRTY)
            rotated = self._journal.append(ids, encoded)
            self._hot.mark(ids, PINNED)
            self.stats["journal_appends"] += 1
            self.stats["journal_records"] += len(ids)
            if rotated:
                self.stats["journal_rotations"] += 1
            self._enforce_budget()
            if self._auto_compact and not self._compacting \
                    and len(self._journal.sealed) >= self._compact_after:
                self._kick_compaction()

    # -- compaction ----------------------------------------------------------
    def _kick_compaction(self) -> None:
        """Fold sealed journals into a warm segment off the serving
        threads (single-flight; the fold itself holds the store lock)."""
        self._compacting = True
        t = threading.Thread(target=self._background_compact,
                             name="label-store-compact", daemon=True)
        t.start()

    def _background_compact(self) -> None:
        try:
            with self._lock:
                self._compact_sealed_locked()
        except Exception as e:  # never kill the process from a helper thread
            fmt.log(f"background compaction of {self.path} failed: {e}")
        finally:
            self._compacting = False

    def _compact_sealed_locked(self) -> int:
        """Fold every sealed journal segment into one new warm segment.
        Publish order is crash-safe: segment files, global id index, then
        the manifest (the commit point), and only then are the sealed
        journals unlinked — a crash anywhere replays to the same state."""
        sealed = list(self._journal.sealed)
        if not sealed:
            return 0
        merged: Dict[int, Any] = {}
        for p in sealed:
            encoded, _ = read_journal(p, self._lineage_matches)
            merged.update(encoded)
        if merged:
            seg = write_segment(self.path, self._next_seg_seq, merged)
            self._next_seg_seq += 1
            self._warm.add_segment(seg)
            if len(self._warm.segments) > self._max_segments:
                self._merge_segments_locked()
            self._publish_manifest()
            self._hot.mark(merged.keys(), CLEAN)
        self._journal.drop(sealed)
        self.stats["compactions"] += 1
        self._evict()
        return len(merged)

    def _merge_segments_locked(self) -> None:
        """Fold every warm segment into one (bounds segment count, dedups
        ids duplicated across crash-window segments)."""
        old = list(self._warm.segments)
        everything = {i: _encode_annotation(a)
                      for i, a in self._warm.load_all().items()}
        seg = write_segment(self.path, self._next_seg_seq, everything)
        self._next_seg_seq += 1
        self._warm.adopt([seg])
        self._publish_manifest()
        for s in old:
            s.ids_path.unlink(missing_ok=True)
            s.ann_path.unlink(missing_ok=True)

    def _publish_manifest(self) -> None:
        meta = {**self._lineage(),
                "segments": [s.meta() for s in self._warm.segments],
                "n_warm": self._warm.n}
        body = json.dumps(meta)  # encode before touching any file
        with atomic_write(self.npz_path, "wb") as f:
            np.savez(f, ids=self._warm.all_ids())
        with atomic_write(self.json_path, "w") as f:
            f.write(body)
        self._disk_valid = True

    def _cleanup_orphans(self) -> None:
        keep = {self.json_path, self.npz_path, self.journal_path}
        keep.update(self._journal.sealed)
        for s in self._warm.segments:
            keep.add(s.ids_path)
            keep.add(s.ann_path)
        for p in fmt.store_files(self.path):
            if p not in keep:
                p.unlink(missing_ok=True)

    def save(self) -> None:
        """Full compaction: persist every not-yet-warm label as a new warm
        segment, publish the manifest atomically, then drop the journals it
        subsumes (and any orphaned files from older generations).  A
        failing save (non-serializable annotation) aborts before any file
        or state is touched."""
        with self._lock:
            self._save_locked()

    def _save_locked(self) -> None:
        pending = self._hot.non_clean()
        encoded = {i: _encode_annotation(a) for i, a in pending.items()}
        if encoded:
            seg = write_segment(self.path, self._next_seg_seq, encoded)
            self._next_seg_seq += 1
            self._warm.add_segment(seg)
        if len(self._warm.segments) > self._max_segments:
            self._merge_segments_locked()
        else:
            self._publish_manifest()
        self._journal.clear()
        self._hot.mark(pending.keys(), CLEAN)
        self._cleanup_orphans()
        self.stats["compactions"] += 1
        self._evict()

    # -- broker integration --------------------------------------------------
    def attach(self, broker, engine=None) -> int:
        """Install this store as the broker's (tier-aware) label cache and
        journal every flush.  With ``engine`` given, a mid-serving crack
        re-stamps the lineage the store is cached against (and compacts),
        so its labels stay loadable against the re-saved index.  Returns
        the number of labels the broker can now serve without the oracle
        (i.e. ``len(self)`` after adopting anything already in the
        broker's previous cache)."""
        with self._lock:
            if not self._disk_valid:
                # fresh stem, stale files from another index generation, or
                # a v1 snapshot: compact now so the on-disk lineage (and any
                # journal header written later) is unambiguously this
                # store's, in v2 form
                self._save_locked()
        broker.adopt_cache(_TieredCacheView(self))
        broker.on_fresh(self._write_through)
        if engine is not None:
            def _restamp(_added: int) -> None:
                with self._lock:
                    self.index_version = engine.index.version
                    self._save_locked()

            engine.on_crack(_restamp)
        return len(self)

    # -- observability -------------------------------------------------------
    def observe(self) -> Dict[str, Any]:
        """One consistent snapshot of tier sizes, hit/eviction/compaction
        counters, and journal segment count/age — the source for the
        ``label_store_*`` metric families and the ``/stats`` store
        section."""
        with self._lock:
            active = 1 if self.journal_path.exists() else 0
            return {
                "n_labels": self._n,
                "hot": {"entries": len(self._hot),
                        "bytes": self._hot.bytes,
                        "budget": self._hot.budget,
                        "pinned": self._hot.pinned_count()},
                "warm": {"entries": self._warm.n,
                         "bytes": self._warm.nbytes(),
                         "segments": len(self._warm.segments)},
                "journal": {"bytes": self._journal.nbytes(),
                            "segments": len(self._journal.sealed) + active,
                            "sealed": len(self._journal.sealed),
                            "oldest_age_s": self._journal.oldest_age_s()},
                "hits": {"hot": self.stats["hits_hot"],
                         "warm": self.stats["hits_warm"]},
                "counters": dict(self.stats),
            }

    def close(self) -> None:
        """Release warm-tier file handles (mmaps); the store stays usable
        (segments reopen lazily)."""
        with self._lock:
            self._warm.close()
