"""Warm tier: immutable, compacted segment files read lazily off disk.

One segment = two sibling files written atomically by a compaction:

* ``<stem>.labels.seg-N.npz`` — sorted int64 ``ids``, int64 ``offsets``
  (length ``n+1``, byte ranges into the annotation file), a bloom bitset
  over the ids, and the packed **blob sidecar** (``blob``/``blob_offsets``):
  every ndarray/Scene payload's raw bytes, concatenated;
* ``<stem>.labels.seg-N.ann.jsonl`` — one JSON-encoded annotation per
  line, addressed by ``offsets`` so a lookup reads exactly its lines via
  the mmap, never parsing the file.  Array payloads are hoisted out of the
  JSON into the sidecar and replaced by ``{"__kind__": "blob", "k": i}``
  references, so a warm read is a tiny skeleton parse plus an O(size)
  buffer slice — not a float-by-float JSON decode (this is what keeps warm
  lookups within a small factor of a hot dict hit).

Lookups fall through newest segment first (a later compaction shadows an
older one for duplicated ids), and each segment gates the binary search
behind a min/max-id fence and the bloom filter, so a miss usually costs
two array compares and three bit probes.  Hits batch: one ``json.loads``
over all requested lines, then per-id blob resolution.  Segment index
arrays load lazily on first probe; annotation bytes are mmap-backed and
never held.
"""
from __future__ import annotations

import bisect
import json
import mmap
import pathlib
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core import schema as schema_lib
from repro.core.index import _decode_annotation
from repro.core.persist import atomic_write
from repro.serve.store import format as fmt

_KIND_SCENE = 1  # columnar fast-path code: row decodes without JSON


def _hoist_blobs(node: Any, blobs: List[bytes]) -> Any:
    """Rewrite an ENCODED annotation so ndarray/Scene float payloads move
    into the packed sidecar, leaving a cheap-to-parse JSON skeleton."""
    if not isinstance(node, dict):
        return node
    kind = node.get("__kind__")
    if kind == "ndarray":
        a = np.asarray(node["data"], dtype=np.dtype(node["dtype"]))
        blobs.append(a.tobytes())
        return {"__kind__": "blob", "dtype": node["dtype"],
                "shape": node["shape"], "k": len(blobs) - 1}
    if kind == "scene":
        blobs.append(np.asarray(node["boxes"], np.float64).tobytes())
        return {"__kind__": "sceneblob", "n": int(node["n"]),
                "k": len(blobs) - 1}
    if kind == "list":
        return {"__kind__": "list",
                "items": [_hoist_blobs(x, blobs) for x in node["items"]]}
    if kind == "dict":
        return {"__kind__": "dict",
                "items": {key: _hoist_blobs(v, blobs)
                          for key, v in node["items"].items()}}
    return node


def _resolve_blobs(node: Any, blob: np.ndarray, off: np.ndarray) -> Any:
    """Decode a skeleton back to the annotation object, slicing array
    payloads out of the sidecar (the inverse of :func:`_hoist_blobs`).
    Array payloads are zero-copy views into the segment's loaded sidecar —
    repeat reads of one id share a buffer, exactly like the v1 store
    handing out its one cached object per id."""
    if not isinstance(node, dict):
        return node
    kind = node.get("__kind__")
    if kind == "blob":
        k = node["k"]
        return blob[off[k]:off[k + 1]].view(
            np.dtype(node["dtype"])).reshape(node["shape"])
    if kind == "sceneblob":
        k = node["k"]
        return schema_lib.Scene(boxes=blob[off[k]:off[k + 1]].view(
            np.float64).reshape(int(node["n"]), 2))
    if kind == "list":
        return [_resolve_blobs(x, blob, off) for x in node["items"]]
    if kind == "dict":
        return {key: _resolve_blobs(v, blob, off)
                for key, v in node["items"].items()}
    return _decode_annotation(node)  # blob-less kinds (text_record, ...)


class WarmSegment:
    """One immutable on-disk segment; cheap until first probed."""

    def __init__(self, stem: pathlib.Path, seq: int,
                 meta: Optional[Dict[str, Any]] = None):
        self.stem = stem
        self.seq = int(seq)
        meta = meta or {}
        self.n = int(meta.get("n", 0))
        self.min_id = meta.get("min_id")
        self.max_id = meta.get("max_id")
        self.ann_bytes = int(meta.get("ann_bytes", 0))
        self._ids: Optional[np.ndarray] = None
        self._offsets: Optional[np.ndarray] = None
        self._bloom: Optional[np.ndarray] = None
        self._blob: Optional[np.ndarray] = None
        self._blob_offsets: Optional[np.ndarray] = None
        self._kinds: Optional[np.ndarray] = None
        self._blob_k: Optional[np.ndarray] = None
        self._aux: Optional[np.ndarray] = None
        # plain-list shadows of the index arrays, built lazily on the first
        # per-hit probe: list indexing beats numpy scalar extraction at
        # single-id granularity
        self._ids_list: Optional[List[int]] = None
        self._off_list: Optional[List[int]] = None
        self._boff_list: Optional[List[int]] = None
        self._kind_list: Optional[List[int]] = None
        self._bk_list: Optional[List[int]] = None
        self._aux_list: Optional[List[int]] = None
        self._mmap: Optional[mmap.mmap] = None
        self._file = None

    @property
    def ids_path(self) -> pathlib.Path:
        return fmt.segment_ids_path(self.stem, self.seq)

    @property
    def ann_path(self) -> pathlib.Path:
        return fmt.segment_ann_path(self.stem, self.seq)

    def meta(self) -> Dict[str, Any]:
        return {"seq": self.seq, "n": self.n, "min_id": self.min_id,
                "max_id": self.max_id, "ann_bytes": self.ann_bytes}

    def _load_index(self) -> None:
        if self._ids is None:
            with np.load(self.ids_path) as z:
                self._ids = z["ids"]
                self._offsets = z["offsets"]
                self._bloom = z["bloom"]
                self._blob = z["blob"]
                self._blob_offsets = z["blob_offsets"]
                self._kinds = z["kinds"]
                self._blob_k = z["blob_k"]
                self._aux = z["aux"]

    def _ann(self) -> mmap.mmap:
        if self._mmap is None:
            self._file = open(self.ann_path, "rb")
            self._mmap = mmap.mmap(self._file.fileno(), 0,
                                   access=mmap.ACCESS_READ)
        return self._mmap

    def close(self) -> None:
        if self._mmap is not None:
            self._mmap.close()
            self._file.close()
            self._mmap = self._file = None

    def ids(self) -> np.ndarray:
        self._load_index()
        return self._ids

    def index_nbytes(self) -> int:
        if self._ids is None:
            return 0
        return int(self._ids.nbytes + self._offsets.nbytes +
                   self._bloom.nbytes + self._blob.nbytes +
                   self._blob_offsets.nbytes + self._kinds.nbytes +
                   self._blob_k.nbytes + self._aux.nbytes)

    def get_one(self, i: int):
        """``(annotation, True)`` or ``(None, False)`` for one id — the
        broker's per-hit path.  A plain bisect over a cached id list plus
        one line parse: no numpy batch machinery, so a single warm hit
        costs microseconds, not a vectorized-lookup setup."""
        if self.n == 0 or self.min_id is None \
                or not (self.min_id <= i <= self.max_id):
            return None, False
        if self._ids_list is None:
            self._load_index()
            self._ids_list = self._ids.tolist()
            self._off_list = self._offsets.tolist()
            self._boff_list = self._blob_offsets.tolist()
            self._kind_list = self._kinds.tolist()
            self._bk_list = self._blob_k.tolist()
            self._aux_list = self._aux.tolist()
        j = bisect.bisect_left(self._ids_list, i)
        if j >= self.n or self._ids_list[j] != i:
            return None, False
        if self._kind_list[j] == _KIND_SCENE:
            # columnar fast path: the Scene's blob range is precomputed in
            # the index, so the hit is a buffer slice — no JSON touched
            k = self._bk_list[j]
            boff = self._boff_list
            return schema_lib.Scene(boxes=self._blob[boff[k]:boff[k + 1]]
                                    .view(np.float64)
                                    .reshape(self._aux_list[j], 2)), True
        ann = self._ann()
        off = self._off_list
        raw = ann[off[j]:off[j + 1]]
        # decode to str explicitly: json.loads on bytes pays an encoding
        # sniff per call, noticeable at per-hit granularity
        return _resolve_blobs(json.loads(raw.decode()), self._blob,
                              self._blob_offsets), True

    def lookup_many(self, ids: np.ndarray) -> Dict[int, Any]:
        """Decoded annotations for the subset of ``ids`` in this segment.
        Fence and bloom run before the index is even loaded from disk."""
        if not len(ids) or self.n == 0:
            return {}
        if self.min_id is not None:
            fenced = ids[(ids >= self.min_id) & (ids <= self.max_id)]
            if not len(fenced):
                return {}
        else:
            fenced = ids
        self._load_index()
        maybe = fenced[fmt.bloom_maybe_contains(self._bloom, fenced)]
        if not len(maybe):
            return {}
        pos = np.searchsorted(self._ids, maybe)
        valid = pos < len(self._ids)
        pos, maybe = pos[valid], maybe[valid]
        hit = self._ids[pos] == maybe
        pos, found = pos[hit], maybe[hit]
        if not len(found):
            return {}
        blob, boff = self._blob, self._blob_offsets.tolist()
        out: Dict[int, Any] = {}
        scene, f64 = schema_lib.Scene, np.float64
        # columnar fast path first: Scene rows decode straight off the
        # precomputed kind/blob_k/aux columns, no JSON touched
        kinds = self._kinds[pos].tolist()
        bks = self._blob_k[pos].tolist()
        auxs = self._aux[pos].tolist()
        generic: List[int] = []   # segment rows still needing a JSON parse
        generic_ids: List[int] = []
        for i, j, kd, k, n in zip(found.tolist(), pos.tolist(), kinds,
                                  bks, auxs):
            if kd == _KIND_SCENE:
                out[i] = scene(boxes=blob[boff[k]:boff[k + 1]].view(
                    f64).reshape(n, 2))
            else:
                generic.append(j)
                generic_ids.append(i)
        if generic:
            ann = self._ann()
            off = self._offsets.tolist()
            raws = [ann[off[j]:off[j + 1]] for j in generic]
            # one C-level parse for the whole remainder: the trailing
            # newline each line carries is legal JSON whitespace
            skeletons = json.loads(b"[" + b",".join(raws) + b"]")
            for i, skel in zip(generic_ids, skeletons):
                kind = skel.get("__kind__") if type(skel) is dict else None
                if kind == "blob":
                    k = skel["k"]
                    out[i] = blob[boff[k]:boff[k + 1]].view(
                        np.dtype(skel["dtype"])).reshape(skel["shape"])
                else:
                    out[i] = _resolve_blobs(skel, blob,
                                            self._blob_offsets)
        return out


def write_segment(stem: pathlib.Path, seq: int,
                  encoded: Dict[int, Any]) -> WarmSegment:
    """Persist ``{id: ENCODED annotation}`` as segment ``seq`` (both files
    via :func:`atomic_write`) and return its handle.  Callers encode first
    so a non-serializable annotation aborts before any file is touched."""
    ids = np.asarray(sorted(encoded), np.int64)
    blobs: List[bytes] = []
    skeletons = [_hoist_blobs(encoded[int(i)], blobs) for i in ids]
    lines = [json.dumps(s).encode() + b"\n" for s in skeletons]
    offsets = np.zeros(len(ids) + 1, np.int64)
    np.cumsum([len(b) for b in lines], out=offsets[1:])
    blob_offsets = np.zeros(len(blobs) + 1, np.int64)
    np.cumsum([len(b) for b in blobs], out=blob_offsets[1:])
    blob = np.frombuffer(b"".join(blobs), np.uint8)
    # columnar fast path: Scene rows (the dominant video annotation) carry
    # their blob index + box count here, so a per-hit read never parses
    # JSON at all — kind 0 rows take the generic skeleton path
    kinds = np.zeros(len(ids), np.uint8)
    blob_k = np.zeros(len(ids), np.int64)
    aux = np.zeros(len(ids), np.int64)
    for row, s in enumerate(skeletons):
        if type(s) is dict and s.get("__kind__") == "sceneblob":
            kinds[row] = _KIND_SCENE
            blob_k[row] = s["k"]
            aux[row] = s["n"]
    with atomic_write(fmt.segment_ann_path(stem, seq), "wb") as f:
        for b in lines:
            f.write(b)
    with atomic_write(fmt.segment_ids_path(stem, seq), "wb") as f:
        np.savez(f, ids=ids, offsets=offsets, bloom=fmt.bloom_build(ids),
                 blob=blob, blob_offsets=blob_offsets,
                 kinds=kinds, blob_k=blob_k, aux=aux)
    meta = {"seq": int(seq), "n": int(len(ids)),
            "min_id": int(ids[0]) if len(ids) else None,
            "max_id": int(ids[-1]) if len(ids) else None,
            "ann_bytes": int(offsets[-1])}
    return WarmSegment(stem, seq, meta)


class WarmTier:
    """All live segments plus the global sorted id union (exact membership
    and an O(1) ``len`` without touching segment files)."""

    def __init__(self, stem: pathlib.Path):
        self.stem = stem
        self.segments: List[WarmSegment] = []
        self._ids = np.empty(0, np.int64)
        self._ids_list: Optional[List[int]] = None  # lazy, for per-id bisect

    @property
    def n(self) -> int:
        return len(self._ids)

    def all_ids(self) -> np.ndarray:
        return self._ids

    def set_ids(self, ids: np.ndarray) -> None:
        self._ids = np.asarray(ids, np.int64)
        self._ids_list = None

    def add_segment(self, seg: WarmSegment) -> None:
        self.segments.append(seg)
        self.segments.sort(key=lambda s: s.seq)
        self._ids = np.union1d(self._ids, seg.ids())
        self._ids_list = None

    def adopt(self, segments: List[WarmSegment],
              ids: Optional[np.ndarray] = None) -> None:
        """Swap in a new segment list (closing the old one).  ``ids`` is the
        trusted precomputed global union (the ``.labels.npz`` fast path);
        when absent it is rebuilt by unioning every segment's ids."""
        for seg in self.segments:
            seg.close()
        self.segments = sorted(segments, key=lambda s: s.seq)
        if ids is None:
            ids = np.empty(0, np.int64)
            for seg in self.segments:
                ids = np.union1d(ids, seg.ids())
        self._ids = np.asarray(ids, np.int64)
        self._ids_list = None

    def contains(self, i: int) -> bool:
        # per-id membership is serving-path hot: C bisect over a plain list
        # beats a numpy searchsorted call at single-id granularity
        lst = self._ids_list
        if lst is None:
            lst = self._ids_list = self._ids.tolist()
        j = bisect.bisect_left(lst, i)
        return j < len(lst) and lst[j] == i

    def get_one(self, i: int):
        """``(annotation, True)`` or ``(None, False)``, newest segment
        first — the per-hit serving path."""
        for seg in reversed(self.segments):
            a, ok = seg.get_one(i)
            if ok:
                return a, True
        return None, False

    def get_many(self, ids) -> Dict[int, Any]:
        """Decoded annotations for every requested id present in any
        segment, newest segment winning duplicates."""
        want = np.unique(np.asarray(list(ids), np.int64))
        out: Dict[int, Any] = {}
        for seg in reversed(self.segments):
            if not len(want):
                break
            found = seg.lookup_many(want)
            if found:
                out.update(found)
                want = want[~np.isin(want, np.asarray(list(found), np.int64))]
        return out

    def load_all(self) -> Dict[int, Any]:
        """The whole tier as one dict (oldest first, so newer wins)."""
        out: Dict[int, Any] = {}
        for seg in self.segments:
            out.update(seg.lookup_many(seg.ids()))
        return out

    def nbytes(self) -> int:
        return sum(s.ann_bytes + s.index_nbytes() for s in self.segments)

    def close(self) -> None:
        for seg in self.segments:
            seg.close()
