"""Tiered persistent label store (hot RAM -> warm segments -> oracle).

The public surface is :class:`LabelStore` and :func:`index_fingerprint`,
unchanged in spirit from the single-file v1 store this package replaced:
open against an index lineage, ``attach`` to a broker, and every oracle
label paid for is journaled and reusable across restarts.  What the
package adds is *bigger-than-memory* operation: a byte-budgeted hot tier,
mmap-backed warm segment files, rotating journals with background
compaction, and tier-attributed observability.  See
``docs/api/label-store.md`` for the lifecycle, on-disk format, and
invariants.
"""
from repro.serve.store.store import LabelStore, index_fingerprint

__all__ = ["LabelStore", "index_fingerprint"]
