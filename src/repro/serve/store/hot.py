"""Hot tier: the in-memory map, LRU-bounded by tracked approximate bytes.

Entries carry a durability state that doubles as eviction eligibility:

* ``DIRTY`` — in memory only (a bare :meth:`LabelStore.update`).  Evicting
  it would lose a paid label, so it is pinned until a save/compaction.
* ``PINNED`` — durable in a journal file but not yet folded into a warm
  segment.  Still unreadable from the warm tier, so still pinned; budget
  pressure resolves this by *compacting*, not by evicting.
* ``CLEAN`` — warm-resident: the same annotation is readable from a warm
  segment, so the hot copy is pure cache and may be dropped.

The invariant the tests lean on: **only CLEAN entries are ever evicted**,
so no journaled (or merely updated) label can be lost to budget pressure —
it either stays hot or becomes readable from warm first.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.serve.store.format import approx_nbytes

DIRTY = 0    # memory only: pinned until saved
PINNED = 1   # journal-durable: pinned until compacted into a warm segment
CLEAN = 2    # warm-resident: evictable


class HotTier:
    """Insertion-ordered ``{id: [annotation, nbytes, state]}`` with
    move-to-end on touch; not thread-safe (the owning store locks)."""

    def __init__(self, budget: Optional[int] = None):
        self.budget = budget
        self._entries: "OrderedDict[int, List[Any]]" = OrderedDict()
        self.bytes = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, i: int) -> bool:
        return i in self._entries

    def get(self, i: int, touch: bool = True):
        """``(annotation, True)`` on a hit (LRU-touched), ``(None, False)``
        on a miss — annotations may legitimately be None."""
        e = self._entries.get(i)
        if e is None:
            return None, False
        if touch:
            self._entries.move_to_end(i)
        return e[0], True

    def get_many(self, ids, touch: bool = True):
        """Batch probe: ``({id: annotation}, [missing ids])`` — one tight
        loop instead of a method call per id (the tiered ``get_many`` fast
        path)."""
        entries = self._entries
        move = entries.move_to_end
        hits: Dict[int, Any] = {}
        missing: List[int] = []
        for i in ids:
            e = entries.get(i)
            if e is None:
                missing.append(i)
            else:
                hits[i] = e[0]
                if touch:
                    move(i)
        return hits, missing

    def put(self, i: int, a: Any, state: int) -> None:
        """Insert or overwrite.  An overwrite keeps the *highest* durability
        seen for the id: labels are deterministic per record (the oracle is
        a pure function of the id), so a re-put never invalidates the copy
        already sitting in a journal or warm segment."""
        old = self._entries.get(i)
        nbytes = approx_nbytes(a)
        if old is not None:
            self.bytes -= old[1]
            state = max(old[2], state)
            self._entries.move_to_end(i)  # fresh assignment appends at end
        self._entries[i] = [a, nbytes, state]
        self.bytes += nbytes

    def mark(self, ids, state: int) -> None:
        """Promote durability (DIRTY -> PINNED -> CLEAN); never demotes."""
        for i in ids:
            e = self._entries.get(int(i))
            if e is not None and e[2] < state:
                e[2] = state

    def state(self, i: int) -> Optional[int]:
        e = self._entries.get(i)
        return None if e is None else e[2]

    def pinned_count(self) -> int:
        return sum(1 for e in self._entries.values() if e[2] != CLEAN)

    def items(self) -> Iterator[Tuple[int, Any]]:
        for i, e in self._entries.items():
            yield i, e[0]

    def non_clean(self) -> Dict[int, Any]:
        """Everything a full compaction still has to persist."""
        return {i: e[0] for i, e in self._entries.items() if e[2] != CLEAN}

    def evict(self, limit: Optional[int] = None) -> int:
        """Drop CLEAN entries in LRU order until ``bytes <= limit`` (the
        tier budget when None).  Returns how many entries were dropped;
        stops early when only pinned entries remain."""
        limit = self.budget if limit is None else limit
        if limit is None or self.bytes <= limit:
            return 0
        evicted = 0
        for i in list(self._entries):
            if self.bytes <= limit:
                break
            e = self._entries[i]
            if e[2] != CLEAN:
                continue
            del self._entries[i]
            self.bytes -= e[1]
            evicted += 1
        return evicted
