"""Workload registry: many (index, engine, store) stacks behind one server.

TASTI's economics amortize one cheap index across many queries; a production
deployment amortizes further by hosting many *workloads* — video, text,
speech — behind one endpoint.  The registry is that mounting table:

* a :class:`WorkloadSpec` declares one workload (dataset + index to load or
  build + label store + oracle knobs) without constructing anything;
* :class:`WorkloadRegistry` maps workload names to entries, loads each
  lazily on first lookup (a server binds its port immediately and pays each
  workload's index build/load only when the first spec routes to it), and
  owns the shutdown sweep (close every loaded engine's replica pool, save
  every store);
* :meth:`WorkloadRegistry.from_manifest` mounts a whole fleet from one JSON
  file (the ``--manifest`` flag of ``repro.launch.serve_queries``)::

      {"default": "video",
       "workloads": {
         "video": {"dataset": "night-street", "n_frames": 3000,
                   "index": "/data/video-idx", "store": "/data/video-idx",
                   "oracle_replicas": 2},
         "text": {"dataset": "wikisql", "n_records": 2000, "quick": true}}}

Every entry is a full serving stack of its own — ``TastiIndex``,
``QueryEngine`` (with per-workload ``oracle_replicas``/``oracle_batch``/
``crack``), optional ``LabelStore`` attached with write-through — so
workloads never share caches, accounts, or label stores; they share only
the server's worker pool and HTTP front end.
"""
from __future__ import annotations

import dataclasses
import json
import sys
import threading
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

from repro.core.engine import QueryEngine
from repro.core.index import TastiIndex
from repro.core.schema import WORKLOAD_NAMES, make_workload
from repro.obs import Observability
from repro.serve.store import LabelStore
from repro.serve.store.format import parse_bytes

#: Name the single-engine (legacy) server wraps its one workload under.
DEFAULT_WORKLOAD = "default"


@dataclass
class WorkloadSpec:
    """Declarative description of one mountable workload (JSON-friendly).

    ``index`` is the stem of a saved :class:`~repro.core.index.TastiIndex`
    to load; without it an index is built in-process on first use (with the
    tiny ``quick`` budgets when set).  ``store`` defaults to the ``index``
    stem, mirroring the serving CLI; leave both unset to serve without
    persistence.
    """

    name: str
    dataset: str                     # make_workload name (night-street, ...)
    n_records: int = 8000            # workload size (n_frames for video)
    index: Optional[str] = None      # saved index stem to load
    store: Optional[str] = None      # label-store stem (default: index stem)
    #: Hot-tier byte budget for this workload's label store (int bytes or a
    #: "64m"-style string); None = unbounded.  Labels past the budget spill
    #: to warm segment files instead of growing the server's heap.
    store_budget: Optional[Any] = None
    quick: bool = False              # tiny build budgets (smoke tests / CI)
    variant: str = "T"
    n_train: int = 400
    n_reps: int = 800
    k: int = 8
    triplet_steps: int = 400
    oracle_batch: int = 64
    oracle_replicas: int = 1
    oracle_backend: str = "thread"   # replica kind: "thread" | "process"
    crack: bool = False

    def __post_init__(self):
        if self.dataset not in WORKLOAD_NAMES:
            raise ValueError(f"unknown dataset {self.dataset!r} for workload "
                             f"{self.name!r}; known: {list(WORKLOAD_NAMES)}")
        try:
            # normalize "64m"-style budgets to int bytes at declaration time
            # so a bad manifest fails at mount, not at first lazy load
            self.store_budget = parse_bytes(self.store_budget)
        except ValueError as e:
            raise ValueError(f"workload {self.name!r}: bad store_budget: "
                             f"{e}") from None

    _ALIASES = {"n_frames": "n_records"}

    @classmethod
    def from_dict(cls, name: str, d: Dict[str, Any]) -> "WorkloadSpec":
        if "n_frames" in d and "n_records" in d:
            raise ValueError(f"workload {name!r}: pass n_frames or "
                             "n_records, not both")
        fields = {f.name for f in dataclasses.fields(cls)} - {"name"}
        kw = {}
        for key, value in d.items():
            key = cls._ALIASES.get(key, key)
            if key not in fields:
                raise ValueError(
                    f"unknown key {key!r} in workload {name!r}; allowed: "
                    f"{sorted(fields | set(cls._ALIASES))}")
            kw[key] = value
        if "dataset" not in kw:
            raise ValueError(f"workload {name!r} needs a 'dataset'")
        return cls(name=name, **kw)


class WorkloadEntry:
    """One mounted workload: its spec and, once loaded, its serving stack."""

    def __init__(self, name: str, spec: Optional[WorkloadSpec] = None,
                 engine: Optional[QueryEngine] = None,
                 store: Optional[LabelStore] = None,
                 obs: Optional[Observability] = None):
        self.name = name
        self.spec = spec
        self.engine = engine
        self.store = store
        self.seeded = 0                      # labels seeded from the store
        self._lock = threading.Lock()        # serializes this entry's load
        self._load_error: Optional[Exception] = None
        self._obs: Optional[Observability] = None
        if obs is not None:
            self.adopt_obs(obs)

    def adopt_obs(self, obs: Observability) -> None:
        """Point this entry's stack at ``obs`` (metrics + tracing), labeling
        everything with ``workload=<name>``.  Safe before or after load: an
        unloaded entry remembers the scope for :meth:`_load`, a loaded one
        (pre-built engines mounted via ``register``) is re-pointed live."""
        self._obs = obs
        if self.engine is not None:
            self.engine.set_obs(obs.scoped(workload=self.name))

    @property
    def loaded(self) -> bool:
        return self.engine is not None

    @property
    def load_error(self) -> Optional[Exception]:
        """The memoized failure of a broken lazy mount (None when healthy);
        surfaced by ``/healthz`` and ``/workloads`` so a dead mount is
        distinguishable from a not-yet-loaded one without sending a query."""
        return self._load_error

    def describe(self) -> Dict[str, Any]:
        spec = self.spec
        out: Dict[str, Any] = {
            "name": self.name,
            "loaded": self.loaded,
            "dataset": (spec.dataset if spec is not None else
                        getattr(getattr(self.engine, "workload", None),
                                "name", None)),
        }
        if self.loaded:
            index = self.engine.index
            out.update(records=index.n_records, reps=index.n_reps,
                       index_version=index.version,
                       oracle_replicas=self.engine.oracle_replicas,
                       oracle_backend=self.engine.oracle_backend,
                       store_labels=(None if self.store is None
                                     else len(self.store)))
        else:
            out.update(records=spec.n_records,
                       oracle_replicas=spec.oracle_replicas,
                       oracle_backend=spec.oracle_backend,
                       store_labels=None)
        if self._load_error is not None:
            out["error"] = str(self._load_error)
        return out

    def ensure_loaded(self) -> "WorkloadEntry":
        with self._lock:
            if self.engine is None:
                # a failed load is memoized: manifest mistakes (wrong
                # n_records, missing index files) are deterministic, and
                # re-running a multi-minute build per routed request would
                # tie up the worker pool just to fail the same way
                if self._load_error is not None:
                    raise RuntimeError(
                        f"workload {self.name!r} failed to load previously "
                        f"(fix the manifest and restart): "
                        f"{self._load_error}") from self._load_error
                try:
                    self._load()
                except Exception as e:
                    self._load_error = e
                    raise
        return self

    def _load(self) -> None:
        spec = self.spec
        wl = make_workload(spec.dataset, n_records=spec.n_records)
        if spec.index:
            index = TastiIndex.load(spec.index)
            if index.n_records != len(wl.features):
                raise ValueError(
                    f"workload {self.name!r}: index {spec.index} covers "
                    f"{index.n_records} records but dataset {spec.dataset} "
                    f"has {len(wl.features)}; fix n_records in the manifest")
        else:
            # build in-process: heavy imports stay off the serve fast path
            from repro.core.pipeline import build_tasti, cli_tasti_config
            cfg = cli_tasti_config(spec.quick, n_train=spec.n_train,
                                   n_reps=spec.n_reps, k=spec.k,
                                   triplet_steps=spec.triplet_steps)
            index = build_tasti(wl, cfg, variant=spec.variant).index
        scope = (self._obs.scoped(workload=self.name)
                 if self._obs is not None else None)
        engine = QueryEngine(index, wl, crack=spec.crack,
                             max_oracle_batch=spec.oracle_batch,
                             oracle_replicas=spec.oracle_replicas,
                             oracle_backend=spec.oracle_backend,
                             obs=scope)
        store = None
        store_stem = spec.store or spec.index
        if store_stem:
            store = LabelStore.for_index(store_stem, index,
                                         hot_budget=spec.store_budget)
            self.seeded = store.attach(engine.broker, engine)
            print(f"[serve] workload {self.name}: label store "
                  f"{store.json_path}: {len(store)} labels, "
                  f"{self.seeded} seeded into the broker", file=sys.stderr)
        # store first: `engine` is the lock-free loaded flag that describe()
        # and /stats read, so everything else must be published before it
        self.store = store
        self.engine = engine

    def close(self) -> None:
        """Stop the engine's replica pool and persist the store (idempotent;
        a never-loaded entry has nothing to do).  A load still in flight is
        skipped rather than awaited: it has published nothing durable yet
        (write-through only starts once queries run), its threads are
        daemons, and blocking a shutdown on a multi-minute index build
        would defeat the server's otherwise-bounded drain."""
        if not self._lock.acquire(timeout=1.0):
            return
        try:
            if self.engine is not None:
                self.engine.close()
            if self.store is not None:
                self.store.save()
        finally:
            self._lock.release()


class WorkloadRegistry:
    """Name -> :class:`WorkloadEntry`, with lazy loading and a default.

        registry = WorkloadRegistry()
        registry.register("video", engine, store=store)   # pre-built
        registry.declare(WorkloadSpec("text", "wikisql", n_records=2000))
        entry = registry.get("text")        # loads on first lookup
        registry.close()                    # stop pools, save stores

    The default workload (explicit, else the first mounted) is what specs
    without a ``workload`` field route to — a single-workload server keeps
    today's API unchanged.
    """

    def __init__(self, default: Optional[str] = None):
        self._entries: Dict[str, WorkloadEntry] = {}
        self._default = default
        self._lock = threading.Lock()
        self._obs: Optional[Observability] = None

    def set_obs(self, obs: Observability) -> None:
        """Adopt every mounted entry (and all future mounts) into ``obs``."""
        with self._lock:
            self._obs = obs
            entries = list(self._entries.values())
        for entry in entries:
            entry.adopt_obs(obs)

    # -- mounting ------------------------------------------------------------
    def _add(self, entry: WorkloadEntry) -> WorkloadEntry:
        with self._lock:
            if entry.name in self._entries:
                raise ValueError(f"workload {entry.name!r} already mounted")
            self._entries[entry.name] = entry
            obs = self._obs
        if obs is not None:
            entry.adopt_obs(obs)
        return entry

    def register(self, name: str, engine: QueryEngine,
                 store: Optional[LabelStore] = None) -> WorkloadEntry:
        """Mount an already-constructed engine (tests, in-process callers).
        A ``store`` passed here is assumed already attached to the engine's
        broker; the registry only tracks it for stats and shutdown save."""
        return self._add(WorkloadEntry(name, engine=engine, store=store))

    def declare(self, spec: WorkloadSpec) -> WorkloadEntry:
        """Mount a workload lazily: nothing is built until first lookup."""
        return self._add(WorkloadEntry(spec.name, spec=spec))

    @classmethod
    def from_manifest(cls, path: str) -> "WorkloadRegistry":
        """Mount every workload declared in a JSON manifest file."""
        with open(path) as f:
            manifest = json.load(f)
        workloads = manifest.get("workloads")
        if not isinstance(workloads, dict) or not workloads:
            raise ValueError(f"manifest {path} needs a non-empty "
                             "'workloads' object")
        default = manifest.get("default")
        if default is not None and default not in workloads:
            raise ValueError(f"manifest default {default!r} is not one of "
                             f"its workloads {sorted(workloads)}")
        registry = cls(default=default)
        for name, entry in workloads.items():
            registry.declare(WorkloadSpec.from_dict(name, entry))
        return registry

    # -- lookup --------------------------------------------------------------
    @property
    def default(self) -> Optional[str]:
        with self._lock:
            if self._default is not None:
                return self._default
            return next(iter(self._entries), None)

    def set_default(self, name: str) -> None:
        with self._lock:
            if name not in self._entries:
                raise KeyError(f"unknown workload {name!r}; mounted: "
                               f"{sorted(self._entries)}")
            self._default = name

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._entries

    def names(self) -> List[str]:
        with self._lock:
            return list(self._entries)

    def entries(self) -> List[WorkloadEntry]:
        """Snapshot of the mounted entries (never triggers a load)."""
        with self._lock:
            return list(self._entries.values())

    def get(self, name: Optional[str] = None) -> WorkloadEntry:
        """The loaded entry for ``name`` (default when None); builds/loads
        its index, engine, and store on first use.  Loading holds only the
        entry's own lock, so a slow build never blocks other workloads."""
        key = name if name is not None else self.default
        with self._lock:
            entry = self._entries.get(key)
        if entry is None:
            raise KeyError(f"unknown workload {key!r}; mounted: "
                           f"{sorted(self.names())}")
        return entry.ensure_loaded()

    def describe(self) -> List[Dict[str, Any]]:
        """Per-workload summaries for the ``/workloads`` endpoint."""
        default = self.default
        rows = []
        for entry in self.entries():
            row = entry.describe()
            row["default"] = entry.name == default
            rows.append(row)
        return rows

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Close every loaded workload: stop engine-owned replica pools and
        save the stores.  Idempotent; entries stay mounted and usable."""
        for entry in self.entries():
            entry.close()
