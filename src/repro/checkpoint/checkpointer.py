"""Checkpointing: async npz-shard save, manifest, reshard-on-restore.

Design for 1000+ nodes (single-host implementation, multi-host layout):

* Arrays are saved **sharding-agnostic** (full logical arrays gathered per
  leaf; in a multi-host deployment each host writes only its owned shards and
  the manifest records the global shape — the on-disk format already carries
  per-leaf global shapes, so restore-time resharding works either way).
* ``save_async`` snapshots to host memory synchronously (cheap) and writes to
  disk on a background thread — the train loop never blocks on I/O.
* Atomicity: write to ``step_XXXX.tmp`` then rename; the manifest is the
  commit point.  Interrupted writes are invisible to ``latest_step``.
* **Elastic restore**: ``restore`` takes the *current* shardings and puts each
  leaf onto the (possibly different-sized) mesh — checkpoints written on a
  512-chip run restore onto 256 chips or a single host unchanged.
"""
from __future__ import annotations

import json
import pathlib
import re
import shutil
import threading
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import PyTree


def _flatten_with_names(tree: PyTree):
    # jax.tree.flatten_with_path only exists on newer jax; the tree_util
    # spelling works everywhere
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    return names, [v for _, v in flat], treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: PyTree, extra: Optional[Dict] = None) -> None:
        names, leaves, _ = _flatten_with_names(tree)
        host = [np.asarray(x) for x in leaves]  # device -> host snapshot
        self._write(step, names, host, extra or {})

    def save_async(self, step: int, tree: PyTree,
                   extra: Optional[Dict] = None) -> None:
        self.wait()
        names, leaves, _ = _flatten_with_names(tree)
        host = [np.asarray(x) for x in leaves]  # snapshot before returning

        def work():
            self._write(step, names, host, extra or {})

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, names, host, extra: Dict) -> None:
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        # numpy's npz can't round-trip ml_dtypes (bf16 etc.): store those as
        # float32 on disk; the manifest records the logical dtype and restore
        # casts back to the target leaf dtype.
        def native(a: np.ndarray) -> np.ndarray:
            if a.dtype == object:
                raise TypeError(
                    "checkpoint leaves must be numeric arrays; carry run "
                    "metadata via the `extra` dict instead")
            try:
                np.dtype(a.dtype.name)
                if a.dtype.kind in "fiub":
                    return a
            except TypeError:
                pass
            return a.astype(np.float32)

        arrays = {f"a{i}": native(a) for i, a in enumerate(host)}
        np.savez(tmp / "arrays.npz", **arrays)
        manifest = {
            "step": step,
            "names": names,
            "shapes": [list(a.shape) for a in host],
            "dtypes": [str(a.dtype) for a in host],
            "extra": extra,
            "time": time.time(),
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: PyTree,
                shardings: Optional[PyTree] = None):
        """Restore into the structure of ``target`` (tree of arrays or
        ShapeDtypeStructs), placing leaves with ``shardings`` if given —
        this is the elastic-resharding path."""
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / "arrays.npz")
        names, leaves, treedef = _flatten_with_names(target)
        assert names == manifest["names"], "checkpoint/target tree mismatch"
        shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                        else [None] * len(leaves))
        out = []
        for i, (tgt, sh) in enumerate(zip(leaves, shard_leaves)):
            arr = data[f"a{i}"]
            want = jnp.dtype(tgt.dtype)
            a = arr.astype(want) if arr.dtype != want else arr
            if sh is not None:
                out.append(jax.device_put(a, sh))
            else:
                out.append(jnp.asarray(a))
        return jax.tree.unflatten(treedef, out), manifest["extra"]
