"""jit-able train / serve steps.

``make_train_step``: loss -> grads -> (optional int8-compressed DP all-reduce)
-> AdamW.  ``make_serve_step``: one-token decode over sharded caches.  Both are
pure functions of (params, state, batch) so they AOT-lower with
ShapeDtypeStructs for the multi-pod dry-run and run identically on real data.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.models.common import PyTree
from repro.optim.adamw import OptimizerConfig, adamw_update


def make_train_step(cfg: ModelConfig, opt: OptimizerConfig,
                    attn_impl: str = "xla",
                    microbatches: int = 1) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``microbatches > 1`` accumulates gradients over sequential micro-batches
    (splitting the leading batch dim) before the optimizer update — the
    standard activation-memory lever.
    """

    def loss_fn(params, batch):
        return lm.lm_loss(params, batch, cfg, attn_impl=attn_impl)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def accumulated(params, batch):
        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            acc, loss_acc = carry
            (loss, _), grads = grad_fn(params, mb)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_acc + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, loss_sum), _ = jax.lax.scan(body, (zeros, 0.0), micro)
        grads = jax.tree.map(lambda g: (g / microbatches), gsum)
        loss = loss_sum / microbatches
        return loss, {"ce_loss": loss}, grads

    def train_step(params: PyTree, opt_state: PyTree,
                   batch: Dict[str, jax.Array]):
        if microbatches > 1:
            loss, metrics, grads = accumulated(params, batch)
        else:
            loss, metrics, grads = single(params, batch)
        params, opt_state, opt_metrics = adamw_update(params, grads, opt_state, opt)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, attn_impl: str = "xla") -> Callable:
    """Forward-only logits over a full prompt (the inference-prefill cell)."""

    def prefill_step(params: PyTree, batch: Dict[str, jax.Array]):
        return lm.lm_logits(params, batch, cfg, attn_impl=attn_impl)

    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """One-token decode: (params, caches, token, pos) -> (logits, caches)."""

    def serve_step(params: PyTree, caches: PyTree, token: jax.Array,
                   pos: jax.Array):
        return lm.decode_step(params, caches, token, pos, cfg)

    return serve_step
