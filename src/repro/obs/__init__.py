"""End-to-end observability: request tracing, flight recorder, metrics.

:class:`Observability` bundles one :class:`~repro.obs.metrics.MetricsRegistry`,
one :class:`~repro.obs.trace.Tracer`, and one
:class:`~repro.obs.trace.FlightRecorder` for a process (usually owned by
``QueryServer``).  Components receive an :class:`ObsScope` — the same
bundle with a preset label set (``workload="video"``) folded into every
instrument they create — via ``obs.scoped(workload=...)``.

Disabled observability is the same object graph built on no-op parts
(``NULL_REGISTRY``, a tracer handing out ``NULL_TRACE``), so call sites
never branch on an enabled flag.  ``NULL_SCOPE`` is the default for every
component's ``obs`` parameter.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, Optional

from repro.obs import trace as trace_mod
from repro.obs.metrics import (
    LATENCY_BUCKETS, NULL_REGISTRY, MetricsRegistry, Sample, SIZE_BUCKETS,
    parse_prometheus_text, series_key,
)
from repro.obs.trace import (
    NULL_SPAN, NULL_TRACE, FlightRecorder, Span, Trace, Tracer, activate,
    active_trace, add_timed_span, chrome_trace, chrome_traces, new_trace_id,
    span, start_span,
)

__all__ = [
    "Observability", "ObsScope", "NULL_OBS", "NULL_SCOPE",
    "MetricsRegistry", "NULL_REGISTRY", "Sample", "parse_prometheus_text",
    "series_key", "LATENCY_BUCKETS", "SIZE_BUCKETS",
    "Tracer", "Trace", "Span", "FlightRecorder", "NULL_TRACE", "NULL_SPAN",
    "activate", "active_trace", "span", "start_span", "add_timed_span",
    "chrome_trace", "chrome_traces", "new_trace_id",
]


class Observability:
    """Process-wide observability bundle (metrics + tracer + recorder)."""

    def __init__(self, enabled: bool = True, trace_buffer: int = 256):
        self.enabled = bool(enabled)
        if self.enabled:
            self.metrics: Any = MetricsRegistry()
            self.recorder: Optional[FlightRecorder] = \
                FlightRecorder(trace_buffer)
            self.tracer = Tracer(self.recorder, enabled=True)
        else:
            self.metrics = NULL_REGISTRY
            self.recorder = None
            self.tracer = Tracer(None, enabled=False)

    def scoped(self, **labels: Any) -> "ObsScope":
        return ObsScope(self, labels)

    # conveniences so an Observability can be used where a scope is
    # expected (empty label set)
    def counter(self, name: str, help: str = "", **labels: Any):
        return self.metrics.counter(name, help, **labels)

    def gauge(self, name: str, help: str = "", **labels: Any):
        return self.metrics.gauge(name, help, **labels)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Iterable[float]] = None, **labels: Any):
        return self.metrics.histogram(name, help, buckets=buckets, **labels)


class ObsScope:
    """An :class:`Observability` view with preset labels.  This is the
    type every instrumented component takes as its ``obs`` parameter."""

    __slots__ = ("obs", "labels")

    def __init__(self, obs: Observability, labels: Dict[str, Any]):
        self.obs = obs
        self.labels = {str(k): str(v) for k, v in labels.items()}

    @property
    def enabled(self) -> bool:
        return self.obs.enabled

    @property
    def tracer(self) -> Tracer:
        return self.obs.tracer

    @property
    def recorder(self) -> Optional[FlightRecorder]:
        return self.obs.recorder

    @property
    def metrics(self):
        return self.obs.metrics

    def scoped(self, **labels: Any) -> "ObsScope":
        return ObsScope(self.obs, {**self.labels, **labels})

    def counter(self, name: str, help: str = "", **labels: Any):
        return self.obs.metrics.counter(name, help, **self.labels, **labels)

    def gauge(self, name: str, help: str = "", **labels: Any):
        return self.obs.metrics.gauge(name, help, **self.labels, **labels)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Iterable[float]] = None, **labels: Any):
        return self.obs.metrics.histogram(
            name, help, buckets=buckets, **self.labels, **labels)


NULL_OBS = Observability(enabled=False)
NULL_SCOPE = NULL_OBS.scoped()

# re-export the module for ``from repro import obs; obs.trace`` style use
trace = trace_mod
