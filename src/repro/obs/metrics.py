"""Low-overhead metrics registry with Prometheus text exposition.

Three instrument kinds — :class:`Counter`, :class:`Gauge`, and fixed-bucket
:class:`Histogram` — grouped into families keyed by metric name, with
per-child label sets (``oracle_fresh_total{workload="video"}``).  Hot-path
cost is one short ``with lock`` per observation; instrument handles are
meant to be resolved once and cached by the caller, not looked up per
event.

Most of the serving stack already keeps its own counters under its own
locks (broker/pool/scheduler stats dicts).  Rather than double-count on
the hot path, the registry supports *collectors*: callables run at scrape
time that yield derived samples straight from those stats snapshots.  The
hot path pays nothing; ``/metrics`` pays one snapshot pass.

A disabled registry is the :data:`NULL_REGISTRY` no-op object — same
surface, zero work — per the off-by-default-cheap rule.
"""
from __future__ import annotations

import threading
from bisect import bisect_right
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "NULL_REGISTRY",
    "Sample", "LATENCY_BUCKETS", "SIZE_BUCKETS", "parse_prometheus_text",
]

# seconds; tuned for request/flush/sub-batch latencies in this stack
LATENCY_BUCKETS: Tuple[float, ...] = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0)
# items per batch/flush
SIZE_BUCKETS: Tuple[float, ...] = (
    1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 4096)

LabelItems = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> LabelItems:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    __slots__ = ("_lock", "value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n


class Gauge:
    __slots__ = ("_lock", "value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n


class Histogram:
    """Fixed-bucket histogram (cumulative on exposition, Prometheus
    style).  ``observe`` is a bisect + three adds under one lock."""

    __slots__ = ("_lock", "buckets", "counts", "sum", "count")

    def __init__(self, buckets: Iterable[float] = LATENCY_BUCKETS) -> None:
        self._lock = threading.Lock()
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self.counts = [0] * (len(self.buckets) + 1)   # last = +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        i = bisect_right(self.buckets, v)
        with self._lock:
            self.counts[i] += 1
            self.sum += v
            self.count += 1

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {"buckets": self.buckets, "counts": list(self.counts),
                    "sum": self.sum, "count": self.count}


class _NullInstrument:
    __slots__ = ()
    value = 0.0
    sum = 0.0
    count = 0
    buckets: Tuple[float, ...] = ()
    counts: List[int] = []

    def inc(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def snapshot(self) -> Dict[str, Any]:
        return {}


_NULL_INSTRUMENT = _NullInstrument()


class Sample:
    """One derived sample emitted by a collector at scrape time."""

    __slots__ = ("name", "mtype", "value", "labels", "help")

    def __init__(self, name: str, value: float, mtype: str = "counter",
                 labels: Optional[Dict[str, Any]] = None, help: str = ""):
        self.name = name
        self.value = float(value)
        self.mtype = mtype
        self.labels = labels or {}
        self.help = help


class _Family:
    __slots__ = ("name", "mtype", "help", "buckets", "children", "_lock")

    def __init__(self, name: str, mtype: str, help: str,
                 buckets: Optional[Tuple[float, ...]] = None):
        self.name = name
        self.mtype = mtype
        self.help = help
        self.buckets = buckets
        self.children: Dict[LabelItems, Any] = {}
        self._lock = threading.Lock()

    def child(self, labels: Dict[str, Any]):
        key = _label_key(labels)
        inst = self.children.get(key)
        if inst is None:
            with self._lock:
                inst = self.children.get(key)
                if inst is None:
                    if self.mtype == "counter":
                        inst = Counter()
                    elif self.mtype == "gauge":
                        inst = Gauge()
                    else:
                        inst = Histogram(self.buckets or LATENCY_BUCKETS)
                    self.children[key] = inst
        return inst


def _fmt_labels(items: LabelItems, extra: str = "") -> str:
    parts = [f'{k}="{v}"' for k, v in items]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt_value(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


class MetricsRegistry:
    """Named families of instruments + scrape-time collectors, rendered
    as Prometheus text exposition format 0.0.4."""

    enabled = True

    def __init__(self) -> None:
        self._families: Dict[str, _Family] = {}
        self._lock = threading.Lock()
        self._collectors: List[Callable[[], Iterable[Sample]]] = []

    # -- instrument factories ------------------------------------------
    def _family(self, name: str, mtype: str, help: str,
                buckets: Optional[Iterable[float]] = None) -> _Family:
        fam = self._families.get(name)
        if fam is None:
            with self._lock:
                fam = self._families.get(name)
                if fam is None:
                    fam = _Family(name, mtype, help,
                                  tuple(buckets) if buckets else None)
                    self._families[name] = fam
        if fam.mtype != mtype:
            raise ValueError(
                f"metric {name!r} already registered as {fam.mtype}")
        return fam

    def counter(self, name: str, help: str = "", **labels: Any) -> Counter:
        return self._family(name, "counter", help).child(labels)

    def gauge(self, name: str, help: str = "", **labels: Any) -> Gauge:
        return self._family(name, "gauge", help).child(labels)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Iterable[float]] = None,
                  **labels: Any) -> Histogram:
        return self._family(name, "histogram", help,
                            buckets=buckets).child(labels)

    # -- collectors ----------------------------------------------------
    def add_collector(self, fn: Callable[[], Iterable[Sample]]) -> None:
        """Register a scrape-time callable yielding :class:`Sample`s.
        Runs on every :meth:`render`; exceptions are swallowed into a
        ``metrics_collector_errors_total`` counter so one bad snapshot
        can't take down the whole exposition."""
        with self._lock:
            self._collectors.append(fn)

    # -- exposition ----------------------------------------------------
    def render(self) -> str:
        lines: List[str] = []
        with self._lock:
            families = list(self._families.items())
            collectors = list(self._collectors)

        for name, fam in sorted(families):
            self._render_family(lines, name, fam)

        collected: Dict[Tuple[str, str], List[Sample]] = {}
        errors = 0
        for fn in collectors:
            try:
                for s in fn():
                    collected.setdefault((s.name, s.mtype), []).append(s)
            except Exception:
                errors += 1
        for (name, mtype), samples in sorted(collected.items()):
            help = next((s.help for s in samples if s.help), "")
            if help:
                lines.append(f"# HELP {name} {help}")
            lines.append(f"# TYPE {name} {mtype}")
            for s in samples:
                lines.append(
                    f"{name}{_fmt_labels(_label_key(s.labels))}"
                    f" {_fmt_value(s.value)}")
        if errors:
            lines.append("# TYPE metrics_collector_errors_total counter")
            lines.append(f"metrics_collector_errors_total {errors}")
        return "\n".join(lines) + "\n"

    @staticmethod
    def _render_family(lines: List[str], name: str, fam: _Family) -> None:
        if fam.help:
            lines.append(f"# HELP {name} {fam.help}")
        lines.append(f"# TYPE {name} {fam.mtype}")
        children = sorted(fam.children.items())
        if fam.mtype in ("counter", "gauge"):
            for key, inst in children:
                lines.append(
                    f"{name}{_fmt_labels(key)} {_fmt_value(inst.value)}")
            return
        for key, inst in children:
            snap = inst.snapshot()
            cum = 0
            for b, c in zip(snap["buckets"], snap["counts"]):
                cum += c
                le = 'le="%g"' % b
                lines.append(f"{name}_bucket{_fmt_labels(key, le)} {cum}")
            cum += snap["counts"][-1]
            inf = 'le="+Inf"'
            lines.append(f"{name}_bucket{_fmt_labels(key, inf)} {cum}")
            lines.append(
                f"{name}_sum{_fmt_labels(key)} {_fmt_value(snap['sum'])}")
            lines.append(
                f"{name}_count{_fmt_labels(key)} {snap['count']}")


class _NullRegistry:
    """No-op registry: same factory surface, shared no-op instruments."""

    enabled = False

    def counter(self, name: str, help: str = "", **labels: Any):
        return _NULL_INSTRUMENT

    def gauge(self, name: str, help: str = "", **labels: Any):
        return _NULL_INSTRUMENT

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Iterable[float]] = None, **labels: Any):
        return _NULL_INSTRUMENT

    def add_collector(self, fn) -> None:
        pass

    def render(self) -> str:
        return "# observability disabled\n"


NULL_REGISTRY = _NullRegistry()


# ---------------------------------------------------------------------------

def parse_prometheus_text(text: str) -> Dict[str, float]:
    """Parse exposition text into ``{'name{a="b"}': value}`` with labels
    canonically sorted.  Supports what :meth:`MetricsRegistry.render`
    emits (no escapes inside label values); used by tests and the
    client's ``--check-metrics`` scrape assertion."""
    out: Dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        try:
            series, value = line.rsplit(None, 1)
        except ValueError:
            continue
        if "{" in series:
            name, rest = series.split("{", 1)
            body = rest.rsplit("}", 1)[0]
            labels = {}
            for part in body.split(","):
                if not part:
                    continue
                k, _, v = part.partition("=")
                labels[k.strip()] = v.strip().strip('"')
            key = name + _fmt_labels(_label_key(labels))
        else:
            key = series
        try:
            out[key] = float(value)
        except ValueError:
            continue
    return out


def series_key(name: str, **labels: Any) -> str:
    """Canonical key for looking up a series in
    :func:`parse_prometheus_text` output."""
    return name + _fmt_labels(_label_key(labels))
