"""Span-based request tracing with a bounded flight recorder.

One :class:`Trace` is born per server request (or per CLI query) and is
*activated* on whichever thread is currently doing that request's work.
Deep layers (session, engine, broker, oracle pool) never receive a trace
object — they call the module-level :func:`span` / :func:`start_span` /
:func:`add_timed_span` helpers, which consult a thread-local and become
no-ops when no trace is active.  That keeps the disabled path to a single
``getattr`` on a ``threading.local`` and lets the same engine serve traced
and untraced callers concurrently.

Completed traces land in a :class:`FlightRecorder` — a bounded ring buffer
(``collections.deque(maxlen=N)``) holding the last N requests for
postmortems — and can be exported as Chrome trace-event JSON
(``chrome://tracing`` / Perfetto) via :func:`chrome_trace`.

Span timestamps are ``time.perf_counter()`` values (monotonic, comparable
across threads on one host); each trace also records the wall-clock epoch
at which it started so exports can be anchored to real time.
"""
from __future__ import annotations

import itertools
import threading
import time
import uuid
from collections import deque
from typing import Any, Dict, Iterable, List, Optional

__all__ = [
    "Span", "Trace", "Tracer", "FlightRecorder", "NULL_SPAN", "NULL_TRACE",
    "new_trace_id", "span", "start_span", "add_timed_span", "activate",
    "active_trace", "chrome_trace",
]

_tls = threading.local()


def new_trace_id() -> str:
    """A fresh 16-hex-char trace id (short enough to grep, unique enough)."""
    return uuid.uuid4().hex[:16]


def active_trace() -> Optional["Trace"]:
    """The trace activated on this thread, or ``None``."""
    return getattr(_tls, "trace", None)


class Span:
    """One timed operation inside a trace.  Usable as a context manager or
    via explicit :meth:`end` when the operation doesn't nest lexically
    (e.g. the scheduler queue span, ended at grant on another thread)."""

    __slots__ = ("name", "span_id", "parent_id", "t0", "t1", "attrs", "thread")

    def __init__(self, name: str, span_id: int, parent_id: Optional[int],
                 t0: Optional[float] = None,
                 attrs: Optional[Dict[str, Any]] = None):
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = time.perf_counter() if t0 is None else t0
        self.t1: Optional[float] = None
        self.attrs: Dict[str, Any] = attrs or {}
        self.thread = threading.get_ident()

    def set(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self

    def end(self, t1: Optional[float] = None) -> None:
        if self.t1 is None:
            self.t1 = time.perf_counter() if t1 is None else t1

    @property
    def duration_s(self) -> float:
        return ((self.t1 if self.t1 is not None else time.perf_counter())
                - self.t0)

    # context-manager protocol (manual __enter__/__exit__: cheaper than
    # @contextmanager and exception-safe)
    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc is not None and "error" not in self.attrs:
            self.attrs["error"] = f"{type(exc).__name__}: {exc}"
        self.end()
        _pop_span(self)

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "span_id": self.span_id,
                "parent_id": self.parent_id, "t0": self.t0, "t1": self.t1,
                "thread": self.thread, "attrs": self.attrs}


class _NullSpan:
    """Shared no-op stand-in returned when tracing is off.  Supports the
    full Span surface so call sites never branch."""

    __slots__ = ()
    name = ""
    span_id = -1
    parent_id = None
    t0 = 0.0
    t1 = 0.0
    attrs: Dict[str, Any] = {}
    thread = 0
    duration_s = 0.0

    def set(self, **attrs: Any) -> "_NullSpan":
        return self

    def end(self, t1: Optional[float] = None) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        pass

    def to_dict(self) -> Dict[str, Any]:
        return {}


NULL_SPAN = _NullSpan()


class Trace:
    """A request's spans.  Threads append concurrently (the oracle pool
    records sub-batch spans from replica timings), so mutation is locked;
    reads for export happen after completion."""

    __slots__ = ("trace_id", "name", "attrs", "started_unix", "t0", "t1",
                 "spans", "root", "_lock", "_ids", "_finished")

    def __init__(self, name: str, trace_id: Optional[str] = None,
                 **attrs: Any):
        self.trace_id = trace_id or new_trace_id()
        self.name = name
        self.attrs: Dict[str, Any] = dict(attrs)
        self.started_unix = time.time()
        self.t0 = time.perf_counter()
        self.t1: Optional[float] = None
        self.spans: List[Span] = []
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._finished = False
        self.root = Span(name, 0, None, t0=self.t0, attrs=self.attrs)
        with self._lock:
            self.spans.append(self.root)

    @property
    def finished(self) -> bool:
        return self._finished

    def set(self, **attrs: Any) -> "Trace":
        self.attrs.update(attrs)
        return self

    def new_span(self, name: str, parent_id: Optional[int] = None,
                 t0: Optional[float] = None, **attrs: Any) -> Span:
        """Create + register a span.  Parent defaults to the root; use the
        module-level :func:`span` helper to nest under the thread's
        current span automatically."""
        with self._lock:
            sid = next(self._ids)
        s = Span(name, sid, 0 if parent_id is None else parent_id,
                 t0=t0, attrs=dict(attrs) if attrs else None)
        with self._lock:
            self.spans.append(s)
        return s

    def add_timed_span(self, name: str, t0: float, t1: float,
                       parent_id: Optional[int] = None, **attrs: Any) -> Span:
        """Record an already-completed interval (e.g. a replica sub-batch
        timed inside the pool worker, attached after the fact)."""
        s = self.new_span(name, parent_id=parent_id, t0=t0, **attrs)
        s.end(t1)
        return s

    def finish(self) -> None:
        if self._finished:
            return
        self._finished = True
        self.t1 = time.perf_counter()
        self.root.end(self.t1)
        with self._lock:
            for s in self.spans:
                s.end(self.t1)      # clamp any span leaked open

    @property
    def duration_s(self) -> float:
        return (self.t1 if self.t1 is not None else time.perf_counter()) \
            - self.t0

    def find_spans(self, name: str) -> List[Span]:
        with self._lock:
            return [s for s in self.spans if s.name == name]

    def to_dict(self) -> Dict[str, Any]:
        with self._lock:
            spans = [s.to_dict() for s in self.spans]
        return {"trace_id": self.trace_id, "name": self.name,
                "attrs": self.attrs, "started_unix": self.started_unix,
                "duration_s": self.duration_s, "spans": spans}

    def summary(self) -> Dict[str, Any]:
        with self._lock:
            n = len(self.spans)
        return {"trace_id": self.trace_id, "name": self.name,
                "attrs": self.attrs, "started_unix": self.started_unix,
                "duration_s": round(self.duration_s, 6), "n_spans": n}


class _NullTrace:
    """No-op trace handed out by a disabled tracer."""

    __slots__ = ()
    trace_id = ""
    name = ""
    attrs: Dict[str, Any] = {}
    spans: List[Span] = []
    root = NULL_SPAN
    finished = True
    duration_s = 0.0

    def set(self, **attrs: Any) -> "_NullTrace":
        return self

    def new_span(self, name: str, parent_id: Optional[int] = None,
                 t0: Optional[float] = None, **attrs: Any) -> _NullSpan:
        return NULL_SPAN

    def add_timed_span(self, name: str, t0: float, t1: float,
                       parent_id: Optional[int] = None,
                       **attrs: Any) -> _NullSpan:
        return NULL_SPAN

    def finish(self) -> None:
        pass

    def find_spans(self, name: str) -> List[Span]:
        return []

    def to_dict(self) -> Dict[str, Any]:
        return {}

    def summary(self) -> Dict[str, Any]:
        return {}


NULL_TRACE = _NullTrace()


# ---------------------------------------------------------------------------
# thread-local activation + in-context span helpers

class activate:
    """Context manager binding ``trace`` to the current thread so that
    :func:`span` calls anywhere down-stack attach to it.  ``NULL_TRACE``
    (or ``None``) deactivates, making the block trace-free."""

    __slots__ = ("_trace", "_prev_trace", "_prev_stack")

    def __init__(self, trace: Optional[Trace]):
        self._trace = None if trace is NULL_TRACE else trace

    def __enter__(self) -> Optional[Trace]:
        self._prev_trace = getattr(_tls, "trace", None)
        self._prev_stack = getattr(_tls, "stack", None)
        _tls.trace = self._trace
        _tls.stack = [] if self._trace is not None else None
        return self._trace

    def __exit__(self, exc_type, exc, tb) -> None:
        _tls.trace = self._prev_trace
        _tls.stack = self._prev_stack


def _pop_span(s: Span) -> None:
    stack = getattr(_tls, "stack", None)
    if stack and stack[-1] is s:
        stack.pop()


def span(name: str, **attrs: Any):
    """Start a nested span under the thread's active trace (no-op span if
    none).  Use as ``with span("broker.flush", n=5) as sp: ...``."""
    trace = getattr(_tls, "trace", None)
    if trace is None:
        return NULL_SPAN
    stack = getattr(_tls, "stack", None)
    parent = stack[-1].span_id if stack else 0
    s = trace.new_span(name, parent_id=parent, **attrs)
    if stack is not None:
        stack.append(s)
    return s


def start_span(name: str, **attrs: Any):
    """Like :func:`span` but NOT pushed on the nesting stack — for spans
    ended manually (possibly on another thread) via ``.end()``."""
    trace = getattr(_tls, "trace", None)
    if trace is None:
        return NULL_SPAN
    stack = getattr(_tls, "stack", None)
    parent = stack[-1].span_id if stack else 0
    return trace.new_span(name, parent_id=parent, **attrs)


def add_timed_span(name: str, t0: float, t1: float, **attrs: Any):
    """Attach an already-timed interval to the active trace (no-op if
    none).  Parent is the thread's current span."""
    trace = getattr(_tls, "trace", None)
    if trace is None:
        return NULL_SPAN
    stack = getattr(_tls, "stack", None)
    parent = stack[-1].span_id if stack else 0
    return trace.add_timed_span(name, t0, t1, parent_id=parent, **attrs)


# ---------------------------------------------------------------------------
# flight recorder + tracer

class FlightRecorder:
    """Bounded ring buffer of the last ``capacity`` completed traces.
    Appending is O(1) and drops the oldest trace beyond capacity — a
    crash/postmortem tool, not an archive."""

    def __init__(self, capacity: int = 256):
        self.capacity = int(capacity)
        self._traces: deque = deque(maxlen=max(1, self.capacity))
        self._lock = threading.Lock()
        self.recorded = 0

    def record(self, trace: Trace) -> None:
        if trace is NULL_TRACE:
            return
        with self._lock:
            self._traces.append(trace)
            self.recorded += 1

    def traces(self) -> List[Trace]:
        with self._lock:
            return list(self._traces)       # oldest -> newest

    def find(self, trace_id: str) -> Optional[Trace]:
        with self._lock:
            for t in reversed(self._traces):
                if t.trace_id == trace_id:
                    return t
        return None

    def summaries(self) -> List[Dict[str, Any]]:
        return [t.summary() for t in self.traces()]

    def clear(self) -> None:
        with self._lock:
            self._traces.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._traces)


class Tracer:
    """Trace factory.  Disabled tracers hand out ``NULL_TRACE`` so the
    whole span machinery short-circuits at the source."""

    def __init__(self, recorder: Optional[FlightRecorder] = None,
                 enabled: bool = True):
        self.recorder = recorder
        self.enabled = enabled

    def start(self, name: str, trace_id: Optional[str] = None,
              **attrs: Any) -> Trace:
        if not self.enabled:
            return NULL_TRACE
        return Trace(name, trace_id=trace_id, **attrs)

    def finish(self, trace: Trace) -> None:
        if trace is NULL_TRACE or not self.enabled:
            return
        trace.finish()
        if self.recorder is not None:
            self.recorder.record(trace)


# ---------------------------------------------------------------------------
# Chrome trace-event export

def chrome_trace(trace: Trace) -> Dict[str, Any]:
    """Export a finished trace as a Chrome trace-event JSON object
    (load in ``chrome://tracing`` or https://ui.perfetto.dev).  Uses "X"
    (complete) events with microsecond timestamps relative to trace
    start; span attrs land in ``args``."""
    events = []
    d = trace.to_dict()
    for s in d.get("spans", ()):
        t1 = s["t1"] if s["t1"] is not None else s["t0"]
        events.append({
            "name": s["name"],
            "ph": "X",
            "ts": round((s["t0"] - trace.t0) * 1e6, 1),
            "dur": round(max(0.0, t1 - s["t0"]) * 1e6, 1),
            "pid": 1,
            "tid": s["thread"],
            "args": dict(s["attrs"], span_id=s["span_id"],
                         parent_id=s["parent_id"]),
        })
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "trace_id": trace.trace_id,
            "name": trace.name,
            "started_unix": trace.started_unix,
            "duration_s": trace.duration_s,
            **{f"attr_{k}": v for k, v in trace.attrs.items()},
        },
    }


def chrome_traces(traces: Iterable[Trace]) -> Dict[str, Any]:
    """Merge several traces into one Chrome trace document (one ``pid``
    per trace so they stack as separate process tracks)."""
    events: List[Dict[str, Any]] = []
    meta: List[Dict[str, Any]] = []
    for pid, t in enumerate(traces, start=1):
        doc = chrome_trace(t)
        for ev in doc["traceEvents"]:
            ev["pid"] = pid
        events.extend(doc["traceEvents"])
        meta.append(doc["otherData"])
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"traces": meta}}
