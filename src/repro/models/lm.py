"""Unified LM: decoder-only (dense / MoE / hybrid / SSM / VLM) and
encoder-decoder (audio) — built from ``repro.models.blocks``.

Structure: per-position parameter trees stacked over ``n_repeats`` and scanned
(``lax.scan``) with optional per-block remat — this keeps HLO size and AOT
compile times flat in depth (72-layer jamba compiles like a 8-layer model).

Sharding is injected from the outside (``repro.parallel.sharding``): every
ParamSpec carries logical axis names; activations get ``with_sharding_constraint``
at block boundaries only (batch over ("pod","data")), internals are left to the
SPMD partitioner (see DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import blocks, rope as rope_lib
from repro.models.common import (ParamSpec, PyTree, init_params, rmsnorm,
                                 rmsnorm_specs, stack_specs)

BATCH_AXES = ("pod", "data")


def _constrain_batch(h: jax.Array, cfg=None) -> jax.Array:
    """Activation constraint at block boundaries (no-op without a mesh).

    megatron: batch over (pod, data).  pure_dp: batch over (pod, data, model).
    seq_dp: batch over (pod, data) and *sequence* over model — weights are
    replicated, so MLP/norms stay collective-free and attention gathers KV
    once per layer (the prefill hillclimb, EXPERIMENTS.md §Perf).
    """
    strategy = getattr(cfg, "shard_strategy", "megatron") if cfg else "megatron"
    candidates = []
    for batch_ax in (BATCH_AXES, ("data",)):  # multi-pod first, then single
        if strategy == "pure_dp":
            candidates.append(P(batch_ax + ("model",),
                                *([None] * (h.ndim - 1))))
        elif strategy in ("seq_dp", "ep_seq") and h.ndim >= 2:
            candidates.append(P(batch_ax, "model", *([None] * (h.ndim - 2))))
    for batch_ax in (BATCH_AXES, ("data",)):
        candidates.append(P(batch_ax, *([None] * (h.ndim - 1))))
    for spec in candidates:
        try:
            return jax.lax.with_sharding_constraint(h, spec)
        except (RuntimeError, ValueError, KeyError):
            continue
    return h


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def model_specs(cfg: ModelConfig) -> PyTree:
    d = cfg.d_model
    v = cfg.padded_vocab
    dt = jnp.dtype(cfg.param_dtype)
    specs: Dict[str, Any] = {
        "embed": ParamSpec((v, d), ("vocab", "embed"), dt),
        "blocks": tuple(stack_specs(t, cfg.n_repeats)
                        for t in blocks.block_specs(cfg, cross=cfg.encoder_decoder)),
        "final_norm": rmsnorm_specs(d, dt),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((d, v), ("embed", "vocab"), dt)
    if cfg.encoder_decoder:
        enc_layer = blocks.layer_specs(cfg, LayerSpec("attn", "dense"))
        specs["encoder"] = {
            "blocks": (stack_specs(enc_layer, cfg.n_encoder_layers),),
            "final_norm": rmsnorm_specs(d, dt),
        }
    return specs


def init_model(cfg: ModelConfig, key: jax.Array) -> PyTree:
    return init_params(model_specs(cfg), key)


# ---------------------------------------------------------------------------
# Pieces
# ---------------------------------------------------------------------------

def _embed_tokens(params: PyTree, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["embed"], tokens, axis=0)


def _angles_for(cfg: ModelConfig, batch: int, seq: int,
                positions: Optional[jax.Array] = None) -> Optional[jax.Array]:
    if not any(s.mixer == "attn" for s in cfg.pattern):
        return None
    hd = cfg.resolved_head_dim
    if cfg.mrope_sections:
        if positions is not None:
            # decode: a text token past the vision prefix has identical
            # (t,h,w) positions = pos - vision_tokens + 1
            p = jnp.asarray(positions) - cfg.vision_tokens + 1
            pos3 = jnp.broadcast_to(p.reshape(1, 1, 1), (3, batch, 1))
        else:
            pos3 = rope_lib.mrope_positions(batch, seq, cfg.vision_tokens,
                                            cfg.vision_grid)
        return rope_lib.mrope_angles(pos3, hd, cfg.rope_theta,
                                     cfg.mrope_sections)
    pos = (jnp.arange(seq)[None, :] if positions is None
           else jnp.broadcast_to(jnp.asarray(positions).reshape(1, 1), (batch, 1)))
    if positions is None:
        pos = jnp.broadcast_to(pos, (batch, seq))
    return rope_lib.rope_angles(pos, hd, cfg.rope_theta)


def _run_blocks(params: PyTree, h: jax.Array, cfg: ModelConfig,
                angles, causal: bool, enc_out=None,
                attn_impl: str = "xla") -> Tuple[jax.Array, jax.Array]:
    """Scan over n_repeats stacked blocks; returns (h, aux_loss)."""

    def body(carry, block_params):
        hh = _constrain_batch(carry, cfg)
        hh, aux = blocks.block_fwd(block_params, hh, cfg, angles, causal,
                                   enc_out=enc_out, attn_impl=attn_impl)
        return hh, aux

    if cfg.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    if cfg.unroll_layers:
        # methodology validation (EXPERIMENTS.md §Roofline): unrolled layers
        # make XLA cost analysis count every layer — ground truth for the
        # scan-once + block-scaling accounting
        aux_total = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_repeats):
            h, aux = body(h, jax.tree.map(lambda a: a[i], params["blocks"]))
            aux_total = aux_total + aux
        return h, aux_total
    h, auxs = jax.lax.scan(body, h, params["blocks"])
    return h, jnp.sum(auxs)


def _merge_vision(cfg: ModelConfig, h: jax.Array,
                  vision_embeds: Optional[jax.Array]) -> jax.Array:
    if not cfg.vision_tokens or vision_embeds is None:
        return h
    vt = cfg.vision_tokens
    s = h.shape[1]
    vis = jnp.pad(vision_embeds.astype(h.dtype),
                  ((0, 0), (0, s - vt), (0, 0)))
    mask = (jnp.arange(s) < vt)[None, :, None]
    return jnp.where(mask, vis, h)


def encode(params: PyTree, enc_embeds: jax.Array, cfg: ModelConfig,
           attn_impl: str = "xla") -> jax.Array:
    """Encoder stack (seamless): frame embeddings (B,S,D) -> (B,S,D)."""
    h, _ = _run_blocks(params["encoder"], enc_embeds.astype(jnp.dtype(cfg.dtype)),
                       cfg, angles=_angles_for(cfg, *enc_embeds.shape[:2]),
                       causal=False, attn_impl=attn_impl)
    return rmsnorm(params["encoder"]["final_norm"], h, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------

def forward_hidden(params: PyTree, batch: Dict[str, jax.Array],
                   cfg: ModelConfig, attn_impl: str = "xla"):
    """Returns (final hidden states (B,S,D), aux_loss)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = _embed_tokens(params, tokens)
    h = _merge_vision(cfg, h, batch.get("vision_embeds"))
    h = _constrain_batch(h, cfg)
    enc_out = None
    if cfg.encoder_decoder:
        enc_out = encode(params, batch["enc_embeds"], cfg, attn_impl=attn_impl)
    angles = _angles_for(cfg, b, s)
    h, aux = _run_blocks(params, h, cfg, angles, causal=True,
                         enc_out=enc_out, attn_impl=attn_impl)
    return rmsnorm(params["final_norm"], h, cfg.norm_eps), aux


def _unembed(params: PyTree, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])
    else:
        logits = jnp.dot(h, params["unembed"])
    if cfg.shard_strategy == "megatron":
        try:
            logits = jax.lax.with_sharding_constraint(
                logits, P(BATCH_AXES, None, "model"))
        except (RuntimeError, ValueError):
            pass
    else:
        logits = _constrain_batch(logits, cfg)
    return logits


def lm_loss(params: PyTree, batch: Dict[str, jax.Array], cfg: ModelConfig,
            attn_impl: str = "xla"):
    """Vocab-parallel cross-entropy.  batch: tokens, targets (+modality)."""
    h, aux = forward_hidden(params, batch, cfg, attn_impl=attn_impl)
    logits = _unembed(params, h, cfg).astype(jnp.float32)
    v = cfg.padded_vocab
    vocab_mask = (jnp.arange(v) < cfg.vocab_size)[None, None, :]
    logits = jnp.where(vocab_mask, logits, -1e30)
    targets = batch["targets"]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    true_logit = jnp.take_along_axis(logits, targets[..., None],
                                     axis=-1)[..., 0]
    token_mask = (targets >= 0).astype(jnp.float32)
    nll = (lse - true_logit) * token_mask
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(token_mask), 1.0)
    metrics = {"ce_loss": loss, "aux_loss": aux,
               "tokens": jnp.sum(token_mask)}
    return loss + aux, metrics


def lm_logits(params: PyTree, batch: Dict[str, jax.Array], cfg: ModelConfig,
              attn_impl: str = "xla") -> jax.Array:
    h, _ = forward_hidden(params, batch, cfg, attn_impl=attn_impl)
    return _unembed(params, h, cfg)


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode
# ---------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, batch: int, seq: int,
                cross_len: int = 0) -> PyTree:
    """Abstract stacked decode caches: tuple over pattern positions, each a
    tree with leading n_repeats dim."""
    out = []
    for spec in cfg.pattern:
        layer = blocks.layer_cache_specs(cfg, spec, batch, seq,
                                         cross_len if cfg.encoder_decoder else 0)
        out.append(jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.n_repeats,) + s.shape, s.dtype),
            layer))
    return tuple(out)


def init_cache(cfg: ModelConfig, batch: int, seq: int,
               cross_len: int = 0) -> PyTree:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, seq, cross_len))


def decode_step(params: PyTree, caches: PyTree, token: jax.Array, pos,
                cfg: ModelConfig):
    """One decode step.  token (B,1) int32; pos scalar int32 (current length).

    Returns (logits (B,1,V), new caches).  Attention caches are ring buffers
    sequence-sharded over ``model``; SSM/xLSTM states are O(1) per token.
    """
    b = token.shape[0]
    h = _embed_tokens(params, token)
    angles = _angles_for(cfg, b, 1, positions=pos)

    def body(carry, xs):
        block_params, block_cache = xs
        hh, new_cache = blocks.block_decode(block_params, carry, block_cache,
                                            pos, cfg, angles)
        return hh, new_cache

    h, new_caches = jax.lax.scan(body, h, (params["blocks"], caches))
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return _unembed(params, h, cfg), new_caches


def prefill(params: PyTree, batch: Dict[str, jax.Array], cfg: ModelConfig,
            cache_len: int, attn_impl: str = "xla"):
    """Run the full prompt, materializing decode caches of capacity cache_len.

    Used by the serving example; the decode dry-run cells take caches as
    abstract inputs directly.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    enc_out = None
    cross_len = 0
    if cfg.encoder_decoder:
        enc_out = encode(params, batch["enc_embeds"], cfg, attn_impl=attn_impl)
        cross_len = enc_out.shape[1]
    caches = init_cache(cfg, b, cache_len, cross_len)
    if cfg.encoder_decoder:
        # Precompute cross-attention K/V for every decoder layer.
        hd = cfg.resolved_head_dim
        new_caches = []
        for p_idx in range(len(cfg.pattern)):
            layer_cache = dict(caches[p_idx])
            wk = params["blocks"][p_idx]["cross_attn"]["wk"]  # (R, d, kvd)
            wv = params["blocks"][p_idx]["cross_attn"]["wv"]
            ck = jnp.einsum("bsd,rde->rbse", enc_out, wk)
            cv = jnp.einsum("bsd,rde->rbse", enc_out, wv)
            r = cfg.n_repeats
            layer_cache["cross_k"] = ck.reshape(r, b, cross_len,
                                                cfg.n_kv_heads, hd)
            layer_cache["cross_v"] = cv.reshape(r, b, cross_len,
                                                cfg.n_kv_heads, hd)
            new_caches.append(layer_cache)
        caches = tuple(new_caches)

    # Replay the prompt one token at a time in a scan (cache capacity >= s):
    # exact, single compiled graph.  (A parallel prefill that rebuilds caches
    # from the blocked forward is the attn-only fast path; see serving docs.)
    def step(carry, i):
        caches_c, h_unused = carry
        tok = jax.lax.dynamic_slice_in_dim(tokens, i, 1, axis=1)
        logits, caches_n = decode_step(params, caches_c, tok, i, cfg)
        return (caches_n, h_unused), logits[:, 0]

    (caches, _), all_logits = jax.lax.scan(
        step, (caches, jnp.zeros((b,), jnp.float32)), jnp.arange(s))
    return all_logits.swapaxes(0, 1), caches
