"""Mamba (selective SSM) mixer, TPU-adapted.

The CUDA reference implements the selective scan as a fused sequential kernel.
TPU adaptation: ``lax.scan`` over *time chunks* carrying the (B, d_inner, N)
state, with an intra-chunk ``associative_scan`` — the working set per step is
(B, chunk, d_inner_shard, N) which fits VMEM-scale budgets once ``d_inner`` is
tensor-parallel over ``model`` (in_proj column-parallel, out_proj row-parallel,
A/conv/dt sharded on d_inner).  This preserves the recurrence exactly (diagonal
A) instead of emulating the GPU kernel.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec, PyTree


def mamba_specs(cfg: ModelConfig) -> PyTree:
    d, di, n, r, w = (cfg.d_model, cfg.d_inner, cfg.ssm_state_dim,
                      cfg.dt_rank, cfg.ssm_conv_width)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "mamba_inner"), dt),
        "conv_w": ParamSpec((w, di), (None, "mamba_inner"), dt, init="normal",
                            init_scale=0.5),
        "conv_b": ParamSpec((di,), ("mamba_inner",), dt, init="zeros"),
        "x_proj": ParamSpec((di, r + 2 * n), ("mamba_inner", None), dt),
        "dt_proj": ParamSpec((r, di), (None, "mamba_inner"), dt),
        "dt_bias": ParamSpec((di,), ("mamba_inner",), dt, init="zeros"),
        "A_log": ParamSpec((di, n), ("mamba_inner", None), jnp.float32, init="ones"),
        "D": ParamSpec((di,), ("mamba_inner",), jnp.float32, init="ones"),
        "out_proj": ParamSpec((di, d), ("mamba_inner", "embed"), dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 init_state: jax.Array = None) -> jax.Array:
    """Depthwise causal conv. x (B,S,di), w (W,di). init_state (B,W-1,di)."""
    width = w.shape[0]
    if init_state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = init_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(width))
    return out + b[None, None, :]


def _ssm_inputs(params: PyTree, x_conv: jax.Array, cfg: ModelConfig):
    """x_conv (B,S,di) -> decay a (B,S,di,N) and input bx (B,S,di,N), C (B,S,N)."""
    n, r = cfg.ssm_state_dim, cfg.dt_rank
    proj = jnp.dot(x_conv, params["x_proj"])  # (B,S,r+2N)
    dt_in, b_in, c_in = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(jnp.dot(dt_in, params["dt_proj"])
                         + params["dt_bias"][None, None, :]).astype(jnp.float32)
    a_mat = -jnp.exp(params["A_log"])  # (di, N), negative
    a = jnp.exp(dt[..., None] * a_mat[None, None])  # (B,S,di,N) decay in (0,1]
    bx = (dt * x_conv.astype(jnp.float32))[..., None] * \
        b_in.astype(jnp.float32)[:, :, None, :]  # (B,S,di,N)
    return a, bx, c_in.astype(jnp.float32)


def _scan_chunk(a: jax.Array, bx: jax.Array, h0: jax.Array):
    """Intra-chunk associative scan. a/bx (B,T,di,N), h0 (B,di,N).

    Returns h (B,T,di,N) with h_t = a_t h_{t-1} + bx_t, and final state.
    """
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    a_c, b_c = jax.lax.associative_scan(combine, (a, bx), axis=1)
    h = a_c * h0[:, None] + b_c
    return h, h[:, -1]


def mamba_fwd(params: PyTree, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x (B,S,D) -> (B,S,D).  Chunked selective scan."""
    b, s, _ = x.shape
    di = cfg.d_inner
    xu, z = jnp.split(jnp.dot(x, params["in_proj"]), 2, axis=-1)
    x_conv = jax.nn.silu(_causal_conv(xu, params["conv_w"], params["conv_b"]))
    a, bx, c = _ssm_inputs(params, x_conv, cfg)

    chunk = min(cfg.ssm_chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    a_ch = a.reshape(b, nc, chunk, di, cfg.ssm_state_dim).swapaxes(0, 1)
    bx_ch = bx.reshape(b, nc, chunk, di, cfg.ssm_state_dim).swapaxes(0, 1)

    def body(h0, inputs):
        a_i, bx_i = inputs
        h, h_last = _scan_chunk(a_i, bx_i, h0)
        return h_last, h

    h0 = jnp.zeros((b, di, cfg.ssm_state_dim), jnp.float32)
    _, hs = jax.lax.scan(body, h0, (a_ch, bx_ch))
    h = hs.swapaxes(0, 1).reshape(b, s, di, cfg.ssm_state_dim)
    y = jnp.einsum("bsdn,bsn->bsd", h, c)
    y = y + params["D"][None, None, :] * x_conv.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return jnp.dot(y, params["out_proj"])


def mamba_decode(params: PyTree, x: jax.Array, conv_state: jax.Array,
                 h_state: jax.Array, cfg: ModelConfig):
    """One-token decode.  x (B,1,D); conv_state (B,W-1,di); h_state (B,di,N)."""
    xu, z = jnp.split(jnp.dot(x, params["in_proj"]), 2, axis=-1)
    x_conv = jax.nn.silu(_causal_conv(xu, params["conv_w"], params["conv_b"],
                                      init_state=conv_state))
    new_conv_state = jnp.concatenate([conv_state[:, 1:], xu], axis=1)
    a, bx, c = _ssm_inputs(params, x_conv, cfg)
    h = a[:, 0] * h_state + bx[:, 0]  # (B,di,N)
    y = jnp.einsum("bdn,bn->bd", h, c[:, 0])[:, None, :]
    y = y + params["D"][None, None, :] * x_conv.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return jnp.dot(y, params["out_proj"]), new_conv_state, h


def mamba_cache_specs(cfg: ModelConfig, batch: int):
    """abstract decode-state shapes for one mamba layer."""
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv_width - 1, cfg.d_inner),
                                     jnp.dtype(cfg.dtype)),
        "h": jax.ShapeDtypeStruct((batch, cfg.d_inner, cfg.ssm_state_dim),
                                  jnp.float32),
    }
