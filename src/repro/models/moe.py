"""Mixture-of-Experts layer: top-k routing, GShard-style grouped dense dispatch
with a capacity factor, expert-parallel sharding over the ``model`` mesh axis.

Why grouped dispatch: the dispatch one-hot has shape (groups, group_tokens,
experts, capacity) with capacity = group_tokens*top_k*cf/experts, so both its
memory and its einsum FLOPs scale as O(tokens * group_tokens * top_k * cf) —
*independent of expert count* — and stay a few percent of the expert-FFN FLOPs
for group_size <= 512 (see EXPERIMENTS.md §Roofline / moe-dispatch note).
Expert weights are sharded over ``model`` on the expert dim (64/16=4 olmoe,
128/16=8 qwen3-moe, 16/16=1 jamba per shard); the SPMD partitioner turns the
dispatch/combine einsums into the expected all-to-alls.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec, PyTree


def moe_specs(cfg: ModelConfig) -> PyTree:
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    e = cfg.n_experts
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "router": ParamSpec((d, e), ("embed", None), dt, init_scale=0.1),
        "wi_gate": ParamSpec((e, d, f), ("experts", "embed", "mlp"), dt),
        "wi_up": ParamSpec((e, d, f), ("experts", "embed", "mlp"), dt),
        "wo": ParamSpec((e, f, d), ("experts", "mlp", "embed"), dt),
    }


def _top_k_gating(logits: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """logits (..., E) -> (weights (..., k), indices (..., k)); softmax over top-k."""
    top_vals, top_idx = jax.lax.top_k(logits, k)
    weights = jax.nn.softmax(top_vals.astype(jnp.float32), axis=-1)
    return weights, top_idx


def moe_fwd(params: PyTree, x: jax.Array, cfg: ModelConfig):
    """x (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    gt = min(cfg.moe_group_size, b * s)
    tokens = b * s
    assert tokens % gt == 0, (tokens, gt)
    g = tokens // gt
    if gt <= 64:
        # decode / tiny-batch regime: dropless (cap covers the worst case) so
        # serving logits are independent of batch grouping
        cap = gt
    else:
        cap = max(1, int(round(gt * k * cfg.capacity_factor / e)))

    xg = x.reshape(g, gt, d)
    logits = jnp.dot(xg, params["router"]).astype(jnp.float32)  # (g, gt, E)
    weights, top_idx = _top_k_gating(logits, k)  # (g, gt, k)

    # Load-balancing auxiliary loss (Switch-style): mean prob * token fraction.
    probs = jax.nn.softmax(logits, axis=-1)
    density = jnp.mean(jax.nn.one_hot(top_idx[..., 0], e, dtype=jnp.float32),
                       axis=(0, 1))
    density_prob = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(density * density_prob)

    # Position of each (token, choice) within its expert's capacity buffer.
    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)  # (g, gt, k, E)
    flat = onehot.reshape(g, gt * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(g, gt, k, e)
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)  # (g, gt, k)
    keep = pos < cap  # capacity dropping
    weights = weights * keep.astype(weights.dtype)

    # dispatch tensor (g, gt, E, cap)
    pos_oh = jax.nn.one_hot(pos, cap, dtype=x.dtype) * keep[..., None].astype(x.dtype)
    dispatch = jnp.einsum("gtke,gtkc->gtec", onehot.astype(x.dtype), pos_oh)
    combine = jnp.einsum("gtk,gtke,gtkc->gtec",
                         weights.astype(x.dtype), onehot.astype(x.dtype), pos_oh)

    # tokens -> expert buffers (g, E, cap, D); all-to-all under EP sharding
    xe = jnp.einsum("gtd,gtec->gecd", xg, dispatch)
    # expert FFN (SwiGLU), batched over experts
    gate = jnp.einsum("gecd,edf->gecf", xe, params["wi_gate"])
    up = jnp.einsum("gecd,edf->gecf", xe, params["wi_up"])
    ye = jnp.einsum("gecf,efd->gecd", jax.nn.silu(gate) * up, params["wo"])
    # back to token order
    out = jnp.einsum("gecd,gtec->gtd", ye, combine)
    return out.reshape(b, s, d), aux * cfg.router_aux_weight
