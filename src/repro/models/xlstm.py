"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM (scalar
memory with exponential gating, strictly sequential).

TPU adaptation notes:
* mLSTM admits a chunkwise-parallel form (linear attention with per-step decay
  gates): ``lax.scan`` over chunks carrying the (B,H,hd,hd) matrix memory and
  (B,H,hd) normalizer, intra-chunk handled with (Tc x Tc) MXU matmuls.  We
  bound the exponential input gate with a softcap instead of carrying the
  max-stabilizer through the chunk recurrence (f-gate is a sigmoid <= 1, so
  products only decay); tests validate against the exact sequential recurrence.
* sLSTM has recurrent (h_{t-1}) gate dependencies -> no parallel form exists
  (per the paper); we scan over time with the standard m-stabilized update.
  Its recurrent weights are block-diagonal per head.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec, PyTree, softcap

_IGATE_CAP = 10.0


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_specs(cfg: ModelConfig) -> PyTree:
    d, di = cfg.d_model, cfg.mlstm_inner
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "up": ParamSpec((d, 2 * di), ("embed", "mlstm_inner"), dt),
        "wq": ParamSpec((di, di), ("mlstm_inner", "mlstm_inner2"), dt),
        "wk": ParamSpec((di, di), ("mlstm_inner", "mlstm_inner2"), dt),
        "wv": ParamSpec((di, di), ("mlstm_inner", "mlstm_inner2"), dt),
        "w_gates": ParamSpec((di, 2 * cfg.n_heads), ("mlstm_inner", None), dt,
                             init_scale=0.1),
        "b_gates": ParamSpec((2 * cfg.n_heads,), (None,), jnp.float32,
                             init="zeros"),
        "down": ParamSpec((di, d), ("mlstm_inner", "embed"), dt),
    }


def _mlstm_qkv_gates(params: PyTree, x: jax.Array, cfg: ModelConfig):
    di, h = cfg.mlstm_inner, cfg.n_heads
    hd = di // h
    u, z = jnp.split(jnp.dot(x, params["up"]), 2, axis=-1)
    b, s = u.shape[:2]
    q = jnp.dot(u, params["wq"]).reshape(b, s, h, hd)
    k = jnp.dot(u, params["wk"]).reshape(b, s, h, hd) / jnp.sqrt(float(hd))
    v = jnp.dot(u, params["wv"]).reshape(b, s, h, hd)
    gates = (jnp.dot(u, params["w_gates"]).astype(jnp.float32)
             + params["b_gates"][None, None])
    log_i = softcap(gates[..., :h], _IGATE_CAP)          # (B,S,H)
    log_f = jax.nn.log_sigmoid(gates[..., h:])           # (B,S,H) <= 0
    return q, k, v, log_i, log_f, z


def mlstm_fwd(params: PyTree, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x (B,S,D) -> (B,S,D), chunkwise-parallel mLSTM."""
    b, s, _ = x.shape
    h_heads = cfg.n_heads
    di = cfg.mlstm_inner
    hd = di // h_heads
    q, k, v, log_i, log_f, z = _mlstm_qkv_gates(params, x, cfg)
    chunk = min(cfg.ssm_chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    def resh(a):  # (B,S,...) -> (nc,B,chunk,...)
        return a.reshape(b, nc, chunk, *a.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = resh(q), resh(k), resh(v)
    lic, lfc = resh(log_i), resh(log_f)

    def body(carry, inp):
        c_state, n_state = carry  # (B,H,hd,hd), (B,H,hd)
        qi, ki, vi, li, lf = inp
        fcum = jnp.cumsum(lf, axis=1)  # (B,T,H) inclusive
        ftot = fcum[:, -1]
        # intra-chunk: weights_ts = exp(fcum_t - fcum_s + li_s) q_t.k_s, s<=t
        rel = fcum[:, :, None, :] - fcum[:, None, :, :] + li[:, None, :, :]
        mask = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
        rel = jnp.where(mask[None, :, :, None], rel, -jnp.inf)
        decay = jnp.exp(rel)  # (B,T,T,H)
        scores = jnp.einsum("bthd,bshd->btsh", qi, ki).astype(jnp.float32) * decay
        h_intra = jnp.einsum("btsh,bshd->bthd", scores.astype(vi.dtype), vi)
        n_intra = jnp.einsum("btsh,bshd->bthd", decay.astype(ki.dtype), ki)
        # inter-chunk from carried state
        qf = qi * jnp.exp(fcum).astype(qi.dtype)[..., None]
        h_inter = jnp.einsum("bthd,bhde->bthe", qf, c_state.astype(qi.dtype))
        n_inter = jnp.einsum("bthd,bhd->bth", qf, n_state.astype(qi.dtype))
        # normalizer: max(|n.q|, 1) with n_t = intra sum + decayed carry
        n_dot_q = (jnp.einsum("bthd,bthd->bth", n_intra.astype(jnp.float32),
                              qi.astype(jnp.float32))
                   + n_inter.astype(jnp.float32))
        denom = jnp.maximum(jnp.abs(n_dot_q), 1.0)[..., None]
        h_out = (h_intra.astype(jnp.float32) + h_inter.astype(jnp.float32)) / denom
        # state update to end of chunk
        wk = jnp.exp(ftot[:, None, :] - fcum + li).astype(ki.dtype)  # (B,T,H)
        c_new = (c_state * jnp.exp(ftot).astype(jnp.float32)[..., None, None]
                 + jnp.einsum("bthd,bthe->bhde",
                              (ki * wk[..., None]), vi).astype(jnp.float32))
        n_new = (n_state * jnp.exp(ftot).astype(jnp.float32)[..., None]
                 + jnp.sum(ki * wk[..., None], axis=1).astype(jnp.float32))
        return (c_new, n_new), h_out.astype(x.dtype)

    c0 = jnp.zeros((b, h_heads, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, h_heads, hd), jnp.float32)
    _, hs = jax.lax.scan(body, (c0, n0),
                         (qc, kc, vc, lic, lfc))
    out = hs.swapaxes(0, 1).reshape(b, s, di)
    out = out * jax.nn.silu(z)
    return jnp.dot(out, params["down"])


def mlstm_decode(params: PyTree, x: jax.Array, c_state, n_state,
                 cfg: ModelConfig):
    """One-token mLSTM step. c (B,H,hd,hd) n (B,H,hd)."""
    b = x.shape[0]
    h_heads = cfg.n_heads
    di = cfg.mlstm_inner
    hd = di // h_heads
    q, k, v, log_i, log_f, z = _mlstm_qkv_gates(params, x, cfg)
    i_g = jnp.exp(log_i[:, 0])[..., None]  # (B,H,1)
    f_g = jnp.exp(log_f[:, 0])[..., None]
    c_new = (c_state * f_g[..., None]
             + jnp.einsum("bhd,bhe->bhde", k[:, 0] * i_g.astype(k.dtype),
                          v[:, 0]).astype(jnp.float32))
    n_new = n_state * f_g + (k[:, 0] * i_g.astype(k.dtype)).astype(jnp.float32)
    h_num = jnp.einsum("bhd,bhde->bhe", q[:, 0].astype(jnp.float32), c_new)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh",
                                           n_new, q[:, 0].astype(jnp.float32))), 1.0)
    h_out = (h_num / denom[..., None]).reshape(b, 1, di).astype(x.dtype)
    out = h_out * jax.nn.silu(z)
    return jnp.dot(out, params["down"]), c_new, n_new


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_specs(cfg: ModelConfig) -> PyTree:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    p = int(d * cfg.xlstm_slstm_proj)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "w_in": ParamSpec((d, 4 * d), ("embed", None), dt),   # z,i,f,o inputs
        "r": ParamSpec((4, h, hd, hd), (None, None, None, None), dt,
                       init_scale=0.5),                        # block-diag recurrent
        "bias": ParamSpec((4 * d,), (None,), jnp.float32, init="zeros"),
        "up": ParamSpec((d, 2 * p), ("embed", "mlp"), dt),
        "down": ParamSpec((p, d), ("mlp", "embed"), dt),
    }


def _slstm_step(params: PyTree, cfg: ModelConfig, carry, x_t):
    """carry: (c,n,m,h) each (B,D) f32; x_t: precomputed W_in x (B,4D)."""
    c, n, m, h = carry
    d = cfg.d_model
    hh = cfg.n_heads
    hd = d // hh
    b = c.shape[0]
    hr = h.reshape(b, hh, hd)
    rec = jnp.einsum("bhd,ghde->bghe", hr.astype(params["r"].dtype),
                     params["r"]).reshape(b, 4 * d)
    pre = (x_t + rec.astype(jnp.float32)
           + params["bias"][None]).astype(jnp.float32)
    z_pre, i_pre, f_pre, o_pre = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    log_i = softcap(i_pre, _IGATE_CAP)
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + m, log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * z
    n_new = f_s * n + i_s
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h_new), h_new


def slstm_fwd(params: PyTree, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x (B,S,D) -> (B,S,D): sequential scan + post up/down projection."""
    b, s, d = x.shape
    x_in = jnp.dot(x, params["w_in"]).astype(jnp.float32)  # (B,S,4D)
    carry0 = tuple(jnp.zeros((b, d), jnp.float32) for _ in range(4))
    _, hs = jax.lax.scan(lambda c, xt: _slstm_step(params, cfg, c, xt),
                         carry0, x_in.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).astype(x.dtype)  # (B,S,D)
    u, g = jnp.split(jnp.dot(h, params["up"]), 2, axis=-1)
    return jnp.dot(u * jax.nn.gelu(g), params["down"])


def slstm_decode(params: PyTree, x: jax.Array, state, cfg: ModelConfig):
    """One-token sLSTM step; state = (c,n,m,h) each (B,D)."""
    x_in = jnp.dot(x[:, 0], params["w_in"]).astype(jnp.float32)
    state_new, h = _slstm_step(params, cfg, state, x_in)
    h = h[:, None, :].astype(x.dtype)
    u, g = jnp.split(jnp.dot(h, params["up"]), 2, axis=-1)
    return jnp.dot(u * jax.nn.gelu(g), params["down"]), state_new
