"""Rotary position embeddings: standard RoPE and M-RoPE (Qwen2-VL).

M-RoPE splits the head dimension into (temporal, height, width) sections; text
tokens use identical positions in all three sections, vision tokens use their
(t, h, w) grid coordinates.  ``mrope_positions`` builds the (3, B, S) position
tensor for the assignment's stubbed frontend: ``vision_tokens`` patch embeddings
occupy positions [0, V) on a (gh, gw) grid, text follows.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> jax.Array:
    """positions (..., S) -> angles (..., S, head_dim//2)."""
    freqs = rope_freqs(head_dim, theta)
    return positions[..., None].astype(jnp.float32) * freqs


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x (B, S, H, hd); angles (B, S, hd//2) or (S, hd//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if angles.ndim == 2:
        angles = angles[None]
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def mrope_angles(positions_3d: jax.Array, head_dim: int, theta: float,
                 sections: Tuple[int, int, int]) -> jax.Array:
    """positions_3d (3, B, S) -> angles (B, S, head_dim//2).

    ``sections`` gives per-axis sizes in *half-dim* units, summing to hd//2.
    """
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    freqs = rope_freqs(head_dim, theta)  # (hd//2,)
    # (3, B, S, hd//2)
    all_angles = positions_3d[..., None].astype(jnp.float32) * freqs
    parts = []
    off = 0
    for axis, sec in enumerate(sections):
        parts.append(all_angles[axis, :, :, off:off + sec])
        off += sec
    return jnp.concatenate(parts, axis=-1)


def mrope_positions(batch: int, seq: int, vision_tokens: int,
                    grid: Tuple[int, int], offset: int = 0) -> jax.Array:
    """(3, B, S) positions: vision patches on a grid, then text."""
    gh, gw = grid
    v = vision_tokens
    idx = jnp.arange(seq) + offset
    t_pos = jnp.where(idx < v, 0, idx - v + 1)
    h_pos = jnp.where(idx < v, (idx % (gh * gw)) // gw, idx - v + 1)
    w_pos = jnp.where(idx < v, idx % gw, idx - v + 1)
    pos = jnp.stack([t_pos, h_pos, w_pos])  # (3, S)
    return jnp.broadcast_to(pos[:, None, :], (3, batch, seq))
