"""Dense SwiGLU MLP (llama-family)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec, PyTree


def mlp_specs(cfg: ModelConfig, d_ff: int = 0) -> PyTree:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "wi_gate": ParamSpec((d, f), ("embed", "mlp"), dt),
        "wi_up": ParamSpec((d, f), ("embed", "mlp"), dt),
        "wo": ParamSpec((f, d), ("mlp", "embed"), dt),
    }


def mlp_fwd(params: PyTree, x: jax.Array) -> jax.Array:
    gate = jnp.dot(x, params["wi_gate"])
    up = jnp.dot(x, params["wi_up"])
    return jnp.dot(jax.nn.silu(gate) * up, params["wo"])
