"""Layer-block assembly: (norm -> mixer -> residual) + (norm -> mlp -> residual)
per :class:`repro.configs.base.LayerSpec`, with decode variants threading
per-layer state.  One *block* = one period of the config's repeating pattern;
``lm.py`` scans over ``n_repeats`` blocks with stacked parameters.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention, mamba, mlp, moe, xlstm
from repro.models.common import PyTree, rmsnorm, rmsnorm_specs


def layer_specs(cfg: ModelConfig, spec: LayerSpec, cross: bool = False) -> PyTree:
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    out: Dict[str, Any] = {"norm1": rmsnorm_specs(d, dt)}
    if spec.mixer == "attn":
        out["attn"] = attention.attention_specs(cfg)
    elif spec.mixer == "mamba":
        out["mamba"] = mamba.mamba_specs(cfg)
    elif spec.mixer == "mlstm":
        out["mlstm"] = xlstm.mlstm_specs(cfg)
    elif spec.mixer == "slstm":
        out["slstm"] = xlstm.slstm_specs(cfg)
    else:
        raise ValueError(spec.mixer)
    if cross:
        out["norm_cross"] = rmsnorm_specs(d, dt)
        out["cross_attn"] = attention.attention_specs(cfg, cross=True)
    if spec.mlp == "dense":
        out["norm2"] = rmsnorm_specs(d, dt)
        out["mlp"] = mlp.mlp_specs(cfg)
    elif spec.mlp == "moe":
        out["norm2"] = rmsnorm_specs(d, dt)
        out["moe"] = moe.moe_specs(cfg)
    return out


def block_specs(cfg: ModelConfig, cross: bool = False) -> Tuple[PyTree, ...]:
    """One period: a tuple of per-position layer spec trees."""
    return tuple(layer_specs(cfg, s, cross=cross) for s in cfg.pattern)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def layer_fwd(params: PyTree, h: jax.Array, cfg: ModelConfig, spec: LayerSpec,
              angles: Optional[jax.Array], causal: bool,
              enc_out: Optional[jax.Array] = None,
              attn_impl: str = "xla") -> Tuple[jax.Array, jax.Array]:
    """Returns (h, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    x = rmsnorm(params["norm1"], h, cfg.norm_eps)
    if spec.mixer == "attn":
        mixed = attention.attention_fwd(params["attn"], x, cfg, causal=causal,
                                        angles=angles, impl=attn_impl)
    elif spec.mixer == "mamba":
        mixed = mamba.mamba_fwd(params["mamba"], x, cfg)
    elif spec.mixer == "mlstm":
        mixed = xlstm.mlstm_fwd(params["mlstm"], x, cfg)
    elif spec.mixer == "slstm":
        mixed = xlstm.slstm_fwd(params["slstm"], x, cfg)
    else:
        raise ValueError(spec.mixer)
    h = h + mixed
    if "cross_attn" in params and enc_out is not None:
        xc = rmsnorm(params["norm_cross"], h, cfg.norm_eps)
        h = h + attention.attention_fwd(params["cross_attn"], xc, cfg,
                                        causal=False, angles=None,
                                        kv_x=enc_out, impl=attn_impl)
    if spec.mlp == "dense":
        x2 = rmsnorm(params["norm2"], h, cfg.norm_eps)
        h = h + mlp.mlp_fwd(params["mlp"], x2)
    elif spec.mlp == "moe":
        x2 = rmsnorm(params["norm2"], h, cfg.norm_eps)
        out, aux_l = moe.moe_fwd(params["moe"], x2, cfg)
        h = h + out
        aux = aux + aux_l
    return h, aux


def block_fwd(params_tuple: Tuple[PyTree, ...], h: jax.Array, cfg: ModelConfig,
              angles: Optional[jax.Array], causal: bool,
              enc_out: Optional[jax.Array] = None,
              attn_impl: str = "xla") -> Tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    for pos, spec in enumerate(cfg.pattern):
        h, a = layer_fwd(params_tuple[pos], h, cfg, spec, angles, causal,
                         enc_out=enc_out, attn_impl=attn_impl)
        aux = aux + a
    return h, aux


# ---------------------------------------------------------------------------
# Decode (one token, stateful)
# ---------------------------------------------------------------------------

def layer_cache_specs(cfg: ModelConfig, spec: LayerSpec, batch: int, seq: int,
                      cross_len: int = 0) -> PyTree:
    """Abstract per-layer decode state."""
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    out: Dict[str, Any] = {}
    if spec.mixer == "attn":
        kv = jax.ShapeDtypeStruct((batch, seq, cfg.n_kv_heads, hd), dt)
        out["k"], out["v"] = kv, kv
        if cfg.decode_ring:
            ring = jax.ShapeDtypeStruct(
                (batch, cfg.decode_ring, cfg.n_kv_heads, hd), dt)
            out["ring_k"], out["ring_v"] = ring, ring
    elif spec.mixer == "mamba":
        out.update(mamba.mamba_cache_specs(cfg, batch))
    elif spec.mixer == "mlstm":
        h = cfg.n_heads
        hd_m = cfg.mlstm_inner // h
        out["c"] = jax.ShapeDtypeStruct((batch, h, hd_m, hd_m), jnp.float32)
        out["n"] = jax.ShapeDtypeStruct((batch, h, hd_m), jnp.float32)
    elif spec.mixer == "slstm":
        for name in ("c", "n", "m", "h"):
            out[name] = jax.ShapeDtypeStruct((batch, cfg.d_model), jnp.float32)
    if cross_len:
        ckv = jax.ShapeDtypeStruct((batch, cross_len, cfg.n_kv_heads, hd), dt)
        out["cross_k"], out["cross_v"] = ckv, ckv
    return out


def layer_decode(params: PyTree, h: jax.Array, cache: PyTree, pos,
                 cfg: ModelConfig, spec: LayerSpec,
                 angles: Optional[jax.Array]) -> Tuple[jax.Array, PyTree]:
    new_cache = dict(cache)
    x = rmsnorm(params["norm1"], h, cfg.norm_eps)
    if spec.mixer == "attn":
        if cfg.decode_ring:
            mixed, rk, rv = attention.attention_decode_two_tier(
                params["attn"], x, cache["k"], cache["v"], cache["ring_k"],
                cache["ring_v"], pos, cfg, angles=angles)
            new_cache["ring_k"], new_cache["ring_v"] = rk, rv
        else:
            mixed, k, v = attention.attention_decode(
                params["attn"], x, cache["k"], cache["v"], pos, cfg,
                angles=angles)
            new_cache["k"], new_cache["v"] = k, v
    elif spec.mixer == "mamba":
        mixed, conv, hst = mamba.mamba_decode(params["mamba"], x,
                                              cache["conv"], cache["h"], cfg)
        new_cache["conv"], new_cache["h"] = conv, hst
    elif spec.mixer == "mlstm":
        mixed, c, n = xlstm.mlstm_decode(params["mlstm"], x, cache["c"],
                                         cache["n"], cfg)
        new_cache["c"], new_cache["n"] = c, n
    elif spec.mixer == "slstm":
        state = (cache["c"], cache["n"], cache["m"], cache["h"])
        mixed, state = xlstm.slstm_decode(params["slstm"], x, state, cfg)
        (new_cache["c"], new_cache["n"], new_cache["m"],
         new_cache["h"]) = state
    else:
        raise ValueError(spec.mixer)
    h = h + mixed
    if "cross_attn" in params:
        xc = rmsnorm(params["norm_cross"], h, cfg.norm_eps)
        mixed, _, _ = attention.attention_decode(
            params["cross_attn"], xc, cache["cross_k"], cache["cross_v"],
            pos, cfg, angles=None, cross=True)
        h = h + mixed
    if spec.mlp == "dense":
        x2 = rmsnorm(params["norm2"], h, cfg.norm_eps)
        h = h + mlp.mlp_fwd(params["mlp"], x2)
    elif spec.mlp == "moe":
        x2 = rmsnorm(params["norm2"], h, cfg.norm_eps)
        out, _ = moe.moe_fwd(params["moe"], x2, cfg)
        h = h + out
    return h, new_cache


def block_decode(params_tuple: Tuple[PyTree, ...], h: jax.Array,
                 caches: Tuple[PyTree, ...], pos, cfg: ModelConfig,
                 angles: Optional[jax.Array]):
    new_caches = []
    for p, spec in enumerate(cfg.pattern):
        h, c = layer_decode(params_tuple[p], h, caches[p], pos, cfg, spec, angles)
        new_caches.append(c)
    return h, tuple(new_caches)
