"""Grouped-query attention: training/prefill (blocked, flash-style in XLA) and
single-token decode over a (possibly sequence-sharded) KV cache.

Design notes (see DESIGN.md §4/§5):

* QKV/O projections are stored *flattened* ``(d_model, n_heads*head_dim)`` and
  column/row-sharded over the ``model`` mesh axis — head counts of the assigned
  archs (40, 28, 4, ...) are not divisible by 16, but ``n_heads*head_dim``
  always is.  Internal per-head shardings are left to the SPMD partitioner.

* The XLA path computes attention with an **unrolled outer loop over query
  blocks** and an inner ``lax.scan`` over key/value blocks with *static,
  causally-trimmed trip counts* (q-block i only scans kv-blocks [0, i]): true
  causal FLOPs (not the 2x of masked-full-blocks), flash-style O(S·block)
  memory, and HLO that compiles in seconds.  The Pallas flash-attention kernel
  (``repro.kernels.flash_attention``) is the TPU execution path; it is
  validated against ``ref.py`` in interpret mode and selected with
  ``impl="pallas"``.

* Decode: one query token against a full cache.  The cache is sharded along
  the *sequence* axis over ``model`` (flash-decoding style) — softmax over the
  sharded axis lowers to two small all-reduces per layer, which is what makes
  ``long_500k`` (batch=1) distributable.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import rope as rope_lib
from repro.models.common import ParamSpec, PyTree, rmsnorm

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def attention_specs(cfg: ModelConfig, cross: bool = False) -> PyTree:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    qd = cfg.n_heads * hd
    kvd = cfg.n_kv_heads * hd
    dt = jnp.dtype(cfg.param_dtype)
    specs = {
        "wq": ParamSpec((d, qd), ("embed", "heads"), dt),
        "wk": ParamSpec((d, kvd), ("embed", "kv_heads"), dt),
        "wv": ParamSpec((d, kvd), ("embed", "kv_heads"), dt),
        "wo": ParamSpec((qd, d), ("heads", "embed"), dt),
    }
    if cfg.qk_norm and not cross:
        specs["q_norm"] = ParamSpec((hd,), (None,), dt, init="ones")
        specs["k_norm"] = ParamSpec((hd,), (None,), dt, init="ones")
    return specs


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------

def _project_qkv(params: PyTree, x: jax.Array, cfg: ModelConfig,
                 kv_x: Optional[jax.Array] = None):
    """x (B,S,D) -> q (B,S,H,hd), k/v (B,Skv,Hk,hd)."""
    hd = cfg.resolved_head_dim
    kv_src = x if kv_x is None else kv_x
    q = jnp.dot(x, params["wq"]).reshape(*x.shape[:2], cfg.n_heads, hd)
    k = jnp.dot(kv_src, params["wk"]).reshape(*kv_src.shape[:2], cfg.n_kv_heads, hd)
    v = jnp.dot(kv_src, params["wv"]).reshape(*kv_src.shape[:2], cfg.n_kv_heads, hd)
    if "q_norm" in params:
        q = rmsnorm({"scale": params["q_norm"]}, q, cfg.norm_eps)
        k = rmsnorm({"scale": params["k_norm"]}, k, cfg.norm_eps)
    return q, k, v


def _out_proj(params: PyTree, o: jax.Array, cfg: ModelConfig) -> jax.Array:
    b, s = o.shape[:2]
    return jnp.dot(o.reshape(b, s, cfg.n_heads * cfg.resolved_head_dim), params["wo"])


# ---------------------------------------------------------------------------
# Blocked attention core (training / prefill)
# ---------------------------------------------------------------------------

def _block_attend(q, k, v, q_start, kv_start, scale, causal, window,
                  q_positions=None):
    """One (q-block, kv-block) tile -> (scores-applied v, running max, sum).

    q (B,Sq,Hk,G,hd); k/v (B,Bk,Hk,hd).  Returns unnormalized o, m, l.
    """
    sq, bk = q.shape[1], k.shape[1]
    s = jnp.einsum("bskgh,btkh->bkgst", q, k) * scale  # (B,Hk,G,Sq,Bk) bf16->f32
    s = s.astype(jnp.float32)
    qpos = (q_start + jnp.arange(sq)) if q_positions is None else q_positions
    kpos = kv_start + jnp.arange(bk)
    mask = jnp.ones((sq, bk), jnp.bool_)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)  # (B,Hk,G,Sq)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", p.astype(v.dtype), v)
    return o, m, l


def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      cfg: ModelConfig, causal: bool,
                      window: int = 0) -> jax.Array:
    """Flash-style attention in pure XLA.

    q (B,S,H,hd), k/v (B,Skv,Hk,hd).  Outer unrolled loop over q blocks; inner
    lax.scan over kv blocks with causally/window-trimmed static trip counts.
    """
    b, s, h, hd = q.shape
    skv = k.shape[1]
    hk = cfg.n_kv_heads
    g = h // hk
    scale = 1.0 / math.sqrt(hd)
    bq = min(cfg.attn_block_q, s)
    bk = min(cfg.attn_block_k, skv)
    assert s % bq == 0 and skv % bk == 0, (s, bq, skv, bk)
    nq, nk = s // bq, skv // bk
    qg = q.reshape(b, s, hk, g, hd)

    out_blocks = []
    for i in range(nq):
        q_blk = jax.lax.dynamic_slice_in_dim(qg, i * bq, bq, axis=1)
        q_start = i * bq
        # static kv range for this q block
        if causal:
            hi = i + 1  # kv blocks fully above the diagonal are skipped
        else:
            hi = nk
        lo = 0
        if window:
            lo = max(0, (q_start - window + 1) // bk)
        n_trips = hi - lo

        def body(carry, j):
            o_acc, m_acc, l_acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, (lo + j) * bk, bk, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, (lo + j) * bk, bk, axis=1)
            o, m, l = _block_attend(q_blk, k_blk, v_blk, q_start,
                                    (lo + j) * bk, scale, causal, window)
            m_new = jnp.maximum(m_acc, m)
            alpha = jnp.exp(m_acc - m_new)
            beta = jnp.exp(m - m_new)
            l_new = l_acc * alpha + l * beta
            o_new = (o_acc * alpha[..., None].astype(o.dtype)
                     + o.transpose(0, 2, 3, 1, 4) * beta[..., None].astype(o.dtype))
            return (o_new, m_new, l_new), None

        o0 = jnp.zeros((b, hk, g, bq, hd), jnp.float32)
        m0 = jnp.full((b, hk, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hk, g, bq), jnp.float32)
        (o_f, m_f, l_f), _ = jax.lax.scan(body, (o0, m0, l0), jnp.arange(n_trips))
        o_norm = o_f / jnp.maximum(l_f, 1e-30)[..., None]
        out_blocks.append(o_norm.transpose(0, 3, 1, 2, 4).reshape(b, bq, h, hd))
    return jnp.concatenate(out_blocks, axis=1).astype(q.dtype)


def _local_blocked_attention(q, k, v, cfg: ModelConfig, q_start, causal: bool,
                             window: int) -> jax.Array:
    """Blocked attention for a LOCAL q chunk against full K/V, with a traced
    sequence offset ``q_start`` and a causally-trimmed *dynamic* kv loop
    (fori_loop; trip count depends on the shard index)."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    hk = cfg.n_kv_heads
    g = h // hk
    scale = 1.0 / math.sqrt(hd)
    bk = min(cfg.attn_block_k, skv)
    assert skv % bk == 0
    nk = skv // bk
    qg = q.reshape(b, sq, hk, g, hd)
    q_positions = q_start + jnp.arange(sq)

    def body(j, carry):
        o_acc, m_acc, l_acc = carry
        k_blk = jax.lax.dynamic_slice_in_dim(k, j * bk, bk, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(v, j * bk, bk, axis=1)
        o, m, l = _block_attend(qg, k_blk, v_blk, 0, j * bk, scale, causal,
                                window, q_positions=q_positions)
        m_new = jnp.maximum(m_acc, m)
        alpha = jnp.exp(m_acc - m_new)
        beta = jnp.exp(m - m_new)
        l_new = l_acc * alpha + l * beta
        o_new = (o_acc * alpha[..., None]
                 + o.transpose(0, 2, 3, 1, 4).astype(jnp.float32)
                 * beta[..., None])
        return (o_new, m_new, l_new)

    if causal:
        hi = (q_start + sq + bk - 1) // bk  # traced upper bound
    else:
        hi = nk
    lo = 0
    if window:
        lo = jnp.maximum(0, (q_start - window + 1) // bk)
    o0 = jnp.zeros((b, hk, g, sq, hd), jnp.float32)
    m0 = jnp.full((b, hk, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hk, g, sq), jnp.float32)
    o_f, m_f, l_f = jax.lax.fori_loop(lo, hi, body, (o0, m0, l0))
    o_norm = o_f / jnp.maximum(l_f, 1e-30)[..., None]
    return o_norm.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd).astype(q.dtype)


def _seq_dp_attention(q, k, v, cfg: ModelConfig, causal: bool, window: int):
    """Sequence-parallel blockwise attention (seq_dp strategy).

    Each ``model`` shard owns S/n_model query positions; K/V are all-gathered
    ONCE per layer (tiled), and the causally-needed kv prefix is walked with a
    dynamic fori_loop — per-layer wire is exactly the KV bytes, and compute
    splits causally-balanced-enough across shards (shard i does (i+1)/n of the
    score work; the imbalance is the known cost of contiguous partitioning —
    see EXPERIMENTS.md §Perf prefill iteration 4).
    """
    try:
        from jax.experimental.shard_map import shard_map
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or "model" not in mesh.axis_names:
            return None
        batch_ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        n_model = mesh.shape["model"]
        if q.shape[1] % n_model or k.shape[1] % n_model:
            return None
        from jax.sharding import PartitionSpec as P
        spec = P(batch_ax, "model", None, None)

        def local(qs, ks, vs):
            kf = jax.lax.all_gather(ks, "model", axis=1, tiled=True)
            vf = jax.lax.all_gather(vs, "model", axis=1, tiled=True)
            idx = jax.lax.axis_index("model")
            q_start = idx * qs.shape[1]
            return _local_blocked_attention(qs, kf, vf, cfg, q_start, causal,
                                            window)

        fn = shard_map(local, mesh=mesh, in_specs=(spec, spec, spec),
                       out_specs=spec, check_rep=False)
        return fn(q, k, v)
    except Exception:
        return None


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def _replicate_seq(t: jax.Array) -> jax.Array:
    """Force one explicit KV gather per layer under seq_dp (XLA otherwise
    re-gathers the sequence-sharded KV for every unrolled q block — the
    refuted first attempt in EXPERIMENTS.md §Perf/prefill)."""
    from jax.sharding import PartitionSpec as P
    for batch_ax in (("pod", "data"), ("data",)):
        try:
            return jax.lax.with_sharding_constraint(
                t, P(batch_ax, *([None] * (t.ndim - 1))))
        except (RuntimeError, ValueError, KeyError):
            continue
    return t


def attention_fwd(params: PyTree, x: jax.Array, cfg: ModelConfig,
                  causal: bool = True, angles: Optional[jax.Array] = None,
                  kv_x: Optional[jax.Array] = None,
                  impl: str = "xla") -> jax.Array:
    """Full-sequence attention (training / prefill).  kv_x -> cross-attention."""
    q, k, v = _project_qkv(params, x, cfg, kv_x)
    if angles is not None and kv_x is None:
        q = rope_lib.apply_rope(q, angles)
        k = rope_lib.apply_rope(k, angles)
    if cfg.shard_strategy in ("seq_dp", "ep_seq") and kv_x is None:
        out = _seq_dp_attention(q, k, v, cfg, causal=causal,
                                window=cfg.sliding_window)
        if out is not None:
            return _out_proj(params, out, cfg)
        # no mesh / model axis available: fall back to explicit KV gather
        k = _replicate_seq(k)
        v = _replicate_seq(v)
    window = cfg.sliding_window if kv_x is None else 0
    if impl == "pallas" or impl == "pallas_interpret":
        from repro.kernels.flash_attention import ops as flash_ops
        o = flash_ops.flash_attention(
            q, k, v, causal=causal, window=window,
            interpret=(impl == "pallas_interpret"))
    else:
        o = blocked_attention(q, k, v, cfg, causal=causal and kv_x is None,
                              window=window)
    return _out_proj(params, o, cfg)


def attention_decode(params: PyTree, x: jax.Array, cache_k: jax.Array,
                     cache_v: jax.Array, pos, cfg: ModelConfig,
                     angles: Optional[jax.Array] = None,
                     cross: bool = False,
                     update_cache: bool = True):
    """One-token decode.  x (B,1,D); cache_k/v (B,S,Hk,hd) seq-sharded.

    Returns (out (B,1,D), new_cache_k, new_cache_v).  For cross-attention the
    cache holds precomputed encoder K/V and is not updated.
    """
    b, _, d = x.shape
    hd = cfg.resolved_head_dim
    hk, h = cfg.n_kv_heads, cfg.n_heads
    g = h // hk
    q = jnp.dot(x, params["wq"]).reshape(b, 1, h, hd)
    if "q_norm" in params:
        q = rmsnorm({"scale": params["q_norm"]}, q, cfg.norm_eps)
    if angles is not None:
        q = rope_lib.apply_rope(q, angles)
    if not cross:
        k_new = jnp.dot(x, params["wk"]).reshape(b, 1, hk, hd)
        v_new = jnp.dot(x, params["wv"]).reshape(b, 1, hk, hd)
        if "k_norm" in params:
            k_new = rmsnorm({"scale": params["k_norm"]}, k_new, cfg.norm_eps)
        if angles is not None:
            k_new = rope_lib.apply_rope(k_new, angles)
        if update_cache:
            s = cache_k.shape[1]
            slot = pos % s
            if cfg.decode_cache_update == "dus":
                # single-slot write: O(1) HBM traffic; SPMD turns this into a
                # masked update on the owning sequence shard only
                cache_k = jax.lax.dynamic_update_slice_in_dim(
                    cache_k, k_new.astype(cache_k.dtype), slot, axis=1)
                cache_v = jax.lax.dynamic_update_slice_in_dim(
                    cache_v, v_new.astype(cache_v.dtype), slot, axis=1)
            else:
                # ring-buffer write via one-hot mask (baseline; rewrites the
                # full cache — see EXPERIMENTS.md §Perf decode hillclimb)
                onehot = (jnp.arange(s) == slot)[None, :, None, None]
                cache_k = jnp.where(onehot, k_new.astype(cache_k.dtype),
                                    cache_k)
                cache_v = jnp.where(onehot, v_new.astype(cache_v.dtype),
                                    cache_v)
    s = cache_k.shape[1]
    qg = q.reshape(b, 1, hk, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, cache_k) / math.sqrt(hd)
    scores = scores.astype(jnp.float32)
    if not cross:
        kpos = jnp.arange(s)
        valid = (kpos <= pos)[None, :]  # (1, S) causal within cache
        if cfg.sliding_window:
            valid &= (pos - kpos < cfg.sliding_window)[None, :]
        scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgst,btkh->bskgh", (p / l).astype(cache_v.dtype), cache_v)
    out = _out_proj(params, o.reshape(b, 1, h, hd), cfg)
    return out, cache_k, cache_v


def attention_decode_two_tier(params: PyTree, x: jax.Array, main_k, main_v,
                              ring_k, ring_v, pos, cfg: ModelConfig,
                              angles=None):
    """Two-tier decode (§Perf decode hillclimb): the S-token main cache is
    READ-ONLY; the new token's K/V go into a small ring of recent tokens
    (slot i holds absolute position S+i while the ring fills; the host merges
    ring -> main every ``decode_ring`` steps, amortized O(1)).  Per-step HBM
    writes therefore touch O(ring) bytes instead of O(S).

    Returns (out (B,1,D), new_ring_k, new_ring_v).
    """
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    hk, h = cfg.n_kv_heads, cfg.n_heads
    g = h // hk
    s = main_k.shape[1]
    w = ring_k.shape[1]
    q = jnp.dot(x, params["wq"]).reshape(b, 1, h, hd)
    k_new = jnp.dot(x, params["wk"]).reshape(b, 1, hk, hd)
    v_new = jnp.dot(x, params["wv"]).reshape(b, 1, hk, hd)
    if "q_norm" in params:
        q = rmsnorm({"scale": params["q_norm"]}, q, cfg.norm_eps)
        k_new = rmsnorm({"scale": params["k_norm"]}, k_new, cfg.norm_eps)
    if angles is not None:
        q = rope_lib.apply_rope(q, angles)
        k_new = rope_lib.apply_rope(k_new, angles)
    slot = (pos - s) % w
    onehot = (jnp.arange(w) == slot)[None, :, None, None]
    ring_k = jnp.where(onehot, k_new.astype(ring_k.dtype), ring_k)
    ring_v = jnp.where(onehot, v_new.astype(ring_v.dtype), ring_v)

    qg = q.reshape(b, 1, hk, g, hd)
    scale = 1.0 / math.sqrt(hd)
    s_main = (jnp.einsum("bskgh,btkh->bkgst", qg, main_k) * scale
              ).astype(jnp.float32)
    s_ring = (jnp.einsum("bskgh,btkh->bkgst", qg, ring_k) * scale
              ).astype(jnp.float32)
    kpos_main = jnp.arange(s)
    kpos_ring = s + jnp.arange(w)
    valid_main = (kpos_main <= pos)[None, :]
    valid_ring = (kpos_ring <= pos)[None, :]
    if cfg.sliding_window:
        valid_main &= (pos - kpos_main < cfg.sliding_window)[None, :]
        valid_ring &= (pos - kpos_ring < cfg.sliding_window)[None, :]
    s_main = jnp.where(valid_main[None, None, None], s_main, NEG_INF)
    s_ring = jnp.where(valid_ring[None, None, None], s_ring, NEG_INF)
    # flash-style merge of the two pieces — NO concat: the main scores stay
    # sequence-sharded (their max/sum lower to small all-reduces) while the
    # ring piece is shard-local; concatenating differently-sharded tensors
    # would force a gather + replicated compute (refuted iteration 2).
    m_main = jnp.max(s_main, axis=-1, keepdims=True)
    m_ring = jnp.max(s_ring, axis=-1, keepdims=True)
    m = jnp.maximum(m_main, m_ring)
    p_main = jnp.exp(s_main - m)
    p_ring = jnp.exp(s_ring - m)
    l = (jnp.sum(p_main, axis=-1, keepdims=True)
         + jnp.sum(p_ring, axis=-1, keepdims=True))
    o = (jnp.einsum("bkgst,btkh->bskgh", (p_main / l).astype(main_v.dtype),
                    main_v)
         + jnp.einsum("bkgst,btkh->bskgh", (p_ring / l).astype(ring_v.dtype),
                      ring_v))
    out = _out_proj(params, o.reshape(b, 1, h, hd), cfg)
    return out, ring_k, ring_v
