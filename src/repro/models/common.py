"""Minimal functional parameter system + shared layers.

We deliberately avoid a module framework: parameters are nested dicts of
arrays, and each layer exposes ``*_specs(cfg) -> tree of ParamSpec`` and an
``apply``-style function.  ``ParamSpec`` carries *logical axis names* which
``repro.parallel.sharding`` maps to mesh ``PartitionSpec``s — the same spec
tree therefore drives ``jax.eval_shape``-based AOT lowering (no allocation)
and real initialization.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]  # one name per dim (None = replicated)
    dtype: Any = jnp.bfloat16
    init: str = "normal"  # normal | zeros | ones | conv | small
    init_scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (self.shape, self.logical_axes)


def is_spec_leaf(x) -> bool:
    return isinstance(x, ParamSpec)


def spec_map(fn: Callable[[ParamSpec], Any], tree: PyTree) -> PyTree:
    return jax.tree.map(fn, tree, is_leaf=is_spec_leaf)


def abstract_params(specs: PyTree) -> PyTree:
    """ParamSpec tree -> ShapeDtypeStruct tree (for eval_shape / AOT)."""
    return spec_map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs)


def init_params(specs: PyTree, key: jax.Array) -> PyTree:
    """Materialize parameters (smoke tests / real training on CPU)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec_leaf)
    keys = jax.random.split(key, len(leaves))

    def one(spec: ParamSpec, k):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, spec.dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, spec.dtype)
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        scale = spec.init_scale / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, spec.shape, jnp.float32) * scale).astype(spec.dtype)

    return jax.tree.unflatten(treedef, [one(s, k) for s, k in zip(leaves, keys)])


def stacked(spec: ParamSpec, n: int, axis_name: str = "layers") -> ParamSpec:
    """Add a leading stacked-layer dimension (for scan-over-layers)."""
    return ParamSpec((n,) + spec.shape, (axis_name,) + spec.logical_axes,
                     spec.dtype, spec.init, spec.init_scale)


def stack_specs(tree: PyTree, n: int) -> PyTree:
    return spec_map(lambda s: stacked(s, n), tree)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_specs(d: int, dtype) -> PyTree:
    return {"scale": ParamSpec((d,), (None,), dtype, init="ones")}


def rmsnorm(params: PyTree, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_specs(d: int, dtype) -> PyTree:
    return {"scale": ParamSpec((d,), (None,), dtype, init="ones"),
            "bias": ParamSpec((d,), (None,), dtype, init="zeros")}


def layernorm(params: PyTree, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def take_layer(params: PyTree, i) -> PyTree:
    """Slice layer ``i`` out of a stacked parameter tree."""
    return jax.tree.map(lambda a: a[i], params)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)
