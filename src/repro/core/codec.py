"""JSON wire form of a :class:`~repro.core.engine.QueryResult`.

One codec serves every presentation layer: the ``repro.launch.query`` CLI
prints these rows and the serving layer (``repro.serve.server``) returns
them to HTTP clients.  It lives in core so presentation layers depend on
core, never on each other.
"""
from __future__ import annotations

from typing import Any, Dict, Optional


def result_row(res, workload: Optional[str] = None) -> Dict[str, Any]:
    """Flatten a ``QueryResult`` into JSON-safe primitives.  ``workload`` is
    the mounted workload that actually executed the query (multi-workload
    servers stamp it so interleaved clients can tell rows apart).  There is
    deliberately no fallback to the spec's own routing field: a caller that
    does not route (the ``launch.query`` CLI) must not report one."""
    row = {
        "kind": res.kind,
        "n_invocations": res.n_invocations,
        "n_oracle_fresh": res.n_oracle_fresh,
        "n_oracle_cached": res.n_oracle_cached,
        "n_cracked": res.n_cracked,
        "query_cost_s": round(sum(res.cost.values()), 3),
        "plan": res.plan.trace,
    }
    if workload is not None:
        row["workload"] = workload
    # scheduling fields echo back only when the caller set them, so rows
    # from unscheduled runs stay byte-identical to pre-scheduler output
    spec = res.plan.spec
    if spec.priority is not None:
        row["priority"] = int(spec.priority)
    if spec.deadline_ms is not None:
        row["deadline_ms"] = float(spec.deadline_ms)
    if res.estimate is not None:
        row["estimate"] = round(res.estimate, 6)
    if res.ci_half_width is not None:
        row["ci_half_width"] = round(res.ci_half_width, 6)
    if res.threshold is not None:
        row["threshold"] = round(res.threshold, 6)
    if res.selected is not None:
        row["n_selected"] = int(len(res.selected))
        row["selected_head"] = [int(i) for i in res.selected[:10]]
    if res.session is not None:
        row["session"] = res.session
    return row
