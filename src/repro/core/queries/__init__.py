"""Query-processing algorithms (paper §4.3) + the executor registry that
plugs them into the declarative engine (:mod:`repro.core.engine`).

Importing this package registers the built-in executors for the three paper
query kinds: ``aggregation``, ``selection`` (SUPG), and ``limit``.
"""
from repro.core.queries import registry  # noqa: F401
from repro.core.queries import aggregation, limit, selection  # noqa: F401

from repro.core.queries.registry import (  # noqa: F401
    QueryExecutor, get_executor, register_executor, registered_kinds)
