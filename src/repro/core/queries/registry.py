"""Query-executor registry: the pluggable half of the declarative engine.

Each query kind (``aggregation``, ``selection``, ``limit``, ...) registers a
:class:`QueryExecutor` describing how to plan and run specs of that kind.  The
query modules in this package register themselves at import time, so new query
types plug in without touching :mod:`repro.core.engine`:

    @register_executor
    class MyExecutor(QueryExecutor):
        kind = "my-kind"
        default_propagation = "numeric"
        def execute(self, plan, proxy, oracle):
            ...
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Callable, Dict, Type

import numpy as np

if TYPE_CHECKING:  # pragma: no cover - import cycle guard, typing only
    from repro.core.engine import QueryPlan, QueryResult


class QueryExecutor:
    """One query kind's planning defaults + execution strategy.

    Subclasses set the class attributes and implement :meth:`execute`;
    :meth:`validate` may raise ``ValueError`` for malformed specs at *plan*
    time (before any oracle cost is spent).
    """

    #: registry key; ``QuerySpec.kind`` strings resolve against this
    kind: str = ""
    #: propagation mode used when the spec does not pin one
    #: ("numeric" | "top1" | "categorical")
    default_propagation: str = "numeric"
    #: clip propagated scores into [0, 1] (probability-shaped proxies)
    clip01: bool = False

    def validate(self, spec) -> None:
        """Raise ``ValueError`` if ``spec`` is not executable for this kind."""

    def preview(self, plan: "QueryPlan", proxy: np.ndarray) -> np.ndarray:
        """Record ids this plan will deterministically request first.

        Sessions prefetch these through the oracle broker before executing
        any spec, so one combined ``target_dnn_batch`` flush serves many
        specs.  Must be a *certain* prefix of the execution's requests (no
        speculation — prefetched labels are charged to the spec).  Default:
        nothing to prefetch."""
        return np.empty(0, np.int64)

    def execute(self, plan: "QueryPlan", proxy: np.ndarray,
                oracle: Callable[[np.ndarray], np.ndarray]):
        """Run the plan.  Returns the kind-specific raw result object;
        the engine wraps it into a uniform ``QueryResult``."""
        raise NotImplementedError

    def summarize(self, raw) -> Dict:
        """Map the raw result onto the uniform ``QueryResult`` fields.
        Must include ``n_invocations``; may include ``estimate``,
        ``selected``, ``threshold``, ``ci_half_width``."""
        raise NotImplementedError


_EXECUTORS: Dict[str, QueryExecutor] = {}


def register_executor(cls: Type[QueryExecutor]) -> Type[QueryExecutor]:
    """Class decorator: instantiate and register an executor under its kind."""
    if not cls.kind:
        raise ValueError(f"{cls.__name__} must set a non-empty `kind`")
    _EXECUTORS[cls.kind] = cls()
    return cls


def get_executor(kind: str) -> QueryExecutor:
    try:
        return _EXECUTORS[kind]
    except KeyError:
        raise KeyError(
            f"unknown query kind {kind!r}; registered: {sorted(_EXECUTORS)}"
        ) from None


def registered_kinds() -> list:
    return sorted(_EXECUTORS)
