"""Limit queries (paper §4.3): find K records matching a rare predicate by
walking records in descending proxy-score order, invoking the target DNN on
each until K matches are found.  Metric: target-DNN invocations (fig. 6).
TASTI recommends k=1 propagation with distance tie-breaks for these (§6.3).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Tuple

import numpy as np


@dataclass
class LimitResult:
    found_ids: np.ndarray
    n_invocations: int
    examined_ids: np.ndarray


def limit_query(proxy: np.ndarray,
                oracle: Callable[[np.ndarray], np.ndarray],
                k_results: int, batch: int = 16,
                max_invocations: int = 0) -> LimitResult:
    n = len(proxy)
    order = np.argsort(-proxy, kind="stable")
    max_inv = max_invocations or n
    found: list = []
    examined = 0
    for start in range(0, n, batch):
        ids = order[start:start + batch]
        labels = oracle(ids)
        examined += len(ids)
        found.extend(int(i) for i, l in zip(ids, labels) if l > 0.5)
        if len(found) >= k_results or examined >= max_inv:
            break
    return LimitResult(found_ids=np.asarray(found[:k_results], np.int64),
                       n_invocations=examined,
                       examined_ids=order[:examined])
