"""Limit queries (paper §4.3): find K records matching a rare predicate by
walking records in descending proxy-score order, invoking the target DNN on
each until K matches are found.  Metric: target-DNN invocations (fig. 6).
TASTI recommends k=1 propagation with distance tie-breaks for these (§6.3).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np


@dataclass
class LimitResult:
    found_ids: np.ndarray
    n_invocations: int
    examined_ids: np.ndarray


def scan_order(proxy: np.ndarray) -> np.ndarray:
    """Deterministic descending-proxy visit order of the limit scan."""
    return np.argsort(-proxy, kind="stable")


def limit_query(proxy: np.ndarray,
                oracle: Callable[[np.ndarray], np.ndarray],
                k_results: int, batch: int = 16,
                max_invocations: int = 0) -> LimitResult:
    n = len(proxy)
    order = scan_order(proxy)
    max_inv = max_invocations or n
    found: list = []
    examined = 0
    for start in range(0, n, batch):
        # batching is vectorization sugar; the scan is conceptually one record
        # at a time, so stop counting at the record that yields the Kth match
        ids = order[start:start + min(batch, max_inv - examined)]
        labels = oracle(ids)
        done_at = len(ids)
        for j, (i, lab) in enumerate(zip(ids, labels)):
            if lab > 0.5:
                found.append(int(i))
                if len(found) >= k_results:
                    done_at = j + 1
                    break
        examined += done_at
        if len(found) >= k_results or examined >= max_inv:
            break
    return LimitResult(found_ids=np.asarray(found[:k_results], np.int64),
                       n_invocations=examined,
                       examined_ids=order[:examined])


# ---------------------------------------------------------------------------
# Engine plug-in (repro.core.engine): declarative access to this algorithm.
# ---------------------------------------------------------------------------
from repro.core.queries.registry import (QueryExecutor,  # noqa: E402
                                         register_executor)


@register_executor
class LimitExecutor(QueryExecutor):
    """Proxy-ordered scan for K matches; top-1 propagation with distance
    tie-breaks, the paper's recommendation for limit queries (§6.3)."""

    kind = "limit"
    default_propagation = "top1"
    clip01 = False

    def validate(self, spec) -> None:
        if not spec.k_results or spec.k_results <= 0:
            raise ValueError("limit needs a positive `k_results`")

    def preview(self, plan, proxy) -> np.ndarray:
        # only the first batch is certain: the scan stops as soon as the Kth
        # match lands, so prefetching deeper would speculate with real labels
        s = plan.spec
        first = min(s.batch or 16, s.max_invocations or len(proxy))
        return scan_order(proxy)[:first]

    def execute(self, plan, proxy, oracle) -> LimitResult:
        s = plan.spec
        return limit_query(proxy, oracle, k_results=s.k_results,
                           batch=s.batch or 16,
                           max_invocations=s.max_invocations)

    def summarize(self, raw: LimitResult) -> dict:
        return {"selected": raw.found_ids,
                "n_invocations": raw.n_invocations}
