"""SUPG recall-target selection with importance sampling (Kang et al. 2020),
the algorithm TASTI's proxy scores feed for guaranteed selection (paper §4.3).

Given proxy scores p in [0,1], an oracle budget n, recall target gamma and
confidence delta: sample n records with probability proportional to sqrt(p)
(importance sampling), label them, and pick the *lowest* threshold tau whose
importance-weighted recall lower bound still meets gamma; return
{p >= tau} u {labeled positives}.  Metric: false positives in the returned
set at the fixed budget (paper fig. 5; lower is better).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np


@dataclass
class SUPGResult:
    selected: np.ndarray        # record ids
    threshold: float
    n_invocations: int
    sampled_ids: np.ndarray
    sampled_labels: np.ndarray


def importance_sample(proxy: np.ndarray, budget: int, seed: int = 0):
    """SUPG's sqrt-proxy importance sample: (sampled ids, p, q).

    Deterministic in (proxy, budget, seed) — sessions call this ahead of
    execution to prefetch exactly the ids the query will label."""
    n = len(proxy)
    rng = np.random.default_rng(seed)
    p = np.clip(proxy.astype(np.float64), 1e-6, 1.0)
    q = np.sqrt(p)
    q = q / q.sum()
    ids = rng.choice(n, size=min(budget, n), replace=True, p=q)
    return ids, p, q


def supg_recall_target(proxy: np.ndarray,
                       oracle: Callable[[np.ndarray], np.ndarray],
                       budget: int, recall_target: float = 0.9,
                       delta: float = 0.05, seed: int = 0) -> SUPGResult:
    n = len(proxy)
    budget = min(budget, n)
    ids, p, q = importance_sample(proxy, budget, seed)
    labels = oracle(ids).astype(np.float64)  # 1.0 if matches predicate
    w = 1.0 / (n * q[ids])                    # importance weights (mean-1 scale)

    # importance-weighted positive mass above each candidate threshold
    cand = np.unique(p[ids])[::-1]
    wpos = w * labels
    total_pos = wpos.sum()
    if total_pos <= 0:
        # no positives sampled: return everything above the tiniest proxy —
        # conservative (can't certify recall otherwise)
        tau = float(np.min(p))
    else:
        z = np.sqrt(2.0 * np.log(1.0 / delta))
        tau = float(np.min(p))
        # walk thresholds from high to low until recall LB >= target
        for t in cand:
            above = p[ids] >= t
            mass_above = float(wpos[above].sum())
            # delta-method std of the recall ratio estimate
            m_var = wpos[above].var() * above.sum() if above.any() else 0.0
            t_var = wpos.var() * len(wpos)
            se = np.sqrt((m_var + t_var)) / max(total_pos * np.sqrt(budget), 1e-9)
            recall_lb = mass_above / total_pos - z * se
            if recall_lb >= recall_target:
                tau = float(t)
                break
    selected = np.where(p >= tau)[0]
    pos_sampled = np.unique(ids[labels > 0.5])
    selected = np.union1d(selected, pos_sampled)
    return SUPGResult(selected=selected, threshold=tau, n_invocations=budget,
                      sampled_ids=ids, sampled_labels=labels)


def false_positive_rate(selected: np.ndarray, truth: np.ndarray) -> float:
    """truth: boolean (N,).  FPR = FP / selected (the paper reports FP rate of
    the returned set at fixed budget)."""
    if len(selected) == 0:
        return 0.0
    fp = float((~truth[selected]).sum())
    return fp / len(selected)


def achieved_recall(selected: np.ndarray, truth: np.ndarray) -> float:
    total = float(truth.sum())
    if total == 0:
        return 1.0
    return float(truth[selected].sum()) / total


# ---------------------------------------------------------------------------
# Engine plug-in (repro.core.engine): declarative access to this algorithm.
# ---------------------------------------------------------------------------
from repro.core.queries.registry import (QueryExecutor,  # noqa: E402
                                         register_executor)


@register_executor
class SelectionExecutor(QueryExecutor):
    """SUPG recall-target selection; probability-shaped proxy in [0,1]."""

    kind = "selection"
    default_propagation = "numeric"
    clip01 = True

    def validate(self, spec) -> None:
        if not spec.budget or spec.budget <= 0:
            raise ValueError("selection needs a positive oracle `budget`")
        if not (0.0 < spec.recall_target <= 1.0):
            raise ValueError("recall_target must be in (0, 1]")

    def preview(self, plan, proxy) -> np.ndarray:
        s = plan.spec
        ids, _, _ = importance_sample(proxy, s.budget, s.seed)
        return np.unique(ids)

    def execute(self, plan, proxy, oracle) -> SUPGResult:
        s = plan.spec
        return supg_recall_target(proxy, oracle, budget=s.budget,
                                  recall_target=s.recall_target,
                                  delta=s.delta, seed=s.seed)

    def summarize(self, raw: SUPGResult) -> dict:
        return {"selected": raw.selected, "threshold": raw.threshold,
                "n_invocations": raw.n_invocations}
