"""Approximate aggregation with control variates + empirical-Bernstein (EB)
adaptive stopping — the BlazeIt query processing TASTI plugs into (paper §4.3).

The estimator for E[f] uses the proxy scores p as a control variate:
    E[f] = mean_all(p) + E[f - c*p] + (c-1)*...   (c = cov/var, online)
EB stopping is adaptive in the *residual* variance, so better proxy scores
(higher rho^2) => fewer target-DNN invocations — exactly the paper's fig. 4
mechanism.  Metric: number of target-DNN invocations at a given error bound.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np


@dataclass
class AggResult:
    estimate: float
    n_invocations: int
    ci_half_width: float
    sampled_ids: np.ndarray
    sampled_f: np.ndarray


def eb_half_width(var: float, rng_width: float, n: int, delta: float) -> float:
    """Empirical-Bernstein confidence half-width (Maurer & Pontil / BlazeIt)."""
    log_term = np.log(3.0 / delta)
    return float(np.sqrt(2.0 * var * log_term / n)
                 + 3.0 * rng_width * log_term / n)


def sample_order(n: int, seed: int,
                 shared: Optional[np.ndarray] = None) -> np.ndarray:
    """The id order an aggregation walks: the session-shared sample order
    when given (so specs over the same score draw nested samples), else a
    seeded uniform permutation."""
    if shared is not None:
        order = np.asarray(shared, np.int64)
        if len(order) != n:
            raise ValueError(f"shared sample order covers {len(order)} "
                             f"records, proxy has {n}")
        return order
    return np.random.default_rng(seed).permutation(n)


def first_sample_size(n: int, min_samples: int,
                      max_samples: Optional[int]) -> int:
    """Size of the first (deterministic) oracle batch of the EB loop."""
    return min(min_samples, max_samples or n, n)


def aggregate_control_variates(proxy: np.ndarray,
                               oracle: Callable[[np.ndarray], np.ndarray],
                               err: float, delta: float = 0.05,
                               batch: int = 32, min_samples: int = 64,
                               max_samples: Optional[int] = None,
                               seed: int = 0,
                               use_cv: bool = True,
                               order: Optional[np.ndarray] = None) -> AggResult:
    """Sample until the EB CI half-width <= err (absolute).

    ``oracle(ids) -> f values`` counts as target-DNN invocations.
    ``use_cv=False`` gives the plain random-sampling baseline.
    ``order`` overrides the sampling order (sessions pass a shared
    stratified order so sibling specs' samples nest).
    """
    n = len(proxy)
    order = sample_order(n, seed, shared=order)
    max_samples = max_samples or n
    p_mean = float(proxy.mean())

    taken = 0
    fs: list = []
    ps: list = []
    while taken < max_samples:
        m = min(batch if taken else min_samples, max_samples - taken)
        ids = order[taken:taken + m]
        fs.extend(oracle(ids).tolist())
        ps.extend(proxy[ids].tolist())
        taken += m
        f_arr = np.asarray(fs)
        p_arr = np.asarray(ps)
        if use_cv and len(f_arr) >= 8:
            var_p = p_arr.var() + 1e-12
            c = float(np.cov(f_arr, p_arr)[0, 1] / var_p)
            resid = f_arr - c * p_arr
            est = float(resid.mean() + c * p_mean)
            v = float(resid.var())
            width = float(resid.max() - resid.min()) + 1e-12
        else:
            est = float(f_arr.mean())
            v = float(f_arr.var())
            width = float(f_arr.max() - f_arr.min()) + 1e-12
        hw = eb_half_width(v, width, taken, delta)
        if taken >= min_samples and hw <= err:
            break
    return AggResult(estimate=est, n_invocations=taken, ci_half_width=hw,
                     sampled_ids=order[:taken], sampled_f=np.asarray(fs))


def aggregate_direct(proxy: np.ndarray) -> float:
    """No-guarantee aggregation: the statistic straight off the proxy scores
    (paper §6.5, Table 1)."""
    return float(proxy.mean())


# ---------------------------------------------------------------------------
# Engine plug-in (repro.core.engine): declarative access to this algorithm.
# ---------------------------------------------------------------------------
from repro.core.queries.registry import (QueryExecutor,  # noqa: E402
                                         register_executor)


@register_executor
class AggregationExecutor(QueryExecutor):
    """EB-stopped control-variate aggregation; numeric propagation (§4.2)."""

    kind = "aggregation"
    default_propagation = "numeric"
    clip01 = False

    def validate(self, spec) -> None:
        if spec.err <= 0:
            raise ValueError("aggregation needs a positive error bound `err`")

    def preview(self, plan, proxy) -> np.ndarray:
        s = plan.spec
        order = sample_order(len(proxy), s.seed, shared=plan.shared_order)
        return order[:first_sample_size(len(proxy), s.min_samples,
                                        s.max_samples)]

    def execute(self, plan, proxy, oracle) -> AggResult:
        s = plan.spec
        return aggregate_control_variates(
            proxy, oracle, err=s.err, delta=s.delta, batch=s.batch or 32,
            min_samples=s.min_samples, max_samples=s.max_samples,
            seed=s.seed, use_cv=s.use_cv, order=plan.shared_order)

    def summarize(self, raw: AggResult) -> dict:
        return {"estimate": raw.estimate, "ci_half_width": raw.ci_half_width,
                "n_invocations": raw.n_invocations}
