"""Score propagation (paper §4.2): representative scores -> all records.

Numeric scores: distance-weighted mean of the k nearest representatives.
Categorical: distance-weighted majority vote.  Distances are cached in the
index, so propagation is O(N*k) arithmetic — the paper's key query-time win.
"""
from __future__ import annotations

import numpy as np


def propagate_numeric(rep_scores: np.ndarray, topk_ids: np.ndarray,
                      topk_d2: np.ndarray, eps: float = 1e-6) -> np.ndarray:
    """rep_scores (C,), topk_ids/(d2) (N,k) -> (N,) weighted-mean scores."""
    w = 1.0 / (np.sqrt(np.maximum(topk_d2, 0.0)) + eps)  # (N,k)
    s = rep_scores[topk_ids]                              # (N,k)
    return (w * s).sum(1) / w.sum(1)


def propagate_categorical(rep_scores: np.ndarray, topk_ids: np.ndarray,
                          topk_d2: np.ndarray, n_classes: int,
                          eps: float = 1e-6) -> np.ndarray:
    """Distance-weighted vote -> (N,) class ids."""
    w = 1.0 / (np.sqrt(np.maximum(topk_d2, 0.0)) + eps)
    cls = rep_scores[topk_ids].astype(np.int64)           # (N,k)
    n = len(topk_ids)
    # one scatter-add over the flattened (record, class) grid
    flat = np.arange(n, dtype=np.int64)[:, None] * n_classes + cls
    votes = np.bincount(flat.ravel(), weights=w.ravel(),
                        minlength=n * n_classes).reshape(n, n_classes)
    return votes.argmax(1)


def propagate_top1(rep_scores: np.ndarray, topk_ids: np.ndarray,
                   topk_d2: np.ndarray) -> np.ndarray:
    """k=1 propagation with distance tie-break ordering — the paper's limit-
    query scoring (§6.3): score of the nearest rep, ranked by (score, -dist)."""
    base = rep_scores[topk_ids[:, 0]]
    d = np.sqrt(np.maximum(topk_d2[:, 0], 0.0))
    # strictly monotone in score; distance only breaks ties within a score
    return base - 1e-6 * d / (1.0 + d.max())
