"""Score propagation (paper §4.2): representative scores -> all records.

Numeric scores: distance-weighted mean of the k nearest representatives.
Categorical: distance-weighted majority vote.  Distances are cached in the
index, so propagation is O(N*k) arithmetic — the paper's key query-time win.

This module is the host (numpy, float64) reference path.  The device-resident
serving hot path (:mod:`repro.kernels.propagate` via
:class:`repro.core.resident.ResidentIndexState`) must match it within float32
tolerance; its parity suite runs in tier-1 CI.

Top-k columns whose squared distance is at or above
:data:`~repro.kernels.distance_topk.ops.PAD_DIST` are padding (an index with
fewer reps than k) and carry zero weight — tiling the worst real entry
instead would silently double-weight that rep.
"""
from __future__ import annotations

import numpy as np

from repro.kernels.distance_topk.ops import PAD_DIST


def _weights(topk_d2: np.ndarray, eps: float) -> np.ndarray:
    """Inverse-distance weights with padded columns masked to zero."""
    w = 1.0 / (np.sqrt(np.maximum(topk_d2, 0.0)) + eps)  # (N,k)
    return np.where(topk_d2 >= PAD_DIST, 0.0, w)


def propagate_numeric(rep_scores: np.ndarray, topk_ids: np.ndarray,
                      topk_d2: np.ndarray, eps: float = 1e-6) -> np.ndarray:
    """rep_scores (C,), topk_ids/(d2) (N,k) -> (N,) weighted-mean scores."""
    w = _weights(topk_d2, eps)                            # (N,k)
    s = rep_scores[topk_ids]                              # (N,k)
    return (w * s).sum(1) / w.sum(1)


def propagate_categorical(rep_scores: np.ndarray, topk_ids: np.ndarray,
                          topk_d2: np.ndarray, n_classes: int,
                          eps: float = 1e-6) -> np.ndarray:
    """Distance-weighted vote -> (N,) class ids."""
    w = _weights(topk_d2, eps)
    cls = rep_scores[topk_ids].astype(np.int64)           # (N,k)
    n = len(topk_ids)
    # one scatter-add over the flattened (record, class) grid
    flat = np.arange(n, dtype=np.int64)[:, None] * n_classes + cls
    votes = np.bincount(flat.ravel(), weights=w.ravel(),
                        minlength=n * n_classes).reshape(n, n_classes)
    return votes.argmax(1)


def top1_tie_break_eps(rep_scores: np.ndarray) -> float:
    """Perturbation scale for :func:`propagate_top1`: strictly below the
    smallest nonzero gap between distinct rep scores, so the distance
    nudge can only ever reorder records whose nearest reps score *equal* —
    never flip two distinct score levels (gaps under 1e-6 are common for
    probability-valued scores).  Capped at 1e-6 so well-conditioned scores
    keep the historical output bit-for-bit."""
    levels = np.unique(rep_scores[np.isfinite(rep_scores)])
    gaps = np.diff(levels)
    min_gap = float(gaps.min()) if len(gaps) else np.inf
    return float(min(1e-6, 0.5 * min_gap))


def propagate_top1(rep_scores: np.ndarray, topk_ids: np.ndarray,
                   topk_d2: np.ndarray) -> np.ndarray:
    """k=1 propagation with distance tie-break ordering — the paper's limit-
    query scoring (§6.3): score of the nearest rep, ranked by (score, -dist).
    """
    base = rep_scores[topk_ids[:, 0]].astype(np.float64)
    if len(base) == 0:          # empty index: nothing to rank (and no d.max())
        return base
    d = np.sqrt(np.maximum(topk_d2[:, 0], 0.0))
    # strictly monotone in score: the normalized-distance nudge is scaled
    # strictly below the smallest score gap, so distance only breaks ties
    # within one score level
    return base - top1_tie_break_eps(rep_scores) * d / (1.0 + d.max())
