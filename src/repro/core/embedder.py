"""Embedding DNN: record features -> semantic embeddings.

Two backbones:
* ``mlp`` (default for the paper-scale reproduction; stands in for the
  ResNet-18 / BERT embedders — the paper's point is that the embedder is
  orders of magnitude cheaper than the target DNN, not its architecture), and
* any registered transformer config (``backbone="tasti-embedder"`` or one of
  the 10 assigned archs) for the TPU-scale path: features are projected to
  d_model, run through the backbone blocks bidirectionally, mean-pooled, and
  projected to the embedding size (128, paper default).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ParamSpec, PyTree, init_params


@dataclass(frozen=True)
class EmbedderConfig:
    feature_dim: int = 64
    embed_dim: int = 128          # paper default embedding size
    hidden: int = 256
    n_layers: int = 3
    backbone: str = "mlp"         # "mlp" | config name from repro.configs
    seq_tokens: int = 8           # transformer path: reshape features to tokens
    normalize: bool = False


def embedder_specs(cfg: EmbedderConfig) -> PyTree:
    if cfg.backbone == "mlp":
        dims = [cfg.feature_dim] + [cfg.hidden] * (cfg.n_layers - 1) + [cfg.embed_dim]
        return {f"w{i}": ParamSpec((dims[i], dims[i + 1]), ("embed", "mlp"),
                                   jnp.float32)
                for i in range(len(dims) - 1)} | {
            f"b{i}": ParamSpec((dims[i + 1],), (None,), jnp.float32, init="zeros")
            for i in range(len(dims) - 1)}
    from repro.configs import get_config
    from repro.models import blocks as blocks_lib
    from repro.models.common import stack_specs
    bb = get_config(cfg.backbone)
    assert cfg.feature_dim % cfg.seq_tokens == 0
    tok_dim = cfg.feature_dim // cfg.seq_tokens
    return {
        "proj_in": ParamSpec((tok_dim, bb.d_model), ("embed", "mlp"), jnp.float32),
        "blocks": tuple(stack_specs(t, bb.n_repeats)
                        for t in blocks_lib.block_specs(bb)),
        "proj_out": ParamSpec((bb.d_model, cfg.embed_dim), ("embed", "mlp"),
                              jnp.float32),
    }


def init_embedder(cfg: EmbedderConfig, key: jax.Array) -> PyTree:
    return init_params(embedder_specs(cfg), key)


def embed(params: PyTree, x: jax.Array, cfg: EmbedderConfig) -> jax.Array:
    """x (N, feature_dim) -> (N, embed_dim)."""
    if cfg.backbone == "mlp":
        h = x
        n = sum(1 for k in params if k.startswith("w"))
        for i in range(n):
            h = jnp.dot(h, params[f"w{i}"]) + params[f"b{i}"]
            if i < n - 1:
                h = jax.nn.gelu(h)
    else:
        from repro.configs import get_config
        from repro.models import blocks as blocks_lib
        bb = get_config(cfg.backbone)
        tok = x.reshape(x.shape[0], cfg.seq_tokens, -1)
        h = jnp.dot(tok, params["proj_in"])

        def body(carry, bp):
            out, _ = blocks_lib.block_fwd(bp, carry, bb, angles=None,
                                          causal=False)
            return out, ()

        h, _ = jax.lax.scan(body, h, params["blocks"])
        h = jnp.dot(jnp.mean(h, axis=1), params["proj_out"])
    if cfg.normalize:
        h = h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6)
    return h


def embed_all(params: PyTree, features: np.ndarray, cfg: EmbedderConfig,
              batch: int = 4096) -> np.ndarray:
    """Batched host loop (the N*c_E term of the paper's cost model)."""
    fn = jax.jit(lambda p, x: embed(p, x, cfg))
    outs = []
    for i in range(0, len(features), batch):
        outs.append(np.asarray(fn(params, jnp.asarray(features[i:i + batch]))))
    return np.concatenate(outs, axis=0)
