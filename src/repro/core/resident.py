"""Device-resident index state for the serving hot path.

Proxy-score materialization is O(N*k) arithmetic over index structures that
only change on a crack, so off-host execution is bandwidth-bound on the rep
structures — re-shipping ``topk_ids``/``topk_d2`` (and the embeddings) to the
accelerator per query would cost more than the propagation itself.  A
:class:`ResidentIndexState`, owned by :class:`repro.core.engine.QueryEngine`,
uploads them once and replays the fused propagate kernel
(:func:`repro.kernels.propagate.ops.propagate`) against the cached device
buffers; only the small (C,) rep-score vector moves per call.

Staleness is handled with the index's existing ``version`` counter: every
upload is stamped with the version it saw, every :meth:`propagate` call
carries the version the caller's rep scores were computed against, and any
mismatch (a crack landed in between) returns ``None`` so the engine falls
back to the host path for that attempt and retries against the new index.

Enablement: automatic on accelerators (TPU/GPU), off on CPU — the CPU
serving path keeps the float64 numpy propagation byte-identical to previous
releases.  Override with ``REPRO_RESIDENT_SCORING=1`` (force on; uses the
XLA reference off-TPU) or ``=0`` (force off).
"""
from __future__ import annotations

import os
import threading
import warnings
from typing import Optional

import numpy as np

_TRUTHY = ("1", "true", "on", "force", "yes")
_FALSY = ("0", "false", "off", "no")

ENV_VAR = "REPRO_RESIDENT_SCORING"


def _default_enabled() -> bool:
    env = os.environ.get(ENV_VAR, "").strip().lower()
    if env in _TRUTHY:
        return True
    if env in _FALSY:
        return False
    import jax
    return jax.devices()[0].platform in ("tpu", "gpu")


class ResidentIndexState:
    """Keeps one index's embeddings + top-k rep structures on device.

    Thread-safe; all device handles are guarded by an internal lock, but the
    fused propagate call itself runs outside it (device arrays are
    immutable), so propagations over different score functions overlap.
    """

    def __init__(self, index, enabled: Optional[bool] = None,
                 block_n: int = 256, obs=None):
        self.index = index
        self.enabled = _default_enabled() if enabled is None else bool(enabled)
        self.block_n = int(block_n)
        self._lock = threading.Lock()
        self._version: Optional[int] = None   # version of uploaded structures
        self._topk_ids = None                 # device (N,k) int32
        self._topk_d2 = None                  # device (N,k) float32
        self._embeddings = None               # device (N,d); crack-immutable
        self.stats = {
            "uploads": 0,        # rep-structure uploads (initial + re-upload)
            "invalidations": 0,  # crack listeners dropping device state
            "fallbacks": 0,      # propagate() calls answered by the host path
        }
        self.set_obs(obs)

    def set_obs(self, obs) -> None:
        """Attach an :class:`~repro.obs.ObsScope` (counters here stay in
        ``self.stats`` and are exported at scrape time; nothing to resolve
        eagerly — kept for interface symmetry with broker/pool)."""
        self._obs = obs

    # ------------------------------------------------------------------
    def invalidate(self) -> None:
        """Drop the uploaded rep structures (crack listener).  Correctness
        never depends on this — :meth:`propagate` version-checks every call —
        but dropping eagerly frees device memory for the re-upload."""
        with self._lock:
            if self._version is not None or self._topk_ids is not None:
                self.stats["invalidations"] += 1
            self._version = None
            self._topk_ids = None
            self._topk_d2 = None

    def embeddings_device(self):
        """The (N, d) embedding matrix on device (uploaded once; embeddings
        never change across cracks).  ``None`` when disabled."""
        if not self.enabled:
            return None
        import jax.numpy as jnp
        with self._lock:
            if self._embeddings is None:
                self._embeddings = jnp.asarray(self.index.embeddings)
            return self._embeddings

    def _structures(self, version: int):
        """Device (topk_ids, topk_d2) for ``version``, uploading if stale.
        Must be called with the index at that version (caller checks)."""
        with self._lock:
            if self._version != version:
                import jax.numpy as jnp
                self._topk_ids = jnp.asarray(
                    np.asarray(self.index.topk_ids, np.int32))
                self._topk_d2 = jnp.asarray(
                    np.asarray(self.index.topk_d2, np.float32))
                self._version = version
                self.stats["uploads"] += 1
            return self._topk_ids, self._topk_d2

    # ------------------------------------------------------------------
    def propagate(self, rep_scores: np.ndarray, mode: str, *, version: int,
                  n_classes: Optional[int] = None,
                  clip01: bool = False) -> Optional[np.ndarray]:
        """Fused device propagation of ``rep_scores`` (computed against index
        ``version``) -> (N,) float64, or ``None`` to signal host fallback
        (disabled, version raced with a crack, or a device failure — the
        last also disables the resident path for the rest of the process).
        """
        if not self.enabled:
            self.stats["fallbacks"] += 1
            return None
        if self.index.version != version:
            self.stats["fallbacks"] += 1
            return None          # crack landed since the caller snapshotted
        try:
            import jax.numpy as jnp
            from repro.kernels.propagate.ops import propagate as _propagate
            ids, d2 = self._structures(version)
            out = _propagate(jnp.asarray(rep_scores, jnp.float32), ids, d2,
                             mode, n_classes=n_classes, clip01=clip01,
                             block_n=self.block_n)
            return np.asarray(out, np.float64)
        except Exception as e:                      # pragma: no cover - defensive
            self.enabled = False
            self.stats["fallbacks"] += 1
            self.invalidate()
            warnings.warn("device-resident proxy scoring failed "
                          f"({type(e).__name__}: {e}); falling back to the "
                          "host propagation path", RuntimeWarning)
            return None
