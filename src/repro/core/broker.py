"""Oracle broker: batched, deduplicating access to the target DNN.

TASTI's cost metric is target-DNN invocations (paper §5-6), so the system
layer between executors and the workload should never label a record twice
and should hand the (expensive, batch-friendly) target DNN work in
well-shaped microbatches.  :class:`OracleBroker` owns exactly that seam:

* **microbatching** — label requests accumulate in an ordered pending queue
  and are flushed to ``target_dnn_batch`` in chunks of ``max_batch``
  (flush-on-demand: a blocking read drains the queue);
* **dedup** — ids already cached, or already in flight for another consumer,
  are never re-labeled; the second requester rides along for free;
* **two consumption styles** — a blocking :meth:`fetch` for executors that
  need labels now, and a futures-style :meth:`request`/:class:`LabelFuture`
  pair plus :meth:`prefetch` so several query specs can enqueue their samples
  first and amortize one combined flush (how
  :class:`~repro.core.session.QuerySession` shares batches across specs);
* **per-consumer accounting** — each :class:`OracleAccount` (one per query
  spec) tracks exactly the fresh labels it caused and the cache hits it was
  served, so per-spec invocation counts stay honest under cross-spec dedup:
  a record labeled for spec A is *fresh* for A and *cached* for B;
* **thread safety via reservation** — one reentrant lock protects the
  pending queue, cache, stats, and account registry, so concurrent
  :class:`~repro.core.session.QuerySession` s (the serving layer's worker
  pool) share one broker.  The lock is *not* held across
  ``target_dnn_batch``: a flush **reserves** its pending ids (marks them
  in-flight under the lock), labels them outside it, and **publishes** the
  results under the lock again.  In-flight dedup stays exact — a request for
  a reserved id rides along without re-labeling, and a demand-read blocks on
  the broker's condition until the reservation publishes.  On failure the
  reservation is rolled back into the pending queue with no counts charged;
* **sharded labeling** — with an :class:`~repro.core.oracle_pool.OraclePool`
  attached, each flush's microbatches are dispatched to N target-DNN replica
  workers concurrently (work sharing, per-sub-batch retry, thread *or*
  forked-process replicas — the backend is invisible here) and the results
  are published in pending order, so labels, accounting, and the write-
  through stream are byte-identical to the single-oracle path on either
  backend.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.oracle_pool import OraclePool, OraclePoolClosed
from repro.obs import NULL_SCOPE, SIZE_BUCKETS
from repro.obs.trace import span, start_span


@dataclass
class OracleAccount:
    """Per-consumer (per query spec) oracle accounting.

    ``fresh`` counts records the target DNN labeled *because of this
    consumer*; ``cached`` counts requests served from the shared cache (or
    from another consumer's in-flight batch).  ``labeled`` lists the fresh
    ids in labeling order — the cracking feedback loop folds exactly these
    back into the index.
    """
    name: str = ""
    fresh: int = 0
    cached: int = 0
    labeled: List[int] = field(default_factory=list)
    # ids this account pre-paid via prefetch(); the first demand-read of each
    # is free (the fresh charge already happened at flush time)
    _credit: Set[int] = field(default_factory=set)


class LabelFuture:
    """Handle to labels that may not have been computed yet.

    ``result()`` drains the broker's pending queue if needed (flush-on-
    demand) and returns the annotations in request order.
    """

    def __init__(self, broker: "OracleBroker", ids: np.ndarray):
        self._broker = broker
        self._ids = [int(i) for i in ids]

    def done(self) -> bool:
        with self._broker._lock:
            return all(i in self._broker.cache for i in self._ids)

    def result(self) -> List[Any]:
        b = self._broker
        while True:
            with b._cond:
                if all(i in b.cache for i in self._ids):
                    return [b.cache[i] for i in self._ids]
                if not any(i in b._pending for i in self._ids):
                    # everything still missing is reserved by another
                    # thread's in-flight flush: wait for its publish
                    # (timeout is lost-wakeup insurance; the loop re-checks)
                    b._cond.wait(timeout=0.25)
                    continue
            b.flush()  # outside the lock: flush reserves/labels/publishes


class OracleBroker:
    """Batches, dedups, and accounts for target-DNN label requests.

    ``annotate(ids) -> list`` is the raw oracle (``workload.
    target_dnn_batch``); every call to it goes through :meth:`flush` in
    chunks of at most ``max_batch`` ids.  With ``pool`` set (an
    :class:`~repro.core.oracle_pool.OraclePool`), flushes are sharded across
    the pool's replica workers instead of calling ``annotate`` inline;
    ``pool`` may be swapped at any time between flushes (the engine's
    ``oracle_replicas`` knob does exactly that).
    """

    def __init__(self, annotate: Callable[[np.ndarray], Sequence[Any]],
                 max_batch: int = 64,
                 cache: Optional[Dict[int, Any]] = None,
                 pool: Optional[OraclePool] = None,
                 obs=None):
        if max_batch <= 0:
            raise ValueError(f"max_batch must be positive, got {max_batch}")
        self.annotate = annotate
        self.max_batch = int(max_batch)
        self.pool = pool
        self.set_obs(obs)
        self.cache: Dict[int, Any] = {} if cache is None else cache
        # tier-aware caches (the LabelStore's view) expose record_hit so
        # cache-hit charges can be attributed to the tier that answered
        self._record_hit = getattr(self.cache, "record_hit", None)
        self._pending: Dict[int, Optional[OracleAccount]] = {}  # id -> owner
        # ids reserved by an in-flight flush (labeled outside the lock);
        # requests for them ride along, demand-reads wait on _cond
        self._inflight: Dict[int, Optional[OracleAccount]] = {}
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        # bounded: a long-lived server issues one account per served spec,
        # so retaining them all would grow without bound; global totals live
        # in self.stats, this ring only feeds the /stats "recent" view
        self._accounts: "deque[OracleAccount]" = deque(maxlen=256)
        self._on_fresh: List[Callable[[Dict[int, Any]], None]] = []
        self.stats: Dict[str, int] = {
            "requests": 0,        # ids seen by request()/fetch()
            "fresh": 0,           # records actually labeled
            "cached": 0,          # requests served without labeling
            "dedup_inflight": 0,  # requests that rode an in-flight id
            "batches": 0,         # target_dnn_batch calls issued
            "flushes": 0,         # flush() calls that did work
            "max_pending": 0,     # high-water mark of the pending queue
            "prefetched": 0,      # ids enqueued via prefetch()
        }

    def set_obs(self, obs) -> None:
        """Attach an :class:`~repro.obs.ObsScope` (the server wires one per
        workload).  Resolves the flush histograms once so the hot path
        never touches the registry; counters stay derived at scrape time
        from :meth:`observe`."""
        self._obs = obs if obs is not None else NULL_SCOPE
        self._h_flush_latency = self._obs.histogram(
            "oracle_flush_latency_seconds",
            "wall time of one broker flush (label + publish)")
        self._h_flush_size = self._obs.histogram(
            "oracle_flush_size_labels",
            "fresh labels reserved per flush", buckets=SIZE_BUCKETS)

    def account(self, name: str = "") -> OracleAccount:
        acct = OracleAccount(name=name)
        with self._lock:
            self._accounts.append(acct)
        return acct

    def account_stats(self) -> List[Dict[str, Any]]:
        """Per-account fresh/cached counters for the most recently issued
        accounts (bounded ring; the serving layer's ``/stats`` payload —
        all-time totals are ``stats["fresh"]``/``stats["cached"]``)."""
        with self._lock:
            return [{"name": a.name, "fresh": a.fresh, "cached": a.cached}
                    for a in self._accounts]

    def snapshot(self) -> Dict[str, int]:
        """A consistent copy of ``stats`` (plus cache/pending sizes)."""
        with self._lock:
            return {**self.stats, "cache_size": len(self.cache),
                    "n_pending": len(self._pending),
                    "n_inflight": len(self._inflight)}

    def observe(self, recent_accounts: int = 32) -> Dict[str, Any]:
        """Stats *and* the recent per-account counters under ONE lock
        acquisition, so a scrape racing a flush can't pair totals and
        account rows from different instants (the publish phase bumps
        both atomically).  This is what ``/stats`` and the ``/metrics``
        collector read."""
        with self._lock:
            accounts = list(self._accounts)
            if recent_accounts and len(accounts) > recent_accounts:
                accounts = accounts[-recent_accounts:]
            return {
                "stats": {**self.stats, "cache_size": len(self.cache),
                          "n_pending": len(self._pending),
                          "n_inflight": len(self._inflight)},
                "accounts": [{"name": a.name, "fresh": a.fresh,
                              "cached": a.cached} for a in accounts],
            }

    # -- persistence hooks ---------------------------------------------------
    def seed(self, labels: Dict[int, Any]) -> int:
        """Preload the cache (e.g. from a persistent
        :class:`~repro.serve.store.LabelStore`).  Already-cached ids keep
        their current label.  Returns the number of labels added."""
        added = 0
        with self._lock:
            for i, a in labels.items():
                i = int(i)
                if i not in self.cache:
                    self.cache[i] = a
                    added += 1
        return added

    def adopt_cache(self, cache) -> int:
        """Swap in a replacement label cache (typically a
        :class:`~repro.serve.store.LabelStore`'s tiered view).  Anything in
        the current cache that the replacement does not already hold is
        carried over, so labels paid for before the swap stay paid for.
        Returns the number of labels the new cache serves."""
        with self._lock:
            old = self.cache
            if old is not None and len(old) > 0 and old is not cache:
                cache.update(old)
            self.cache = cache
            self._record_hit = getattr(cache, "record_hit", None)
            return len(cache)

    def on_fresh(self, callback: Callable[[Dict[int, Any]], None]) -> None:
        """Register a write-through listener: called with ``{id: annotation}``
        after every batch of fresh labels (flush or cache-bypassing fetch),
        while the broker lock is held — keep callbacks quick."""
        with self._lock:
            self._on_fresh.append(callback)

    def _notify_fresh(self, labeled: Dict[int, Any]) -> None:
        if labeled:
            for cb in self._on_fresh:
                cb(labeled)

    # -- enqueue -------------------------------------------------------------
    def request(self, ids, account: Optional[OracleAccount] = None
                ) -> LabelFuture:
        """Enqueue ``ids`` (dedup against cache and in-flight) and return a
        future.  Charges ``account.cached`` for every id somebody else
        already paid for; fresh charges land at flush time on the consumer
        that caused the labeling."""
        ids = np.asarray(ids, np.int64).ravel()
        with self._lock:
            self.stats["requests"] += len(ids)
            for raw in ids:
                i = int(raw)
                if i in self.cache:
                    if account is not None and i in account._credit:
                        account._credit.discard(i)  # pre-paid by prefetch
                    else:
                        self.stats["cached"] += 1
                        if self._record_hit is not None:
                            self._record_hit(i)  # tier attribution
                        if account is not None:
                            account.cached += 1
                elif i in self._pending or i in self._inflight:
                    if account is not None and i in account._credit:
                        # own unflushed (or mid-flush) prefetch: this demand-
                        # read consumes the credit; the fresh charge lands at
                        # flush publish
                        account._credit.discard(i)
                    else:
                        self.stats["cached"] += 1
                        self.stats["dedup_inflight"] += 1
                        if account is not None:
                            account.cached += 1
                else:
                    self._pending[i] = account
            self.stats["max_pending"] = max(self.stats["max_pending"],
                                            len(self._pending))
        return LabelFuture(self, ids)

    def prefetch(self, ids, account: Optional[OracleAccount] = None) -> int:
        """Enqueue ``ids`` without charging anything yet.  Ids already cached
        or in flight are skipped (cross-spec dedup); newly enqueued ids are
        credited to ``account`` so its later demand-read is free.  Returns
        the number of ids actually enqueued."""
        ids = np.asarray(ids, np.int64).ravel()
        enqueued = 0
        with self._lock:
            for raw in ids:
                i = int(raw)
                if i in self.cache or i in self._pending \
                        or i in self._inflight:
                    continue
                self._pending[i] = account
                if account is not None:
                    account._credit.add(i)
                enqueued += 1
            self.stats["prefetched"] += enqueued
            self.stats["max_pending"] = max(self.stats["max_pending"],
                                            len(self._pending))
        return enqueued

    # -- consume -------------------------------------------------------------
    def fetch(self, ids, account: Optional[OracleAccount] = None,
              reuse: bool = True) -> List[Any]:
        """Blocking read: labels for ``ids`` in order.

        ``reuse=False`` bypasses the cache *reads* entirely — every id is
        re-labeled and charged fresh (method-vs-method benchmarks count every
        invocation) — but results still land in the cache for later
        consumers.
        """
        ids = np.asarray(ids, np.int64).ravel()
        if reuse:
            return self.request(ids, account=account).result()
        with self._lock:
            self.stats["requests"] += len(ids)
        # cache-bypassing reads label OUTSIDE the lock too (same reservation
        # discipline as flush, minus the dedup: every id is re-labeled)
        sp = start_span("broker.fetch_nocache", n=len(ids))
        try:
            labeled, batches = self._label(ids)
        except BaseException as e:
            sp.set(error=f"{type(e).__name__}: {e}").end()
            raise
        sp.set(fresh=len(ids), batches=batches).end()
        with self._lock:
            self.cache.update(labeled)
            self.stats["batches"] += batches
            self.stats["fresh"] += len(ids)
            if account is not None:
                account.fresh += len(ids)
                account.labeled.extend(int(i) for i in ids)
            if len(ids):
                self.stats["flushes"] += 1
            self._notify_fresh(labeled)
            self._cond.notify_all()
            return [self.cache[int(i)] for i in ids]

    def _label(self, ids: np.ndarray) -> Tuple[Dict[int, Any], int]:
        """Label ``ids`` — sharded across the replica pool when one is
        attached, inline microbatches otherwise.  Called WITHOUT the broker
        lock; returns ``({id: annotation}, n_batches)``."""
        pool = self.pool
        if pool is not None and len(ids):
            try:
                return pool.run(ids, self.max_batch)
            except OraclePoolClosed:
                # the pool was closed under us (a concurrent replica-count
                # resize): retry once with the current pool, else inline
                current = self.pool
                if current is not None and current is not pool:
                    return current.run(ids, self.max_batch)
        labeled: Dict[int, Any] = {}
        batches = 0
        for start in range(0, len(ids), self.max_batch):
            chunk = ids[start:start + self.max_batch]
            anns = self.annotate(chunk)
            batches += 1
            for i, a in zip(chunk, anns):
                labeled[int(i)] = a
        return labeled, batches

    # -- drain ---------------------------------------------------------------
    @property
    def n_pending(self) -> int:
        with self._lock:
            return len(self._pending)

    def flush(self, limit: Optional[int] = None) -> int:
        """Label everything pending, in microbatches of ``max_batch``.
        Fresh charges land on the account that enqueued each id.  Returns
        the number of records labeled.

        ``limit`` reserves only the first ``limit`` pending ids (insertion
        order) instead of draining the queue — the scheduler's preemption
        slices flush a long prefetch in bounded steps so a higher-priority
        session can run between them.  Charging is per id, so a limited
        flush sequence is byte-identical in accounting to one full drain.

        Three phases (the reservation scheme): **reserve** — pending ids move
        to the in-flight map under the lock, so concurrent requests dedup
        against them exactly; **label** — outside the lock, inline or sharded
        across the replica pool, so other threads keep enqueueing (and other
        flushes keep labeling) meanwhile; **publish** — results land in the
        cache in pending order under the lock, owners are charged fresh, the
        write-through listeners see one ordered batch, and waiters wake.  If
        labeling fails, the reservation rolls back into the pending queue
        with nothing charged.
        """
        with self._lock:
            if not self._pending:
                return 0
            queued = list(self._pending.items())  # insertion order
            if limit is not None and 0 < limit < len(queued):
                queued = queued[:limit]
                for i, _ in queued:
                    del self._pending[i]
            else:
                self._pending.clear()
            reserved: List[Tuple[int, Optional[OracleAccount]]] = []
            for i, owner in queued:
                # a forced fetch() may have labeled a pending id meanwhile:
                # the enqueuer is served from cache, not charged fresh
                if i in self.cache:
                    if owner is not None and i in owner._credit:
                        owner._credit.discard(i)  # demand read charges cached
                    else:
                        self.stats["cached"] += 1
                        if self._record_hit is not None:
                            self._record_hit(i)  # tier attribution
                        if owner is not None:
                            owner.cached += 1
                else:
                    self._inflight[i] = owner
                    reserved.append((i, owner))
            if not reserved:
                return 0
            ids = np.asarray([i for i, _ in reserved], np.int64)
        # span + histogram cover label->publish; reserve was under the lock.
        # The span is stack-pushed so the pool's oracle.subbatch spans
        # parent under THIS flush — one chain per fresh label.
        t0 = time.perf_counter()
        with span("broker.flush", reserved=len(reserved),
                  limit=limit if limit is not None else 0) as sp:
            try:
                results, batches = self._label(ids)
                missing = [i for i, _ in reserved if i not in results]
                if missing:
                    raise RuntimeError(
                        f"oracle returned no label for {len(missing)} of "
                        f"{len(reserved)} flushed ids")
            except BaseException as e:
                sp.set(error=f"{type(e).__name__}: {e}", fresh=0)
                with self._lock:
                    # roll the reservation back: nothing was published,
                    # nothing is charged, and the ids are pending again for
                    # a retry
                    for i, owner in reserved:
                        self._inflight.pop(i, None)
                        if i not in self.cache and i not in self._pending:
                            self._pending[i] = owner
                    self._cond.notify_all()
                raise
            with self._lock:
                labeled: Dict[int, Any] = {}
                for i, owner in reserved:  # publish in pending order
                    self._inflight.pop(i, None)
                    a = results[i]
                    self.cache[i] = a
                    labeled[i] = a
                    self.stats["fresh"] += 1
                    if owner is not None:
                        owner.fresh += 1
                        owner.labeled.append(i)
                self.stats["batches"] += batches
                self.stats["flushes"] += 1
                self._notify_fresh(labeled)
                self._cond.notify_all()
            sp.set(fresh=len(reserved), batches=batches)
        self._h_flush_latency.observe(time.perf_counter() - t0)
        self._h_flush_size.observe(len(reserved))
        return len(reserved)
