"""Induced schemas + synthetic workloads with an oracle target DNN.

The paper evaluates on three videos (night-street / taipei / amsterdam, Mask
R-CNN target DNN) and WikiSQL (crowd-worker "target DNN").  Those datasets are
not available offline, so we generate workloads with the same statistical
structure (DESIGN.md §7):

* ``VideoWorkload``: a latent scene process — object count follows a sticky
  Markov chain (mostly empty frames, bursts of traffic, *rare* high-count
  events), object positions drift smoothly.  A frame's unstructured record is
  a fixed random nonlinear rendering of its latent scene + noise; the *target
  DNN* is an oracle that reads the latent scene (cost-modeled at the paper's
  measured 3 fps vs 12,000 fps embedder ratio).
* ``TextWorkload``: latent = (SQL operator, #predicates); records are noisy
  nonlinear renderings of the latent, mirroring the WikiSQL semantic-parsing
  setup.

Both expose the *induced schema* (structured outputs), the paper's
``IsClose`` heuristic, and a metric ``d`` on schema outputs used by the
theoretical analysis and the triplet miner.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

# Cost model (paper §3.4: Mask R-CNN 3 fps vs embedding DNN 12,000 fps).
TARGET_DNN_COST_S = 1.0 / 3.0
EMBED_DNN_COST_S = 1.0 / 12000.0
DIST_COST_S = 1e-7  # per record-representative distance


@dataclass
class Scene:
    """Induced-schema record for video: object positions in [0,1]^2."""
    boxes: np.ndarray  # (n_objects, 2) positions; n_objects may be 0

    @property
    def count(self) -> int:
        return len(self.boxes)

    def mean_x(self) -> float:
        return float(np.mean(self.boxes[:, 0])) if len(self.boxes) else 0.5


def scene_distance(a: Scene, b: Scene) -> float:
    """Metric d on the induced schema: count mismatch dominates, matched
    objects contribute their nearest-neighbor position distance."""
    if a.count != b.count:
        return 1.0 + abs(a.count - b.count)
    if a.count == 0:
        return 0.0
    # greedy nearest matching (counts are small)
    pa = a.boxes.copy()
    pb = b.boxes.copy()
    total = 0.0
    used = np.zeros(len(pb), bool)
    for p in pa:
        d = np.linalg.norm(pb - p, axis=1)
        d[used] = np.inf
        j = int(np.argmin(d))
        used[j] = True
        total += float(d[j])
    return total / a.count


def is_close_video(a: Scene, b: Scene, pos_tol: float = 0.25) -> bool:
    """The paper's IsClose pseudocode (§2.2): same count, all boxes close."""
    return scene_distance(a, b) < pos_tol


@dataclass
class VideoWorkload:
    n_frames: int = 20000
    feature_dim: int = 64
    max_objects: int = 8
    rare_count: int = 6           # frames with >= rare_count objects are rare
    p_stay: float = 0.98          # stickiness of the count chain
    noise: float = 0.15
    seed: int = 0
    name: str = "night-street-synth"

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.scenes: List[Scene] = []
        # sticky markov chain over counts, biased to 0 (mostly-empty street)
        count = 0
        positions = rng.uniform(0, 1, size=(self.max_objects, 2))
        velocity = rng.normal(0, 0.01, size=(self.max_objects, 2))
        counts = np.zeros(self.n_frames, np.int32)
        all_pos = np.zeros((self.n_frames, self.max_objects, 2), np.float32)
        for t in range(self.n_frames):
            if rng.uniform() > self.p_stay:
                # mostly small counts; rare heavy frames
                count = int(min(self.max_objects, rng.geometric(0.5) - 1))
            positions = np.clip(positions + velocity, 0, 1)
            velocity = 0.95 * velocity + rng.normal(0, 0.004, velocity.shape)
            bounce = (positions <= 0) | (positions >= 1)
            velocity[bounce] *= -1
            counts[t] = count
            all_pos[t] = positions
        self.counts = counts
        self._positions = all_pos
        for t in range(self.n_frames):
            self.scenes.append(Scene(boxes=all_pos[t, :counts[t]].copy()))
        # Compositional rendering: each *object* contributes an appearance
        # vector that depends nonlinearly on its position; the frame record is
        # a saturating mix of contributions + background + noise.  Count is
        # only implicit (no linearly-decodable count channel), which makes
        # small-label-budget supervised proxies genuinely hard — the regime
        # the paper studies — while the metric structure the triplet loss
        # needs is preserved.
        w_pos = rng.normal(0, 2.0, size=(3, 96))
        w_mix = rng.normal(0, 1.0, size=(96, self.feature_dim)) / np.sqrt(96)
        background = rng.normal(0, 0.3, size=(self.feature_dim,))
        mask = (np.arange(self.max_objects)[None, :] < counts[:, None])
        aug = np.concatenate([all_pos,
                              np.ones((self.n_frames, self.max_objects, 1))],
                             axis=2)  # (T, M, 3)
        appear = np.tanh(aug @ w_pos)            # (T, M, 96)
        appear = appear * mask[:, :, None]
        mixed = appear.sum(axis=1) @ w_mix       # (T, F)
        # Nuisance latent (lighting / weather): slowly-varying, schema-
        # irrelevant, and *dominant* in feature variance.  This is what makes
        # small-label-budget supervised proxies fit spuriously while the
        # induced-schema triplet loss learns invariance to it.
        nuis = np.zeros((self.n_frames, 4))
        z = rng.normal(0, 1, size=4)
        for t in range(self.n_frames):
            z = 0.98 * z + rng.normal(0, 0.2, size=4)
            nuis[t] = z
        w_nuis_gain = rng.normal(0, 0.6, size=(4, self.feature_dim))
        w_nuis_add = rng.normal(0, 1.2, size=(4, self.feature_dim))
        gain = 1.0 + np.tanh(nuis @ w_nuis_gain)
        feats = np.tanh((mixed + background[None]) * gain + nuis @ w_nuis_add)
        feats = feats + rng.normal(0, self.noise, size=feats.shape)
        self.features = feats.astype(np.float32)
        self.nuisance = nuis.astype(np.float32)

    # --- the "target DNN" oracle + cost model ---
    def target_dnn(self, idx: int) -> Scene:
        return self.scenes[idx]

    def target_dnn_batch(self, ids) -> List[Scene]:
        return [self.scenes[i] for i in ids]

    def schema_distance(self, i: int, j: int) -> float:
        return scene_distance(self.scenes[i], self.scenes[j])

    def is_close(self, i: int, j: int) -> bool:
        return is_close_video(self.scenes[i], self.scenes[j])

    # --- paper's query-specific scoring functions (§4.1) ---
    def score_count(self, scene: Scene) -> float:
        return float(scene.count)

    def score_has_object(self, scene: Scene) -> float:
        return 1.0 if scene.count > 0 else 0.0

    def score_rare(self, scene: Scene) -> float:
        return 1.0 if scene.count >= self.rare_count else 0.0

    def score_left_side(self, scene: Scene) -> float:
        """Selecting objects on the left (paper §6.4, violates Lipschitz)."""
        return 1.0 if (scene.count > 0 and scene.mean_x() < 0.5) else 0.0

    def score_mean_x(self, scene: Scene) -> float:
        """Average x position (paper §6.4 regression query)."""
        return scene.mean_x()


_TEXT_OPS = ("SELECT", "COUNT", "MAX", "MIN", "AVG", "SUM")


@dataclass
class TextRecord:
    op: int            # index into _TEXT_OPS
    n_predicates: int  # 0..4


def text_distance(a: TextRecord, b: TextRecord) -> float:
    return (1.0 if a.op != b.op else 0.0) + 0.5 * abs(a.n_predicates - b.n_predicates)


@dataclass
class TextWorkload:
    """WikiSQL-like: latent (operator, #predicates) -> noisy record features."""
    n_records: int = 8000
    feature_dim: int = 64
    noise: float = 0.15
    seed: int = 1
    name: str = "wikisql-synth"

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        ops = rng.choice(len(_TEXT_OPS), size=self.n_records,
                         p=[0.45, 0.25, 0.1, 0.1, 0.05, 0.05])
        preds = np.minimum(rng.geometric(0.5, size=self.n_records), 5) - 1
        self.records = [TextRecord(int(o), int(p)) for o, p in zip(ops, preds)]
        self.ops = ops
        self.n_predicates = preds
        lat = np.stack([ops / len(_TEXT_OPS), preds / 5.0], axis=1)
        w1 = rng.normal(0, 1, size=(2, 96)) / np.sqrt(2)
        w2 = rng.normal(0, 1, size=(96, self.feature_dim)) / np.sqrt(96)
        h = np.tanh(lat @ w1)
        self.features = (np.tanh(h @ w2) + rng.normal(
            0, self.noise, size=(self.n_records, self.feature_dim))
        ).astype(np.float32)

    def target_dnn(self, idx: int) -> TextRecord:
        return self.records[idx]

    def target_dnn_batch(self, ids) -> List[TextRecord]:
        return [self.records[i] for i in ids]

    def schema_distance(self, i: int, j: int) -> float:
        return text_distance(self.records[i], self.records[j])

    def is_close(self, i: int, j: int) -> bool:
        return self.schema_distance(i, j) < 0.5

    def score_n_predicates(self, rec: TextRecord) -> float:
        return float(rec.n_predicates)

    def score_is_select(self, rec: TextRecord) -> float:
        return 1.0 if rec.op == 0 else 0.0


#: Datasets make_workload knows how to synthesize (CLIs and the serving
#: registry validate workload mounts against this list).
VIDEO_WORKLOAD_NAMES = ("night-street", "taipei", "amsterdam")
WORKLOAD_NAMES = VIDEO_WORKLOAD_NAMES + ("wikisql",)


def make_workload(name: str, **kw):
    """Synthesize the named workload.  The size kw is dataset-specific
    (``n_frames`` for video, ``n_records`` for text) but either spelling is
    accepted and translated, so generic callers (CLIs, the serving
    registry) can size every dataset uniformly."""
    if "n_frames" in kw and "n_records" in kw:
        raise ValueError("pass n_frames or n_records, not both")
    if name in VIDEO_WORKLOAD_NAMES:
        if "n_records" in kw:
            kw["n_frames"] = kw.pop("n_records")
        seeds = {"night-street": 0, "taipei": 7, "amsterdam": 13}
        # taipei has two object classes in the paper; we model heavier traffic
        overrides = {"taipei": dict(p_stay=0.96), "amsterdam": dict(p_stay=0.99)}
        return VideoWorkload(seed=seeds[name], name=name + "-synth",
                             **{**overrides.get(name, {}), **kw})
    if name == "wikisql":
        if "n_frames" in kw:
            kw["n_records"] = kw.pop("n_frames")
        return TextWorkload(**kw)
    raise KeyError(name)
