"""The TASTI index (paper §3): embeddings + annotated cluster representatives
+ cached top-k distances, with cracking (§3.3) and a construction cost model
(§3.4: O(C*c_T + L*c_E + N*c_E + N*C*D*c_D)).
"""
from __future__ import annotations

import dataclasses
import pathlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import schema as schema_lib
from repro.core.fpf import fpf_select
from repro.kernels.distance_topk.ops import distance_topk


@dataclass
class IndexCost:
    target_invocations: int = 0
    embed_records: int = 0
    training_steps: int = 0
    distance_pairs: int = 0

    def wall_clock_s(self) -> float:
        return (self.target_invocations * schema_lib.TARGET_DNN_COST_S
                + self.embed_records * schema_lib.EMBED_DNN_COST_S
                + self.training_steps * 256 * schema_lib.EMBED_DNN_COST_S * 3
                + self.distance_pairs * schema_lib.DIST_COST_S)

    def breakdown(self) -> Dict[str, float]:
        return {
            "target_dnn_s": self.target_invocations * schema_lib.TARGET_DNN_COST_S,
            "embedding_s": self.embed_records * schema_lib.EMBED_DNN_COST_S,
            "training_s": self.training_steps * 256 * schema_lib.EMBED_DNN_COST_S * 3,
            "distance_s": self.distance_pairs * schema_lib.DIST_COST_S,
        }


@dataclass
class TastiIndex:
    embeddings: np.ndarray            # (N, d)
    rep_ids: np.ndarray               # (C,) record indices of representatives
    annotations: list                 # len C target-DNN outputs for reps
    topk_d2: np.ndarray               # (N, k) squared distances (ascending)
    topk_ids: np.ndarray              # (N, k) indices INTO rep_ids
    k: int
    cost: IndexCost = field(default_factory=IndexCost)
    version: int = 0                  # bumped on every crack that mutates;
                                      # caches keyed on it self-invalidate

    @property
    def n_records(self) -> int:
        return len(self.embeddings)

    @property
    def n_reps(self) -> int:
        return len(self.rep_ids)

    # ------------------------------------------------------------------
    @staticmethod
    def build(embeddings: np.ndarray, n_reps: int, annotate: Callable,
              k: int = 8, random_fraction: float = 0.1, seed: int = 0,
              cost: Optional[IndexCost] = None,
              rep_selection: str = "fpf") -> "TastiIndex":
        """annotate(ids) -> list of target-DNN outputs (counted in the cost)."""
        n = len(embeddings)
        cost = cost or IndexCost()
        if rep_selection == "fpf":
            rep_ids = fpf_select(embeddings, n_reps,
                                 random_fraction=random_fraction, seed=seed)
        else:
            rng = np.random.default_rng(seed)
            rep_ids = rng.choice(n, size=min(n_reps, n), replace=False)
        annotations = annotate(rep_ids)
        cost.target_invocations += len(rep_ids)
        d2, ids = distance_topk(jnp.asarray(embeddings),
                                jnp.asarray(embeddings[rep_ids]),
                                min(k, len(rep_ids)))
        cost.distance_pairs += n * len(rep_ids)
        return TastiIndex(embeddings=embeddings,
                          rep_ids=np.asarray(rep_ids),
                          annotations=list(annotations),
                          topk_d2=np.asarray(d2), topk_ids=np.asarray(ids),
                          k=k, cost=cost)

    # ------------------------------------------------------------------
    def crack(self, new_ids: Sequence[int], new_annotations: list) -> None:
        """Fold query-time target-DNN results back in as new representatives
        (paper §3.3).  Incremental: distances only to the new reps, merged
        with the cached top-k (no full rebuild)."""
        new_ids = np.asarray([i for i in new_ids], np.int64)
        if len(new_ids) == 0:
            return
        # dedupe against existing reps
        existing = set(self.rep_ids.tolist())
        keep = [t for t, i in enumerate(new_ids) if int(i) not in existing]
        if not keep:
            return
        new_ids = new_ids[keep]
        new_annotations = [new_annotations[t] for t in keep]
        base_c = self.n_reps
        d2_new, loc = distance_topk(jnp.asarray(self.embeddings),
                                    jnp.asarray(self.embeddings[new_ids]),
                                    min(self.k, len(new_ids)))
        self.cost.distance_pairs += self.n_records * len(new_ids)
        d2_new = np.asarray(d2_new)
        glob = base_c + np.asarray(loc)
        # merge (N, k_old + k_new) and keep k smallest
        cand_d = np.concatenate([self.topk_d2, d2_new], axis=1)
        cand_i = np.concatenate([self.topk_ids, glob], axis=1)
        order = np.argsort(cand_d, axis=1)[:, :self.k]
        self.topk_d2 = np.take_along_axis(cand_d, order, axis=1)
        self.topk_ids = np.take_along_axis(cand_i, order, axis=1)
        self.rep_ids = np.concatenate([self.rep_ids, new_ids])
        self.annotations = self.annotations + list(new_annotations)
        self.version += 1

    # ------------------------------------------------------------------
    def rep_scores(self, score_fn: Callable[[Any], float]) -> np.ndarray:
        return np.asarray([score_fn(a) for a in self.annotations], np.float64)

    def max_intra_cluster(self) -> float:
        return float(np.sqrt(np.max(self.topk_d2[:, 0])))

    # ------------------------------------------------------------------
    # Persistence: arrays in ``<path>.npz``, everything else in a versioned
    # ``<path>.meta.json`` — portable and safe to load (no pickle).  Both
    # files are written atomically (temp file + rename), so a crash mid-save
    # cannot leave a torn pair on disk.
    FORMAT_VERSION = 1

    def save(self, path: str) -> None:
        import json
        from repro.core.persist import atomic_write
        p = pathlib.Path(path)
        # serialize the meta FIRST: an unencodable annotation must fail
        # before any file is touched, not orphan a fresh .npz
        meta = {"format_version": self.FORMAT_VERSION,
                "k": self.k,
                "index_version": self.version,
                "n_reps": int(self.n_reps),
                "cost": dataclasses.asdict(self.cost),
                "annotations": [_encode_annotation(a)
                                for a in self.annotations]}
        meta_body = json.dumps(meta)
        with atomic_write(p.with_suffix(".npz"), "wb") as f:
            np.savez(f, embeddings=self.embeddings,
                     rep_ids=self.rep_ids, topk_d2=self.topk_d2,
                     topk_ids=self.topk_ids, k=np.int64(self.k))
        with atomic_write(p.with_suffix(".meta.json"), "w") as f:
            f.write(meta_body)
        # re-saving over a legacy index drops its stale (now unreadable)
        # pickle so the saved artifact is unambiguous
        p.with_suffix(".ann.pkl").unlink(missing_ok=True)

    @staticmethod
    def load(path: str) -> "TastiIndex":
        import json
        p = pathlib.Path(path)
        z = np.load(p.with_suffix(".npz"))
        meta_json = p.with_suffix(".meta.json")
        if not meta_json.exists():
            pkl = p.with_suffix(".ann.pkl")
            if pkl.exists():
                raise ValueError(
                    f"{pkl} is a legacy pickle-format index; pickle support "
                    "has been removed — load and re-save it with a release "
                    "that still reads .ann.pkl to migrate to the versioned "
                    "JSON+npz format")
            raise FileNotFoundError(f"no {meta_json.name} next to {p}")
        with open(meta_json) as f:
            meta = json.load(f)
        fv = int(meta.get("format_version", -1))
        if fv > TastiIndex.FORMAT_VERSION:
            raise ValueError(
                f"{meta_json} has format_version {fv}; this build reads "
                f"<= {TastiIndex.FORMAT_VERSION}")
        annotations = [_decode_annotation(a) for a in meta["annotations"]]
        index_version = int(meta.get("index_version", 0))
        # each file is written atomically but the pair is not one
        # transaction: a crash between the two renames can mix an old meta
        # with a new npz (or vice versa) — detect, don't mis-serve
        if len(annotations) != len(z["rep_ids"]):
            raise ValueError(
                f"{p} is torn: {meta_json.name} lists {len(annotations)} "
                f"annotations but the npz holds {len(z['rep_ids'])} "
                "representatives (crash between the two file writes?); "
                "re-save the index")
        return TastiIndex(embeddings=z["embeddings"], rep_ids=z["rep_ids"],
                          annotations=annotations,
                          topk_d2=z["topk_d2"], topk_ids=z["topk_ids"],
                          k=int(z["k"]), cost=IndexCost(**meta["cost"]),
                          version=index_version)


# ---------------------------------------------------------------------------
# JSON codec for representative annotations.  Target-DNN outputs are schema
# records (Scene / TextRecord), plain numbers, or nested lists/dicts thereof;
# anything else must be made serializable by the caller (no pickle).
# ---------------------------------------------------------------------------
def _encode_annotation(a):
    if a is None or isinstance(a, (bool, int, float, str)):
        return a
    if isinstance(a, np.integer):
        return int(a)
    if isinstance(a, np.floating):
        return float(a)
    if isinstance(a, np.ndarray):
        return {"__kind__": "ndarray", "dtype": str(a.dtype),
                "shape": list(a.shape), "data": a.ravel().tolist()}
    if isinstance(a, schema_lib.Scene):
        return {"__kind__": "scene",
                "boxes": np.asarray(a.boxes, np.float64).reshape(-1).tolist(),
                "n": int(a.count)}
    if isinstance(a, schema_lib.TextRecord):
        return {"__kind__": "text_record", "op": int(a.op),
                "n_predicates": int(a.n_predicates)}
    if isinstance(a, (list, tuple)):
        return {"__kind__": "list", "items": [_encode_annotation(x) for x in a]}
    if isinstance(a, dict):
        return {"__kind__": "dict",
                "items": {str(k): _encode_annotation(v) for k, v in a.items()}}
    raise TypeError(
        f"cannot JSON-encode annotation of type {type(a).__name__}; "
        "supported: numbers, str, ndarray, Scene, TextRecord, list, dict")


def _decode_annotation(a):
    if not isinstance(a, dict):
        return a
    kind = a.get("__kind__")
    if kind == "ndarray":
        return np.asarray(a["data"], dtype=np.dtype(a["dtype"])).reshape(
            a["shape"])
    if kind == "scene":
        boxes = np.asarray(a["boxes"], np.float64).reshape(int(a["n"]), 2)
        return schema_lib.Scene(boxes=boxes)
    if kind == "text_record":
        return schema_lib.TextRecord(op=int(a["op"]),
                                     n_predicates=int(a["n_predicates"]))
    if kind == "list":
        return [_decode_annotation(x) for x in a["items"]]
    if kind == "dict":
        return {k: _decode_annotation(v) for k, v in a["items"].items()}
    raise ValueError(f"unknown annotation encoding {kind!r}")
