"""Furthest-point-first (Gonzalez 1985): training-data mining and cluster-
representative selection (paper §3.1/§3.2).

FPF gives a 2-approximation to the optimal max intra-cluster distance — the
quantity the paper's Theorems 1/2 depend on.  Each step is one fused pass via
``repro.kernels.fpf_update`` (distance to newest rep + running min + argmax);
a small random fraction is mixed in for average-case queries (§3.2).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.kernels.fpf_update.ops import fpf_update


def fpf_select(embeddings: np.ndarray, n_select: int,
               random_fraction: float = 0.1, seed: int = 0,
               impl: str = "auto", start: Optional[int] = None
               ) -> np.ndarray:
    """Returns indices (n_select,) — FPF points + a random mix."""
    n = len(embeddings)
    n_select = min(n_select, n)
    rng = np.random.default_rng(seed)
    n_rand = int(round(n_select * random_fraction))
    n_fpf = n_select - n_rand

    x = jnp.asarray(embeddings, jnp.float32)
    chosen = np.empty(n_fpf, np.int64)
    chosen[0] = start if start is not None else int(rng.integers(n))
    min_d2 = jnp.full((n,), np.float32(np.inf))
    idx = chosen[0]
    for t in range(1, n_fpf):
        min_d2, nxt, _ = fpf_update(x, x[idx], min_d2, impl=impl)
        idx = int(nxt)
        chosen[t] = idx
    # mix random clusters (dedup while keeping count)
    selected = set(chosen.tolist())
    pool = np.setdiff1d(np.arange(n), chosen, assume_unique=False)
    if n_rand and len(pool):
        extra = rng.choice(pool, size=min(n_rand, len(pool)), replace=False)
        out = np.concatenate([chosen, extra])
    else:
        out = chosen
    return out.astype(np.int64)


def max_intra_cluster_dist(embeddings: np.ndarray,
                           reps: np.ndarray) -> float:
    """max_x ||phi(x) - phi(c(x))|| — the density quantity in Thm 1/2."""
    x = jnp.asarray(embeddings, jnp.float32)
    r = jnp.asarray(embeddings[reps], jnp.float32)
    from repro.kernels.distance_topk.ops import distance_topk
    d2, _ = distance_topk(x, r, 1)
    return float(jnp.sqrt(jnp.max(d2)))
