"""End-to-end TASTI pipelines over a workload (the prototype system of §6).

``build_tasti(workload, variant=...)``:
  1. FPF-mine a training set over pre-trained embeddings (budget target-DNN
     annotations),
  2. train the embedding DNN with the induced-schema triplet loss (TASTI-T) or
     keep the pre-trained embedder (TASTI-PT),
  3. embed all records, FPF-select cluster representatives (+random mix),
     annotate them, cache top-k distances.

Returned ``TastiSystem`` exposes the paper's query API: proxy scores per
query-specific ``Score`` function, with propagation mode per score type.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core.baselines import pretrain_embedder
from repro.core.embedder import EmbedderConfig, embed_all
from repro.core.engine import QueryEngine, QueryResult, QuerySpec
from repro.core.fpf import fpf_select
from repro.core.index import IndexCost, TastiIndex
from repro.core.session import QuerySession, SessionResult
from repro.core.triplet import TripletConfig, mine_triplets, train_embedder


@dataclass
class TastiConfig:
    n_train: int = 3000            # paper: 3,000 training records (video)
    n_reps: int = 7000             # paper: 7,000 cluster representatives
    k: int = 8
    embed_dim: int = 128           # paper default
    random_fraction: float = 0.1
    triplet: TripletConfig = field(default_factory=TripletConfig)
    pretrain_steps: int = 200
    seed: int = 0


@dataclass
class TastiSystem:
    """Thin facade over :class:`~repro.core.engine.QueryEngine`.

    The declarative path is ``system.execute(QuerySpec(...))``.
    ``proxy_scores`` and ``crack_with`` are shims that share the engine's
    caches (memoized propagation, crack invalidation).  ``oracle`` stays
    deliberately cache-free: its callers count every invocation for benchmark
    comparability — use ``execute`` to get the shared label cache.
    """
    index: TastiIndex
    workload: Any
    embed_params: Any
    ecfg: EmbedderConfig
    variant: str
    _engine: Optional[QueryEngine] = dataclasses.field(default=None,
                                                       repr=False)

    @property
    def engine(self) -> QueryEngine:
        if self._engine is None:
            self._engine = QueryEngine(self.index, self.workload)
        return self._engine

    def execute(self, spec: QuerySpec) -> QueryResult:
        return self.engine.execute(spec)

    def session(self, specs=None, **kw) -> QuerySession:
        """A multi-query session over this system's engine: joint planning,
        broker-prefetched labels, combined budget (see
        :mod:`repro.core.session`)."""
        return QuerySession(self.engine, specs, **kw)

    def execute_session(self, specs, **kw) -> SessionResult:
        return self.session(specs, **kw).execute()

    # -- paper §4: query-specific proxy scores (legacy shim) -------------
    def proxy_scores(self, score_fn: Callable[[Any], float],
                     mode: str = "numeric",
                     n_classes: Optional[int] = None) -> np.ndarray:
        """Propagated proxy scores, memoized by the engine.
        ``mode``: "numeric" | "top1" | "categorical" (needs ``n_classes``)."""
        return self.engine.proxy_scores(score_fn, mode=mode,
                                        n_classes=n_classes)

    def oracle(self, score_fn: Callable[[Any], float],
               counter: Optional[list] = None) -> Callable:
        wl = self.workload

        def call(ids: np.ndarray) -> np.ndarray:
            if counter is not None:
                counter.append(len(ids))
            return np.asarray([score_fn(s) for s in wl.target_dnn_batch(ids)])

        return call

    def crack_with(self, ids: np.ndarray) -> None:
        self.engine.crack_with(np.asarray(ids, np.int64))


def cli_tasti_config(quick: bool = False, n_train: int = 400,
                     n_reps: int = 800, k: int = 8,
                     triplet_steps: int = 400) -> TastiConfig:
    """The build budgets shared by the query/serving CLIs and the workload
    registry: one ``--quick`` smoke configuration (tiny budgets for CI),
    else the given knobs at their common CLI defaults."""
    if quick:
        return TastiConfig(n_train=100, n_reps=200, k=4,
                           triplet=TripletConfig(steps=60, batch=128),
                           pretrain_steps=40)
    return TastiConfig(n_train=n_train, n_reps=n_reps, k=k,
                       triplet=TripletConfig(steps=triplet_steps))


def build_tasti(workload, cfg: Optional[TastiConfig] = None,
                variant: str = "T",
                use_fpf_mining: bool = True,
                use_fpf_clustering: bool = True,
                embed_params=None) -> TastiSystem:
    """variant: "T" (triplet-trained) | "PT" (pre-trained only)."""
    cfg = cfg or TastiConfig()
    cost = IndexCost()
    feats = workload.features
    ecfg = EmbedderConfig(feature_dim=feats.shape[1], embed_dim=cfg.embed_dim)
    key = jax.random.PRNGKey(cfg.seed)

    # 1) pre-trained embeddings (generic self-supervision; no schema access)
    if embed_params is None:
        pt_params = pretrain_embedder(feats, ecfg, steps=cfg.pretrain_steps,
                                      seed=cfg.seed)
    else:
        pt_params = embed_params
    cost.embed_records += len(feats)
    pt_embeddings = embed_all(pt_params, feats, ecfg)

    params = pt_params
    if variant == "T":
        # 2) FPF-mine the training set, annotate with the target DNN
        if use_fpf_mining:
            train_ids = fpf_select(pt_embeddings, cfg.n_train,
                                   random_fraction=cfg.random_fraction,
                                   seed=cfg.seed)
        else:
            rng = np.random.default_rng(cfg.seed)
            train_ids = rng.choice(len(feats), size=min(cfg.n_train, len(feats)),
                                   replace=False)
        cost.target_invocations += len(train_ids)  # annotations for closeness
        rng = np.random.default_rng(cfg.seed + 1)
        triples = mine_triplets(train_ids, workload.is_close, rng,
                                max_triplets=cfg.triplet.max_triplets)
        train_feats = feats[train_ids]
        params, _ = train_embedder(params, train_feats, triples, ecfg,
                                   cfg.triplet)
        cost.training_steps += cfg.triplet.steps

    # 3) embed all records with the (possibly trained) embedder
    embeddings = embed_all(params, feats, ecfg)
    cost.embed_records += len(feats)

    def annotate(ids):
        return workload.target_dnn_batch(np.asarray(ids, np.int64))

    index = TastiIndex.build(
        embeddings, cfg.n_reps, annotate, k=cfg.k,
        random_fraction=cfg.random_fraction, seed=cfg.seed, cost=cost,
        rep_selection="fpf" if use_fpf_clustering else "random")
    return TastiSystem(index=index, workload=workload, embed_params=params,
                       ecfg=ecfg, variant=variant)
