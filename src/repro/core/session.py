"""Multi-query sessions: plan a list of ``QuerySpec`` s jointly, execute them
against one engine, and account for the whole batch.

One semantic index answers many queries (paper §4); a *session* makes the
cross-query structure explicit instead of incidental:

* **grouping** — specs over the same score function are planned together:
  propagation runs once per (score, mode) and the group shares the engine's
  oracle-label cache;
* **shared stratified sample** — aggregation specs in a group walk one
  sample order whose every prefix is stratified over proxy-score strata, so
  their samples *nest*: the group's fresh-label cost is the max of its
  members, not the sum;
* **prefetch + combined flush** — each executor previews the ids it will
  certainly request first; the session enqueues all previews through the
  :class:`~repro.core.broker.OracleBroker` and flushes once, so one
  ``target_dnn_batch`` microbatch sequence serves every spec;
* **combined invocation budget** — an optional session-wide cap on
  worst-case oracle demand, allocated proportionally across specs by
  clamping their knobs (selection ``budget``, aggregation ``max_samples``,
  limit ``max_invocations``) at plan time;
* **accounting** — per-spec fresh/cached counts stay exact under dedup (a
  record labeled for spec A is fresh for A, cached for B), and every
  :class:`QueryResult` carries a ``session`` snapshot of the batch totals.

Cracking composes: a spec with ``crack=True`` bumps the index version
mid-session, the engine's memoized propagation self-invalidates, and sibling
specs re-propagate against the improved index.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.broker import OracleAccount
from repro.core.engine import QueryEngine, QueryPlan, QueryResult, QuerySpec
from repro.obs.trace import span as trace_span


def stratified_order(proxy: np.ndarray, n_strata: int = 10,
                     seed: int = 0) -> np.ndarray:
    """A full permutation of record ids whose every prefix is (approximately)
    stratified over ``n_strata`` equal-frequency proxy-score strata.

    Records are ranked by proxy score, split into equal-sized strata,
    shuffled within each stratum, and interleaved round-robin — so any
    prefix covers the proxy range evenly.  Aggregation specs sharing this
    order draw nested, stratified samples."""
    n = len(proxy)
    n_strata = max(1, min(int(n_strata), n))
    rng = np.random.default_rng(seed)
    ranks = np.argsort(np.argsort(proxy, kind="stable"), kind="stable")
    strata = (ranks * n_strata) // n                  # (n,) stratum per record
    perm = rng.permutation(n)
    sp = strata[perm]
    within = np.empty(n, np.int64)
    for s in range(n_strata):
        members = np.where(sp == s)[0]
        within[members] = np.arange(len(members))
    round_pos = rng.permutation(n_strata)             # stratum order per round
    key = within * n_strata + round_pos[sp]
    return perm[np.argsort(key, kind="stable")]


def _oracle_demand(spec: QuerySpec, n: int) -> int:
    """Worst-case fresh-label demand of one spec (the combined-budget unit)."""
    if spec.kind == "selection":
        return min(int(spec.budget or n), n)
    if spec.kind == "aggregation":
        return min(int(spec.max_samples or n), n)
    if spec.kind == "limit":
        return min(int(spec.max_invocations or n), n)
    return n


def _clamp_spec(spec: QuerySpec, alloc: int) -> QuerySpec:
    """Rewrite one spec's knobs so its worst-case demand is ``alloc``."""
    if spec.kind == "selection":
        return dataclasses.replace(spec, budget=alloc)
    if spec.kind == "aggregation":
        return dataclasses.replace(spec, max_samples=alloc,
                                   min_samples=min(spec.min_samples, alloc))
    if spec.kind == "limit":
        return dataclasses.replace(spec, max_invocations=alloc)
    return spec


@dataclass
class SessionGroup:
    """Specs (by position) sharing one score function."""
    score_key: Any
    spec_indices: List[int]
    modes: List[str]
    shared_order: bool = False       # aggregation members share a sample order


@dataclass
class SessionPlan:
    plans: List[QueryPlan]
    groups: List[SessionGroup]
    budget: Optional[int]
    allocations: Optional[List[int]]  # per-spec demand after clamping
    trace: List[str] = field(default_factory=list)


@dataclass
class SessionResult:
    """All per-spec results plus batch-level accounting."""
    results: List[QueryResult]
    stats: Dict[str, Any]
    plan: SessionPlan


class QuerySession:
    """Plans and executes a batch of specs against one :class:`QueryEngine`.

        session = QuerySession(engine, specs, budget=2000)
        out = session.execute()
        out.results[0].session["session_fresh"], out.stats["oracle_batches"]

    ``budget`` caps the batch's worst-case fresh-label demand; ``prefetch``
    disables the preview/flush phase (labels are then fetched on demand,
    still deduped); ``n_strata`` controls the shared stratified sample;
    ``oracle_replicas`` (None = leave the engine's setting alone) resizes
    the target-DNN replica pool behind the broker before execution, and
    ``oracle_backend`` ("thread" | "process", None = keep the engine's)
    picks its replica kind — results and accounting are identical at any
    replica count and on either backend, only flush latency changes.

    ``checkpoint`` makes the session preemptible: it is called between
    ``slice_size``-id slices of every oracle interaction (prefetch flush and
    execution alike) and may block — the serving scheduler parks a preempted
    session there while higher-priority work runs.  Slicing never changes
    which ids are requested, in what order, or on which account, so results
    and fresh/cached accounting are byte-identical to an uncheckpointed run.
    ``slice_size`` defaults to the engine's oracle microbatch size.
    """

    def __init__(self, engine: QueryEngine,
                 specs: Optional[Sequence[QuerySpec]] = None,
                 budget: Optional[int] = None, prefetch: bool = True,
                 n_strata: int = 10, seed: int = 0,
                 oracle_replicas: Optional[int] = None,
                 oracle_backend: Optional[str] = None,
                 checkpoint: Optional[Any] = None,
                 slice_size: Optional[int] = None):
        self.engine = engine
        self.specs: List[QuerySpec] = list(specs or [])
        self.budget = budget
        self.prefetch = bool(prefetch)
        self.n_strata = int(n_strata)
        self.seed = int(seed)
        self.oracle_replicas = oracle_replicas
        self.oracle_backend = oracle_backend
        self.checkpoint = checkpoint
        self.slice_size = (int(slice_size) if slice_size
                           else engine.max_oracle_batch)

    def add(self, spec: QuerySpec) -> "QuerySession":
        self.specs.append(spec)
        return self

    # -- joint planning ------------------------------------------------------
    def plan(self) -> SessionPlan:
        """Compile the batch: allocate the combined budget, group specs by
        score, build shared stratified sample orders.  Spends no oracle
        budget (propagation is free arithmetic)."""
        if not self.specs:
            raise ValueError("session has no specs; pass them to the "
                             "constructor or add() them")
        engine = self.engine
        n = engine.index.n_records
        trace: List[str] = [f"session of {len(self.specs)} specs over "
                            f"{n} records"]

        specs = list(self.specs)
        allocations: Optional[List[int]] = None
        if self.budget is not None:
            if self.budget < len(specs):
                raise ValueError(
                    f"session budget {self.budget} cannot cover "
                    f"{len(specs)} specs (every spec needs >= 1 label)")
            demands = [_oracle_demand(s, n) for s in specs]
            total = sum(demands)
            if total > self.budget:
                allocations = [max(1, (self.budget * d) // total)
                               for d in demands]
                # flooring at 1 can overshoot the cap: shave the largest
                # allocations until the worst-case sum fits again
                while sum(allocations) > self.budget:
                    big = int(np.argmax(allocations))
                    allocations[big] -= 1
                specs = [_clamp_spec(s, a) for s, a in zip(specs, allocations)]
                trace.append(
                    f"combined budget {self.budget} < worst-case demand "
                    f"{total}: allocations {allocations}")
            else:
                allocations = demands
                trace.append(f"combined budget {self.budget} covers "
                             f"worst-case demand {total}")

        plans = [engine.plan(s) for s in specs]

        # group by score cache key (external-proxy specs stay ungrouped)
        keyed: Dict[Any, List[int]] = {}
        for i, plan in enumerate(plans):
            if plan.score_key is None or plan.spec.proxy is not None:
                continue
            keyed.setdefault(plan.score_key, []).append(i)
        groups: List[SessionGroup] = []
        for key, idxs in keyed.items():
            modes = sorted({plans[i].propagation for i in idxs})
            group = SessionGroup(score_key=key, spec_indices=idxs,
                                 modes=modes)
            agg = [i for i in idxs if plans[i].kind == "aggregation"]
            if agg:
                # one stratified order per score group: aggregation members
                # draw nested samples off the numeric proxy
                proxy = engine.proxy_for(plans[agg[0]])
                order = stratified_order(proxy, self.n_strata, self.seed)
                for i in agg:
                    plans[i].shared_order = order
                group.shared_order = True
            label = key if isinstance(key, str) else getattr(
                key, "__name__", repr(key))
            trace.append(
                f"group score={label}: specs {idxs}, propagation once per "
                f"mode {modes}"
                + (f", shared stratified sample ({self.n_strata} strata) "
                   f"across {len(agg)} aggregation spec(s)" if agg else ""))
            groups.append(group)
        if sum(len(g.spec_indices) for g in groups) < len(plans):
            trace.append("ungrouped specs execute with the shared label "
                         "cache only")
        return SessionPlan(plans=plans, groups=groups, budget=self.budget,
                           allocations=allocations, trace=trace)

    # -- execution -----------------------------------------------------------
    def execute(self) -> SessionResult:
        """Prefetch every spec's certain first requests, flush once, then
        execute the specs in order against the shared engine.

        Thread-safe over a shared engine: many sessions may execute
        concurrently from a worker pool (the serving layer does) — per-spec
        accounts keep fresh/cached exact under cross-session dedup, and
        answers match isolated runs because labels and propagation are
        deterministic per record.  Only ``stats["oracle_batches"]`` is a
        broker-level delta and may include a concurrent session's batches.
        """
        sp = self.plan()
        engine = self.engine
        if self.oracle_replicas is not None:
            engine.set_oracle_replicas(self.oracle_replicas,
                                       backend=self.oracle_backend)
        broker = engine.broker
        accounts: List[OracleAccount] = [
            broker.account(name=f"spec{i}:{p.kind}")
            for i, p in enumerate(sp.plans)]
        batches0 = broker.stats["batches"]
        version0 = engine.index.version

        prefetch_fresh = 0
        if self.prefetch and engine.workload is not None:
            with trace_span("session.prefetch") as psp:
                enqueued = 0
                for i, plan in enumerate(sp.plans):
                    if plan.spec.reuse_labels:
                        # cache-bypassing specs pay full freight (no prefetch)
                        ids = plan.executor.preview(plan,
                                                    engine.proxy_for(plan))
                        enqueued += broker.prefetch(ids, accounts[i])
                    if plan.crack:
                        # a crack re-propagates every later spec's proxy, so
                        # their previews would prefetch stale ids — let them
                        # fetch on demand (still deduped and microbatched)
                        sp.trace.append(
                            f"spec {i} cracks: later specs fetch on demand")
                        break
                # account-based delta, not a broker.stats delta: a concurrent
                # session's flush in this window must not inflate our count
                fresh0 = sum(a.fresh for a in accounts)
                if self.checkpoint is None:
                    broker.flush()
                else:
                    # preemptible prefetch: flush in slice-sized steps so the
                    # scheduler can run higher-priority work between them
                    # (per-id charging makes the step sequence byte-identical
                    # to a drain)
                    self.checkpoint()
                    while broker.flush(limit=self.slice_size):
                        self.checkpoint()
                prefetch_fresh = sum(a.fresh for a in accounts) - fresh0
                psp.set(enqueued=enqueued, fresh=prefetch_fresh)
            # execute() only folds post-entry deltas into engine.stats, so
            # the prefetch phase records its labels here
            engine.add_stats(label_fresh=prefetch_fresh)
            sp.trace.append(
                f"prefetched {enqueued} ids -> {prefetch_fresh} fresh labels "
                f"in {broker.stats['batches'] - batches0} microbatch(es)")

        results: List[QueryResult] = []
        for i, plan in enumerate(sp.plans):
            results.append(engine.execute(plan, account=accounts[i],
                                          checkpoint=self.checkpoint,
                                          slice_size=self.slice_size))
        if engine.index.version != version0:
            sp.trace.append(
                f"index version {version0} -> {engine.index.version} "
                "(cracked mid-session; memoized propagation re-ran for "
                "later specs)")

        prefetch_unused = sum(len(a._credit) for a in accounts)
        stats: Dict[str, Any] = {
            "n_specs": len(sp.plans),
            "n_groups": len(sp.groups),
            "fresh_total": sum(a.fresh for a in accounts),
            "cached_total": sum(a.cached for a in accounts),
            "prefetch_labels": prefetch_fresh,
            "prefetch_unused": prefetch_unused,
            "oracle_batches": broker.stats["batches"] - batches0,
            "n_cracked": sum(r.n_cracked for r in results),
            "budget": self.budget,
            "index_version_start": version0,
            "index_version_end": engine.index.version,
        }
        snapshot = {f"session_{k}": v for k, v in stats.items()
                    if k in ("fresh_total", "cached_total", "n_specs",
                             "oracle_batches")}
        for i, res in enumerate(results):
            res.session = {"spec_index": i, **snapshot}
        return SessionResult(results=results, stats=stats, plan=sp)
