"""Declarative query engine: ``QuerySpec`` -> ``QueryPlan`` -> ``QueryResult``.

The paper's core promise is one semantic index serving *many* query types
(aggregation §4.3, selection §4.3/SUPG, limit §4.3) without per-query proxies.
This module is the query layer that delivers that promise as an API: callers
describe the query declaratively and the engine owns everything they used to
hand-assemble —

* **memoized proxy scores**: propagation (§4.2) runs once per
  ``(score function, mode)`` across queries and is invalidated when the index
  is cracked;
* **automatic propagation choice** per query kind: numeric for aggregation,
  top-1 with distance tie-breaks for limit queries (§6.3), clipped-numeric
  for SUPG selection, with ``categorical`` available as an explicit mode;
* **a shared oracle-label cache**: records annotated by the target DNN for one
  query are free for every later query, whatever its score function;
* **an opt-in cracking feedback loop** (§3.3): every fresh target-DNN
  annotation a query makes can be folded straight back into the index.

Query kinds are pluggable through :mod:`repro.core.queries.registry`; the
numerical kernels stay in ``repro.core.queries.*`` and remain callable
directly (legacy shims).

    engine = QueryEngine(index, workload)
    res = engine.execute(QuerySpec(kind="aggregation", score="score_count",
                                   err=0.05))
    res.estimate, res.n_invocations, res.plan.trace
"""
from __future__ import annotations

import dataclasses
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Union

import numpy as np

# importing repro.core.queries registers the built-in executors
from repro.core import propagation, queries as _queries, schema as schema_lib  # noqa: F401
from repro.core.broker import OracleAccount, OracleBroker
from repro.core.index import TastiIndex
from repro.core.oracle_pool import OraclePool
from repro.core.queries.registry import QueryExecutor, get_executor
from repro.core.resident import ResidentIndexState
from repro.obs import NULL_SCOPE
from repro.obs.trace import span as trace_span

PROPAGATION_MODES = ("numeric", "top1", "categorical")


# ---------------------------------------------------------------------------
# Spec
# ---------------------------------------------------------------------------
@dataclass
class QuerySpec:
    """Declarative description of one query.

    ``score`` is either the name of a workload scoring method (portable,
    JSON-friendly) or any callable mapping a target-DNN output to a float.
    Unused knobs are ignored by kinds that don't need them.
    """

    kind: str                                   # "aggregation"|"selection"|"limit"|...
    score: Union[str, Callable, None] = None    # scoring fn (name or callable)
    proxy: Optional[np.ndarray] = None          # precomputed proxy override
    propagation: Optional[str] = None           # None -> kind default
    n_classes: Optional[int] = None             # required for "categorical"

    # statistical knobs
    err: float = 0.05                           # aggregation error bound
    delta: float = 0.05                         # confidence (all kinds)
    recall_target: float = 0.9                  # selection
    budget: Optional[int] = None                # selection oracle budget
    k_results: Optional[int] = None             # limit: K matches wanted
    batch: Optional[int] = None                 # oracle batch (kind default)
    min_samples: int = 64                       # aggregation
    max_samples: Optional[int] = None           # aggregation
    max_invocations: int = 0                    # limit (0 = no cap)
    use_cv: bool = True                         # aggregation control variates
    seed: int = 0

    # engine behaviour
    score_key: Optional[str] = None             # explicit proxy-cache key
    reuse_labels: bool = True                   # read the shared label cache
    crack: Optional[bool] = None                # None -> engine default

    # routing: which mounted workload a multi-workload server executes this
    # spec against (None -> the server's default; the engine itself ignores
    # it — score names already resolve against the engine's own workload)
    workload: Optional[str] = None

    # scheduling (serving layer only; the engine itself ignores both):
    # `priority` is the scheduling class (0 = most urgent; None -> the
    # server's default class), `deadline_ms` a soft latency target relative
    # to arrival that orders same-class work earliest-deadline-first
    priority: Optional[int] = None
    deadline_ms: Optional[float] = None

    _JSON_FIELDS = ("kind", "score", "propagation", "n_classes", "err",
                    "delta", "recall_target", "budget", "k_results", "batch",
                    "min_samples", "max_samples", "max_invocations", "use_cv",
                    "seed", "score_key", "reuse_labels", "crack", "workload",
                    "priority", "deadline_ms")

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "QuerySpec":
        unknown = set(d) - set(cls._JSON_FIELDS)
        if unknown:
            raise ValueError(f"unknown QuerySpec fields: {sorted(unknown)}; "
                             f"allowed: {sorted(cls._JSON_FIELDS)}")
        if "kind" not in d:
            raise ValueError("QuerySpec requires 'kind'")
        return cls(**d)

    def to_dict(self) -> Dict[str, Any]:
        if self.score is not None and not isinstance(self.score, str):
            raise ValueError("only specs with string `score` serialize to JSON")
        if self.proxy is not None:
            raise ValueError("specs with an external `proxy` array do not "
                             "serialize to JSON")
        return {k: getattr(self, k) for k in self._JSON_FIELDS
                if getattr(self, k) != getattr(type(self), k, None)
                or k == "kind"}


# ---------------------------------------------------------------------------
# Plan / result
# ---------------------------------------------------------------------------
@dataclass
class QueryPlan:
    """Compiled, validated form of a spec: every choice the engine made."""
    spec: QuerySpec
    kind: str
    executor: QueryExecutor
    propagation: str                 # resolved mode ("external" if proxy given)
    clip01: bool
    score_key: Any                   # proxy/label cache key
    crack: bool
    trace: List[str] = field(default_factory=list)
    # session-injected sample order shared across specs over the same score
    # (any prefix is stratified over proxy-score strata); None = spec default
    shared_order: Optional[np.ndarray] = None


@dataclass
class QueryResult:
    """Uniform result envelope for every query kind."""
    kind: str
    estimate: Optional[float]        # aggregation estimate (else None)
    selected: Optional[np.ndarray]   # selection/limit record ids (else None)
    threshold: Optional[float]       # selection tau (else None)
    ci_half_width: Optional[float]   # aggregation CI (else None)
    n_invocations: int               # the paper's cost metric for this query
    n_oracle_fresh: int              # target-DNN calls actually made
    n_oracle_cached: int             # label-cache hits (free)
    n_cracked: int                   # reps folded back into the index
    cost: Dict[str, float]           # modeled query-time cost breakdown
    plan: QueryPlan
    raw: Any                         # kind-specific result (AggResult, ...)
    session: Optional[Dict[str, Any]] = None  # session-level accounting
                                              # (set by QuerySession)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------
class QueryEngine:
    """Executes :class:`QuerySpec` s against a :class:`TastiIndex`.

    Owns the per-session caches: memoized propagation per score function,
    shared oracle labels across queries, and the optional cracking feedback
    loop that folds every fresh annotation back into the index.
    """

    def __init__(self, index: TastiIndex, workload: Any = None,
                 crack: bool = False, max_oracle_batch: int = 64,
                 broker: Optional[OracleBroker] = None,
                 oracle_replicas: int = 1,
                 oracle_backend: str = "thread",
                 oracle_pool: Optional[OraclePool] = None,
                 resident: Optional[bool] = None,
                 obs=None):
        self.obs = obs if obs is not None else NULL_SCOPE
        self.index = index
        self.workload = workload
        self.crack_by_default = bool(crack)
        self.max_oracle_batch = int(max_oracle_batch)
        self._proxy_cache: Dict[Any, np.ndarray] = {}
        self._proxy_cache_version = index.version
        # in-flight propagations (single-flight): key -> Event set on finish
        self._proxy_flights: Dict[Any, threading.Event] = {}
        # device-resident rep structures for the fused scoring hot path;
        # `resident=None` auto-enables on accelerators only (see
        # repro.core.resident for the policy and the env override)
        self.resident = ResidentIndexState(index, enabled=resident,
                                           obs=self.obs)
        self._broker = broker
        if broker is not None and obs is not None:
            broker.set_obs(self.obs)
        # oracle sharding: >1 replicas put an OraclePool behind the broker's
        # microbatcher; an externally-owned pool may be passed in instead.
        # `oracle_backend` picks thread replicas (GIL-releasing targets) or
        # forked process replicas (compute-bound targets)
        self.oracle_replicas = max(1, int(oracle_replicas))
        self.oracle_backend = str(oracle_backend)
        self._oracle_pool = oracle_pool
        self._owns_pool = False
        if broker is not None:
            # an injected broker skips the lazy construction below, so the
            # sharding knob must attach to it here (never silently ignored);
            # an existing pool on the shared broker wins
            if broker.pool is not None:
                self._oracle_pool = broker.pool
            elif self._oracle_pool is None and self.oracle_replicas > 1:
                self._oracle_pool = OraclePool(
                    self._annotate, n_replicas=self.oracle_replicas,
                    backend=self.oracle_backend, obs=self.obs)
                self._owns_pool = True
                broker.pool = self._oracle_pool
            elif self._oracle_pool is not None:
                broker.pool = self._oracle_pool
        # guards the proxy cache, stats counters, and index mutation
        # (crack_with) so concurrent sessions can share one engine; always
        # acquired before the broker's lock, never after
        self._lock = threading.RLock()
        self._on_crack: List[Callable[[int], None]] = []
        self.stats: Dict[str, int] = {
            "propagation_computes": 0,
            "proxy_cache_hits": 0,
            "proxy_device_computes": 0,
            "proxy_flight_waits": 0,
            "label_fresh": 0,
            "label_cache_hits": 0,
            "cracked_records": 0,
        }
        # eager device-memory release on crack; correctness relies only on
        # the per-call version check inside ResidentIndexState.propagate
        self._on_crack.append(lambda added: self.resident.invalidate())

    # -- oracle broker -------------------------------------------------------
    def _annotate(self, ids: np.ndarray):
        if self.workload is None:
            raise ValueError("labeling records requires a workload "
                             "(the target-DNN oracle)")
        return self.workload.target_dnn_batch(np.asarray(ids, np.int64))

    @property
    def broker(self) -> OracleBroker:
        """The batched, deduplicating seam to ``workload.target_dnn_batch``;
        its cache is the engine's shared oracle-label cache.  With
        ``oracle_replicas > 1`` the broker's flushes are sharded across an
        :class:`~repro.core.oracle_pool.OraclePool` the engine owns."""
        with self._lock:
            if self._broker is None:
                if self._oracle_pool is None and self.oracle_replicas > 1:
                    self._oracle_pool = OraclePool(
                        self._annotate, n_replicas=self.oracle_replicas,
                        backend=self.oracle_backend, obs=self.obs)
                    self._owns_pool = True
                self._broker = OracleBroker(self._annotate,
                                            max_batch=self.max_oracle_batch,
                                            pool=self._oracle_pool,
                                            obs=self.obs)
            return self._broker

    @property
    def oracle_pool(self) -> Optional[OraclePool]:
        """The replica pool behind the broker, if sharding is on."""
        with self._lock:
            return self._oracle_pool

    def set_oracle_replicas(self, n: int,
                            backend: Optional[str] = None) -> None:
        """Resize the target-DNN replica pool (the ``oracle_replicas`` knob
        at run time; sessions with their own setting call this), optionally
        switching the replica backend at the same time.  Safe between
        flushes: an in-flight flush keeps the pool it started with
        (``broker._label`` reads ``broker.pool`` once)."""
        n = max(1, int(n))
        with self._lock:
            backend = self.oracle_backend if backend is None else str(backend)
            if (n == self.oracle_replicas and backend == self.oracle_backend
                    and (n == 1 or self._oracle_pool is not None)):
                return
            old = self._oracle_pool if self._owns_pool else None
            pool = (OraclePool(self._annotate, n_replicas=n, backend=backend,
                               obs=self.obs)
                    if n > 1 else None)
            self.oracle_replicas = n
            self.oracle_backend = backend
            self._oracle_pool = pool
            self._owns_pool = pool is not None
            if self._broker is not None:
                self._broker.pool = pool
        if old is not None:
            old.close()

    def close(self) -> None:
        """Detach and stop an engine-owned replica pool (idempotent).  The
        broker falls back to inline labeling, so the engine stays usable —
        the serving layer calls this on shutdown."""
        with self._lock:
            pool = self._oracle_pool if self._owns_pool else None
            self._oracle_pool = None
            self._owns_pool = False
            self.oracle_replicas = 1
            if self._broker is not None:
                self._broker.pool = None
        if pool is not None:
            pool.close()

    def set_obs(self, obs) -> None:
        """Adopt an :class:`~repro.obs.ObsScope` after construction (the
        server wires a per-workload scope into engines registered before it
        existed) and push it into the broker/pool/resident the engine
        already built."""
        self.obs = obs if obs is not None else NULL_SCOPE
        with self._lock:
            broker, pool = self._broker, self._oracle_pool
        if broker is not None:
            broker.set_obs(self.obs)
        if pool is not None:
            pool.set_obs(self.obs)
        self.resident.set_obs(self.obs)

    def add_stats(self, **deltas: int) -> None:
        """Atomically bump engine counters (dict ``+=`` is not)."""
        with self._lock:
            for k, v in deltas.items():
                self.stats[k] += v

    def on_crack(self, callback: Callable[[int], None]) -> None:
        """Register a listener called with the number of new representatives
        after every index-mutating crack (a persistent label store re-stamps
        the index version it is cached against)."""
        with self._lock:
            self._on_crack.append(callback)

    @property
    def _label_cache(self) -> Dict[int, Any]:
        return self.broker.cache

    # -- proxy scores (memoized propagation) ---------------------------------
    def _score_fn(self, score: Union[str, Callable]) -> Callable:
        if isinstance(score, str):
            if self.workload is None:
                raise ValueError("string `score` needs a workload to resolve "
                                 f"{score!r} against")
            fn = getattr(self.workload, score, None)
            if fn is None or not callable(fn):
                raise ValueError(f"workload {getattr(self.workload, 'name', '?')} "
                                 f"has no scoring method {score!r}")
            return fn
        if callable(score):
            return score
        raise TypeError(f"score must be a str or callable, got {type(score)}")

    def _cache_key(self, score, score_key=None):
        # strings are stable across sessions; bound methods hash by
        # (__func__, __self__) so repeated getattr lookups hit the same entry;
        # lambdas memoize by identity (conservative but correct).
        return score_key if score_key is not None else score

    def proxy_scores(self, score: Union[str, Callable], mode: str = "numeric",
                     n_classes: Optional[int] = None,
                     score_key: Optional[str] = None) -> np.ndarray:
        """Propagated proxy scores for ``score``, memoized per (score, mode).

        The cache is invalidated whenever the index version changes (i.e.
        after cracking), so callers always see post-crack scores.

        Propagation is **single-flight**: the first caller of a key computes
        (outside the engine lock — on the device-resident fast path when the
        engine's :class:`~repro.core.resident.ResidentIndexState` is enabled,
        else the float64 host path), concurrent callers of the *same* key
        park on its flight and reuse the result as a cache hit, and callers
        of *different* keys propagate in parallel instead of racing the
        lock.  A crack landing mid-compute discards the stale result and the
        owner recomputes against the new index.
        """
        if mode not in PROPAGATION_MODES:
            raise ValueError(f"unknown propagation mode {mode!r}; "
                             f"expected one of {PROPAGATION_MODES}")
        if mode == "categorical" and n_classes is None:
            raise ValueError("categorical propagation requires n_classes")
        fn = self._score_fn(score)  # resolve early: never strand waiters on
        key = (self._cache_key(score, score_key), mode, n_classes)  # bad specs
        while True:
            with self._lock:
                if self._proxy_cache_version != self.index.version:
                    self._proxy_cache.clear()
                    self._proxy_cache_version = self.index.version
                if key in self._proxy_cache:
                    self.stats["proxy_cache_hits"] += 1
                    return self._proxy_cache[key]
                flight = self._proxy_flights.get(key)
                if flight is None:
                    flight = threading.Event()
                    self._proxy_flights[key] = flight
                    owner = True
                    # crack replaces these wholesale (never in place), so the
                    # refs are a consistent snapshot for `version`
                    version = self.index.version
                    annotations = self.index.annotations
                    topk_ids, topk_d2 = self.index.topk_ids, self.index.topk_d2
                else:
                    owner = False
                    self.stats["proxy_flight_waits"] += 1
            if not owner:
                with trace_span("proxy.flight_wait", mode=mode):
                    flight.wait()
                continue      # cache hit, or recompute if the owner lost
            try:
                with trace_span("proxy.materialize", mode=mode) as sp:
                    rep_scores = np.asarray([fn(a) for a in annotations],
                                            np.float64)
                    out, source = self._propagate(
                        rep_scores, topk_ids, topk_d2, mode, n_classes,
                        version)
                    sp.set(source=source, n=len(out))
            except BaseException:
                with self._lock:
                    self._proxy_flights.pop(key, None)
                flight.set()  # waiters retry, become owner, re-raise
                raise
            with self._lock:
                self._proxy_flights.pop(key, None)
                flight.set()
                if self.index.version == version:
                    self.stats["propagation_computes"] += 1
                    self._proxy_cache[key] = out
                    return out
            # cracked mid-compute: result is stale, go around again

    def _propagate(self, rep_scores: np.ndarray, topk_ids: np.ndarray,
                   topk_d2: np.ndarray, mode: str, n_classes: Optional[int],
                   version: int):
        """One propagation over a snapshot: fused device call when resident
        scoring is on (falling back on a mid-compute crack or device error),
        float64 numpy otherwise.  Returns ``(scores, source)`` with source
        in {"device", "host"} for span attribution."""
        if self.resident.enabled:
            out = self.resident.propagate(rep_scores, mode, version=version,
                                          n_classes=n_classes)
            if out is not None:
                self.add_stats(proxy_device_computes=1)
                return out, "device"
        if mode == "numeric":
            return propagation.propagate_numeric(
                rep_scores, topk_ids, topk_d2), "host"
        if mode == "top1":
            return propagation.propagate_top1(
                rep_scores, topk_ids, topk_d2), "host"
        return propagation.propagate_categorical(
            rep_scores, topk_ids, topk_d2,
            n_classes=n_classes).astype(np.float64), "host"

    # -- oracle with the shared label cache ----------------------------------
    def _make_oracle(self, score_fn: Callable, reuse: bool,
                     account: OracleAccount,
                     checkpoint: Optional[Callable[[], None]] = None,
                     slice_size: Optional[int] = None
                     ) -> Callable[[np.ndarray], np.ndarray]:
        """Wrap the broker for one query: blocking calls return scores.
        Sessions enqueue ahead of execution through the broker's futures API
        (``request``/``prefetch``) against the same account.

        ``checkpoint`` is the scheduler's preemption hook: it is called at
        every oracle interaction and between ``slice_size``-id slices of
        large fetches, and may block (the serving scheduler parks a
        preempted query there while higher-priority work runs).  Slicing
        only inserts scheduling points — the same ids reach the broker in
        the same order against the same account, so fresh/cached accounting
        and labels are byte-identical to the unchunked path."""
        broker = self.broker
        step = int(slice_size) if slice_size else self.max_oracle_batch

        def call(ids) -> np.ndarray:
            ids = np.asarray(ids, np.int64).ravel()
            if checkpoint is None:
                anns: List[Any] = broker.fetch(ids, account=account,
                                               reuse=reuse)
            else:
                checkpoint()
                if len(ids) <= step:
                    anns = broker.fetch(ids, account=account, reuse=reuse)
                else:
                    anns = []
                    for k, start in enumerate(range(0, len(ids), step)):
                        if k:
                            checkpoint()
                        anns.extend(broker.fetch(ids[start:start + step],
                                                 account=account, reuse=reuse))
            return np.asarray([score_fn(a) for a in anns], np.float64)

        return call

    # -- plan ----------------------------------------------------------------
    def plan(self, spec: QuerySpec) -> QueryPlan:
        """Compile and validate a spec without spending any oracle budget."""
        executor = get_executor(spec.kind)
        executor.validate(spec)
        if isinstance(spec.score, str) and self.workload is not None:
            self._score_fn(spec.score)  # fail fast on unknown score names
        trace: List[str] = [f"kind={spec.kind}"]
        if spec.proxy is not None:
            mode = "external"
            trace.append("proxy=external (propagation skipped)")
        else:
            if spec.score is None:
                raise ValueError(f"{spec.kind} spec needs `score` or `proxy`")
            mode = spec.propagation or executor.default_propagation
            if mode not in PROPAGATION_MODES:
                raise ValueError(f"unknown propagation mode {mode!r}")
            if mode == "categorical" and spec.n_classes is None:
                raise ValueError("categorical propagation requires n_classes")
            chosen = "spec" if spec.propagation else "auto"
            trace.append(f"propagation={mode} ({chosen})")
        clip01 = executor.clip01
        if clip01:
            trace.append("proxy clipped to [0,1]")
        crack = self.crack_by_default if spec.crack is None else spec.crack
        trace.append(f"crack={'on' if crack else 'off'}, "
                     f"label_reuse={'on' if spec.reuse_labels else 'off'}")
        key = None if spec.score is None else \
            self._cache_key(spec.score, spec.score_key)
        return QueryPlan(spec=spec, kind=spec.kind, executor=executor,
                         propagation=mode, clip01=clip01, score_key=key,
                         crack=crack, trace=trace)

    # -- execute -------------------------------------------------------------
    def proxy_for(self, plan: QueryPlan) -> np.ndarray:
        """The proxy array ``plan`` will execute against (external override,
        or memoized propagation, clipped when the kind requires it)."""
        spec = plan.spec
        if spec.proxy is not None:
            proxy = np.asarray(spec.proxy, np.float64)
        else:
            proxy = self.proxy_scores(spec.score, plan.propagation,
                                      n_classes=spec.n_classes,
                                      score_key=spec.score_key)
        if plan.clip01:
            proxy = np.clip(proxy, 0.0, 1.0)
        return proxy

    def execute(self, spec_or_plan: Union[QuerySpec, QueryPlan],
                account: Optional[OracleAccount] = None,
                checkpoint: Optional[Callable[[], None]] = None,
                slice_size: Optional[int] = None) -> QueryResult:
        """Run one query.  ``account`` carries the oracle accounting; a
        session passes one per spec (pre-charged by its prefetch phase) so
        per-spec fresh/cached counts stay exact under cross-spec dedup.
        ``checkpoint``/``slice_size`` make execution preemptible at oracle-
        slice boundaries (see :meth:`_make_oracle`) without changing labels
        or accounting."""
        plan = (spec_or_plan if isinstance(spec_or_plan, QueryPlan)
                else self.plan(spec_or_plan))
        # each execution owns its trace: re-executing a caller-held plan must
        # not mutate it (or earlier results that share it)
        plan = dataclasses.replace(plan, trace=list(plan.trace))
        spec = plan.spec
        proxy = self.proxy_for(plan)

        if self.workload is None:
            raise ValueError("executing queries requires a workload "
                             "(the target-DNN oracle)")
        score_fn = (self._score_fn(spec.score) if spec.score is not None
                    else None)
        if score_fn is None:
            raise ValueError(f"{spec.kind} spec needs `score` to build the "
                             "target-DNN oracle")
        acct = account if account is not None else \
            self.broker.account(name=spec.kind)
        fresh0, cached0 = acct.fresh, acct.cached
        oracle = self._make_oracle(score_fn, spec.reuse_labels, acct,
                                   checkpoint=checkpoint,
                                   slice_size=slice_size)

        with trace_span("spec.execute", kind=plan.kind) as sp:
            raw = plan.executor.execute(plan, proxy, oracle)
            summary = plan.executor.summarize(raw)
            sp.set(fresh=acct.fresh - fresh0, cached=acct.cached - cached0)

        n_cracked = 0
        if plan.crack and acct.labeled:
            with trace_span("engine.crack") as sp:
                n_cracked = self.crack_with(acct.labeled)
                sp.set(added=n_cracked)
            plan.trace.append(f"cracked {n_cracked} new reps into the index")

        # session-prefetched labels were already folded into engine.stats by
        # the session; only the execution delta lands here
        self.add_stats(label_fresh=acct.fresh - fresh0,
                       label_cache_hits=acct.cached - cached0)
        cost = {
            "target_dnn_s": acct.fresh * schema_lib.TARGET_DNN_COST_S,
            "crack_distance_s": (n_cracked * self.index.n_records
                                 * schema_lib.DIST_COST_S),
        }
        return QueryResult(
            kind=plan.kind,
            estimate=summary.get("estimate"),
            selected=summary.get("selected"),
            threshold=summary.get("threshold"),
            ci_half_width=summary.get("ci_half_width"),
            n_invocations=int(summary["n_invocations"]),
            n_oracle_fresh=acct.fresh,
            n_oracle_cached=acct.cached,
            n_cracked=n_cracked,
            cost=cost,
            plan=plan,
            raw=raw,
        )

    # -- cracking feedback loop ----------------------------------------------
    def crack_with(self, ids) -> int:
        """Fold target-DNN annotations for ``ids`` into the index (§3.3),
        reusing cached labels where available.  Returns the number of *new*
        representatives added; the proxy cache invalidates automatically via
        the index version."""
        ids = np.unique(np.asarray(list(ids), np.int64))
        if len(ids) == 0:
            return 0
        # one crack at a time: index mutation and the proxy-cache version
        # check must not interleave with a concurrent session's propagation
        with self._lock:
            missing = np.asarray(
                [i for i in ids if int(i) not in self._label_cache], np.int64)
            if len(missing):
                # through the broker: microbatched and counted like every
                # other oracle call
                self.broker.fetch(missing)
                self.stats["label_fresh"] += len(missing)
            before = self.index.n_reps
            self.index.crack(ids, [self._label_cache[int(i)] for i in ids])
            added = self.index.n_reps - before
            self.stats["cracked_records"] += added
            callbacks = list(self._on_crack) if added else []
        # listeners run OUTSIDE the engine lock: a label store's re-stamp
        # compacts its whole snapshot, which must not stall every concurrent
        # session on self._lock (they only contend on the store's own lock)
        for cb in callbacks:
            cb(added)
        return added
