"""Triplet-loss training of the embedding DNN (paper §3.1).

* ``mine_triplets``: builds (anchor, positive, negative) index triples from
  target-DNN annotations of the FPF-mined training set, using the workload's
  ``IsClose`` heuristic — "close" under the induced schema.
* ``triplet_loss``: the paper's margin hinge on ||phi(a)-phi(p)|| vs
  ||phi(a)-phi(n)||.
* ``train_embedder``: AdamW on mini-batches of triples; in-batch semi-hard
  selection optional.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embedder import EmbedderConfig, embed
from repro.models.common import PyTree
from repro.optim.adamw import OptimizerConfig, adamw_update, init_opt_state


@dataclass(frozen=True)
class TripletConfig:
    margin: float = 1.0
    batch: int = 256
    steps: int = 400
    lr: float = 1e-3
    max_triplets: int = 200_000
    seed: int = 0


def triplet_loss(emb_a: jax.Array, emb_p: jax.Array, emb_n: jax.Array,
                 margin: float) -> jax.Array:
    d_ap = jnp.linalg.norm(emb_a - emb_p, axis=-1)
    d_an = jnp.linalg.norm(emb_a - emb_n, axis=-1)
    return jnp.mean(jnp.maximum(0.0, margin + d_ap - d_an))


def mine_triplets(train_ids: np.ndarray, is_close: Callable[[int, int], bool],
                  rng: np.random.Generator,
                  max_triplets: int = 200_000) -> np.ndarray:
    """Exhaustive close/far split over the annotated set -> (T, 3) indices."""
    n = len(train_ids)
    close_sets = [[] for _ in range(n)]
    far_sets = [[] for _ in range(n)]
    for i in range(n):
        for j in range(i + 1, n):
            if is_close(int(train_ids[i]), int(train_ids[j])):
                close_sets[i].append(j)
                close_sets[j].append(i)
            else:
                far_sets[i].append(j)
                far_sets[j].append(i)
    triples = []
    for i in range(n):
        if not close_sets[i] or not far_sets[i]:
            continue
        k = min(len(close_sets[i]), 32)
        pos = rng.choice(close_sets[i], size=k, replace=False)
        neg = rng.choice(far_sets[i], size=k, replace=True)
        for p, ng in zip(pos, neg):
            triples.append((i, int(p), int(ng)))
    rng.shuffle(triples)
    out = np.asarray(triples[:max_triplets], np.int32)
    if len(out) == 0:
        out = np.zeros((0, 3), np.int32)
    return out


def train_embedder(params: PyTree, features: np.ndarray, triples: np.ndarray,
                   ecfg: EmbedderConfig, tcfg: TripletConfig) -> Tuple[PyTree, list]:
    """Returns (trained params, loss history).  ``features`` are the training
    records' raw features (indexed by the triples)."""
    if len(triples) == 0:
        return params, []
    opt = OptimizerConfig(peak_lr=tcfg.lr, min_lr=tcfg.lr * 0.1,
                          warmup_steps=20, total_steps=tcfg.steps,
                          weight_decay=0.0, clip_norm=1.0)
    state = init_opt_state(params, opt)
    feats = jnp.asarray(features)

    def loss_fn(p, idx):
        f = feats[idx.reshape(-1)]
        e = embed(p, f, ecfg).reshape(-1, 3, ecfg.embed_dim)
        return triplet_loss(e[:, 0], e[:, 1], e[:, 2], tcfg.margin)

    @jax.jit
    def step(p, s, idx):
        loss, grads = jax.value_and_grad(loss_fn)(p, idx)
        p, s, _ = adamw_update(p, grads, s, opt)
        return p, s, loss

    rng = np.random.default_rng(tcfg.seed)
    history = []
    for it in range(tcfg.steps):
        sel = rng.integers(0, len(triples), size=min(tcfg.batch, len(triples)))
        p_new, state, loss = step(params, state, jnp.asarray(triples[sel]))
        params = p_new
        history.append(float(loss))
    return params, history


def population_triplet_loss(embeddings: np.ndarray, dist_fn, ids: np.ndarray,
                            m_radius: float, margin: float,
                            n_samples: int = 2000, seed: int = 0) -> float:
    """Monte-Carlo estimate of L(phi; M, m) (Eq. 1) over annotated ids —
    used by the theory validators and EXPERIMENTS.md."""
    rng = np.random.default_rng(seed)
    n = len(ids)
    total, used = 0.0, 0
    for _ in range(n_samples):
        a = int(rng.integers(n))
        d_all = np.array([dist_fn(int(ids[a]), int(ids[j])) for j in range(n)])
        close = np.where((d_all < m_radius) & (np.arange(n) != a))[0]
        far = np.where(d_all >= m_radius)[0]
        if len(close) == 0 or len(far) == 0:
            continue
        p = int(rng.choice(close))
        ng = int(rng.choice(far))
        d_ap = np.linalg.norm(embeddings[a] - embeddings[p])
        d_an = np.linalg.norm(embeddings[a] - embeddings[ng])
        total += max(0.0, margin + d_ap - d_an)
        used += 1
    return total / max(used, 1)
