"""Oracle replica pool: N target-DNN workers behind one flush.

TASTI prices queries in target-DNN invocations, and once the index makes
proxy scores cheap the wall-clock bottleneck is how fast those invocations
can be *driven* (BlazeIt/learned-index lesson: scale out the expensive
model, not the cheap index).  :class:`OraclePool` is the scale-out seam the
:class:`~repro.core.broker.OracleBroker` dispatches microbatches to:

* **replicas** — ``n_replicas`` worker threads, each wrapping one target-DNN
  callable.  By default every replica shares the same ``annotate`` callable
  (it must then be thread-safe — the synthetic workloads' ``target_dnn_batch``
  is pure reads); pass ``replicas=[fn0, fn1, ...]`` for distinct instances
  (separate devices, processes behind RPC, or fault-injection doubles);
* **size-aware sharding** — a flush of ``n`` ids splits into sub-batches of
  ``min(max_batch, ceil(n / (n_replicas * oversub)))`` ids, so small flushes
  still fan out across every replica and large ones keep well-shaped
  microbatches;
* **work stealing** — sub-batches go into one shared queue that idle
  replicas pull from, so a slow replica never straggles the flush: the fast
  ones drain its share;
* **retry on a surviving replica** — a sub-batch whose replica raised is
  re-queued for the others; only when *every* replica has failed it does the
  flush fail (and the broker's reservation scheme then restores the ids to
  pending, leaving all accounting untouched);
* **in-order reassembly is the caller's** — :meth:`run` returns a plain
  ``{id: annotation}`` dict; the broker publishes results in its own pending
  order, so label streams (and the :class:`~repro.serve.store.LabelStore`
  journal) are identical to the single-oracle path.

The pool is intentionally stdlib-thread based, matching the serve layer: the
target DNN is assumed to release the GIL (real inference does; the synthetic
oracles are trivial), so replicas genuinely overlap.
"""
from __future__ import annotations

import queue
import threading
import time
from math import ceil
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import NULL_SCOPE
from repro.obs.trace import add_timed_span

_STOP = object()

# weight of the newest sub-batch in the per-replica latency EWMA; ~0.2
# averages over the last ~5 sub-batches — reactive enough for
# latency-aware sizing, stable enough to ignore one-off stalls
_EWMA_ALPHA = 0.2


class OraclePoolError(RuntimeError):
    """A sub-batch failed on every replica (the flush could not complete)."""


class OraclePoolClosed(RuntimeError):
    """:meth:`OraclePool.run` was called on a closed pool (e.g. a concurrent
    replica-count resize swapped it out); the caller should retry against
    its current pool or label inline."""


class _FlushJob:
    """One :meth:`OraclePool.run` call: its sub-batches, results, and the
    condition its caller blocks on.  Workers of several concurrent jobs share
    the pool's task queue; each job completes independently."""

    __slots__ = ("chunks", "tried", "results", "batches", "remaining",
                 "error", "cond", "timings")

    def __init__(self, chunks: List[np.ndarray]):
        self.chunks = chunks
        # per-chunk set of replica indices that already failed it
        self.tried: List[set] = [set() for _ in chunks]
        self.results: Dict[int, Any] = {}
        self.batches = 0                 # successful annotate() calls
        self.remaining = len(chunks)
        self.error: Optional[BaseException] = None
        self.cond = threading.Condition()
        # (replica, t0, t1, n_ids) per completed sub-batch — the caller
        # turns these into trace spans after the job finishes
        self.timings: List[Tuple[int, float, float, int]] = []


class OraclePool:
    """A pool of target-DNN replica workers.

        pool = OraclePool(workload.target_dnn_batch, n_replicas=4)
        labels, batches = pool.run(ids, max_batch=64)   # {id: annotation}
        pool.close()

    ``oversub`` controls sharding granularity: each flush is split into about
    ``n_replicas * oversub`` sub-batches (capped at ``max_batch`` ids each)
    so work stealing has slack to route around a slow replica.
    """

    def __init__(self, annotate: Optional[Callable] = None,
                 n_replicas: int = 2, *,
                 replicas: Optional[Sequence[Callable]] = None,
                 oversub: int = 2, name: str = "oracle-replica",
                 obs=None):
        if replicas is None:
            if annotate is None:
                raise ValueError("OraclePool needs `annotate` or `replicas`")
            if n_replicas <= 0:
                raise ValueError(
                    f"n_replicas must be positive, got {n_replicas}")
            replicas = [annotate] * int(n_replicas)
        replicas = list(replicas)
        if not replicas:
            raise ValueError("OraclePool needs at least one replica")
        self.n_replicas = len(replicas)
        self.oversub = max(1, int(oversub))
        self._tasks: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)  # signals _active == 0
        self._active = 0                              # run() calls in flight
        self._closed = False
        self.stats: Dict[str, Any] = {
            "flushes": 0,        # run() calls
            "dispatched": 0,     # sub-batches enqueued
            "batches": 0,        # successful annotate() calls
            "retries": 0,        # sub-batches re-queued after a failure
            "failures": 0,       # annotate() calls that raised
            "per_replica": [0] * self.n_replicas,          # completed batches
            "per_replica_failures": [0] * self.n_replicas,
            # sub-batches a replica worked beyond its fair share of a job
            # (it stole them from a slower sibling's backlog)
            "steals": 0,
            # EWMA of per-sub-batch wall time, per replica — the signal the
            # ROADMAP's latency-aware sub-batch sizing will consume
            "per_replica_latency_ewma_s": [0.0] * self.n_replicas,
        }
        self.set_obs(obs)
        self._threads = [
            threading.Thread(target=self._worker, args=(ridx, fn),
                             name=f"{name}-{ridx}", daemon=True)
            for ridx, fn in enumerate(replicas)]
        for t in self._threads:
            t.start()

    def set_obs(self, obs) -> None:
        """Attach an :class:`~repro.obs.ObsScope`; resolves the sub-batch
        latency histogram once (workers observe it lock-free on the
        registry side)."""
        self._obs = obs if obs is not None else NULL_SCOPE
        self._h_sub = self._obs.histogram(
            "oracle_subbatch_latency_seconds",
            "wall time of one replica sub-batch (annotate call)")

    # -- sharding ------------------------------------------------------------
    def chunk_size(self, n: int, max_batch: int) -> int:
        """Sub-batch size for a flush of ``n`` ids: small enough that every
        replica gets ~``oversub`` batches (stealing slack), never larger than
        ``max_batch``."""
        per = ceil(n / (self.n_replicas * self.oversub))
        return max(1, min(int(max_batch), per))

    # -- the one entry point -------------------------------------------------
    def run(self, ids, max_batch: int) -> Tuple[Dict[int, Any], int]:
        """Label ``ids`` across the replicas; blocks until every sub-batch
        completed (or failed everywhere).  Returns ``({id: annotation},
        n_batches)``.  Raises :class:`OraclePoolError` if any sub-batch
        failed on all replicas — the caller's ids are then untouched (no
        partial publish)."""
        with self._lock:
            if self._closed:
                raise OraclePoolClosed("OraclePool is closed")
            self.stats["flushes"] += 1
            self._active += 1
        try:
            ids = np.asarray(ids, np.int64).ravel()
            if len(ids) == 0:
                return {}, 0
            size = self.chunk_size(len(ids), max_batch)
            chunks = [ids[s:s + size] for s in range(0, len(ids), size)]
            job = _FlushJob(chunks)
            with self._lock:
                self.stats["dispatched"] += len(chunks)
            for ci in range(len(chunks)):
                self._tasks.put((job, ci))
            with job.cond:
                while job.remaining and job.error is None:
                    job.cond.wait()
                if job.error is not None:
                    raise job.error
                timings = list(job.timings)
                results, batches = dict(job.results), job.batches
            # post-completion bookkeeping: replica sub-batch spans on the
            # caller's trace, and steal counting (work a replica did beyond
            # its fair 1/n share of this job's sub-batches)
            per_job = [0] * self.n_replicas
            for ridx, t0, t1, n in timings:
                per_job[ridx] += 1
                add_timed_span("oracle.subbatch", t0, t1,
                               replica=ridx, n=n)
            fair = ceil(len(chunks) / self.n_replicas)
            stolen = sum(max(0, c - fair) for c in per_job)
            if stolen:
                with self._lock:
                    self.stats["steals"] += stolen
            return results, batches
        finally:
            with self._lock:
                self._active -= 1
                if self._active == 0:
                    self._idle.notify_all()

    # -- workers -------------------------------------------------------------
    def _worker(self, ridx: int, annotate: Callable) -> None:
        while True:
            task = self._tasks.get()
            if task is _STOP:
                return
            job, ci = task
            with job.cond:
                dead = job.error is not None
                skip = ridx in job.tried[ci]
            if dead:
                continue  # run() already raised; drop the stragglers
            if skip:
                # this replica already failed this sub-batch: hand it back
                # for a survivor and back off so one can pick it up (the
                # survivors may all be mid-annotate; 10ms bounds the spin
                # without delaying the handoff noticeably)
                self._tasks.put(task)
                time.sleep(0.01)
                continue
            chunk = job.chunks[ci]
            t0 = time.perf_counter()
            try:
                anns = annotate(chunk)
                if len(anns) != len(chunk):
                    raise OraclePoolError(
                        f"replica {ridx} returned {len(anns)} annotations "
                        f"for {len(chunk)} ids")
            except Exception as e:  # noqa: BLE001 - replica fault barrier
                with self._lock:
                    self.stats["failures"] += 1
                    self.stats["per_replica_failures"][ridx] += 1
                with job.cond:
                    job.tried[ci].add(ridx)
                    if len(job.tried[ci]) >= self.n_replicas:
                        job.error = OraclePoolError(
                            f"sub-batch of {len(chunk)} ids failed on all "
                            f"{self.n_replicas} replicas "
                            f"(last: {type(e).__name__}: {e})")
                        job.cond.notify_all()
                        continue
                with self._lock:
                    self.stats["retries"] += 1
                self._tasks.put(task)
                continue
            t1 = time.perf_counter()
            with job.cond:
                for i, a in zip(chunk, anns):
                    job.results[int(i)] = a
                job.batches += 1
                job.remaining -= 1
                job.timings.append((ridx, t0, t1, len(chunk)))
                if job.remaining == 0:
                    job.cond.notify_all()
            with self._lock:
                self.stats["batches"] += 1
                self.stats["per_replica"][ridx] += 1
                ewma = self.stats["per_replica_latency_ewma_s"]
                prev = ewma[ridx]
                ewma[ridx] = (t1 - t0) if prev == 0.0 else \
                    prev + _EWMA_ALPHA * ((t1 - t0) - prev)
            self._h_sub.observe(t1 - t0)

    # -- lifecycle -----------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """A consistent copy of ``stats`` (lists copied too)."""
        with self._lock:
            out = dict(self.stats)
            out["per_replica"] = list(out["per_replica"])
            out["per_replica_failures"] = list(out["per_replica_failures"])
            out["per_replica_latency_ewma_s"] = [
                round(v, 6) for v in out["per_replica_latency_ewma_s"]]
            out["n_replicas"] = self.n_replicas
            return out

    def close(self, timeout: float = 10.0) -> None:
        """Stop the workers (idempotent).  Drain-safe: waits for in-flight
        :meth:`run` calls to finish before the stop sentinels are enqueued,
        so a retry re-queued by a concurrent flush can never land behind a
        sentinel and strand the flush.  New :meth:`run` calls fail fast
        (the broker falls back to its current pool / inline labeling)."""
        deadline = time.monotonic() + timeout
        with self._idle:
            if self._closed:
                return
            self._closed = True
            while self._active:
                remaining = deadline - time.monotonic()
                if remaining <= 0 or not self._idle.wait(timeout=remaining):
                    break
        for _ in self._threads:
            self._tasks.put(_STOP)
        for t in self._threads:
            t.join(timeout=max(0.1, deadline - time.monotonic()))

    def __enter__(self) -> "OraclePool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
