"""Oracle replica pool: N target-DNN workers behind one flush.

TASTI prices queries in target-DNN invocations, and once the index makes
proxy scores cheap the wall-clock bottleneck is how fast those invocations
can be *driven* (BlazeIt/learned-index lesson: scale out the expensive
model, not the cheap index).  :class:`OraclePool` is the scale-out seam the
:class:`~repro.core.broker.OracleBroker` dispatches microbatches to:

* **two backends** — ``backend="thread"`` (the default) runs each replica as
  an in-process worker thread: right when the target DNN releases the GIL
  (jax/XLA dispatch, real inference, anything I/O-bound).
  ``backend="process"`` forks each replica into its own worker process fed
  over a pipe, so a *compute-bound* oracle that holds the GIL (pure-Python
  or numpy-scalar hot loops) still scales near-linearly — the
  ``compute_bound`` leg of ``benchmarks/oracle_scaling.py`` is the gate the
  thread backend cannot pass.  Id arrays cross as raw dtype/shape/bytes (or
  spooled ``.npy`` files with ``handoff="npz"``), never element pickles;
  labels come back the same way when they are plain ints/floats and as exact
  pickles otherwise, so annotations round-trip type-identically;
* **latency-aware sub-batch sizing** — sub-batches are carved from the flush
  *at dispatch time*, sized per replica by its EWMA labels/s: a replica
  measuring half the best rate gets half-size slices, so heterogeneous or
  degraded replicas stop straggling the flush instead of being handed the
  same fixed ``ceil(n / (replicas * oversub))`` share.  ``max_batches``
  additionally caps each replica's slice individually (heterogeneous
  replicas with different memory/batch limits);
* **work sharing** — every replica's driver pulls the next slice from the
  same flush cursor, so fast replicas naturally work more of the flush (the
  work-stealing behavior of the fixed-chunk design, without a chunk queue);
* **retry on a surviving replica** — a slice whose replica raised is
  re-queued for the others; a replica whose *process died* (crash, kill) is
  marked dead and its slice retried on survivors; only when every live
  replica has failed a slice does the flush fail (and the broker's
  reservation scheme then restores the ids to pending, leaving all
  accounting untouched);
* **in-order reassembly is the caller's** — :meth:`run` returns a plain
  ``{id: annotation}`` dict; the broker publishes results in its own pending
  order, so label streams (and the :class:`~repro.serve.store.LabelStore`
  journal) are identical to the single-oracle path at any replica count and
  on either backend.

The process backend forks (``mp_context="fork"``), so replica callables and
workload state are inherited without pickling; use it for CPU-bound oracles
only — replicas that wrap device handles or threads should stay on the
thread backend (see docs/runbook.md for the decision table).
"""
from __future__ import annotations

import os
import queue
import shutil
import tempfile
import threading
import time
import uuid
from collections import deque
from math import ceil
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.obs import NULL_SCOPE
from repro.obs.trace import add_timed_span

_STOP = object()
_BLOCKED = object()

BACKENDS = ("thread", "process")
HANDOFFS = ("pipe", "npz")

# weight of the newest sub-batch in the per-replica EWMAs; ~0.2 averages
# over the last ~5 sub-batches — reactive enough for latency-aware sizing,
# stable enough to ignore one-off stalls
_EWMA_ALPHA = 0.2


class OraclePoolError(RuntimeError):
    """A sub-batch failed on every live replica (the flush could not
    complete)."""


class OraclePoolClosed(RuntimeError):
    """:meth:`OraclePool.run` was called on a closed pool (e.g. a concurrent
    replica-count resize swapped it out); the caller should retry against
    its current pool or label inline."""


class _ReplicaDead(RuntimeError):
    """A process replica's worker died mid-call (crash/kill); internal —
    the driver converts it into retry-on-survivors."""


# ---------------------------------------------------------------------------
# array / label handoff (process backend)
# ---------------------------------------------------------------------------
def _encode_array(arr: np.ndarray, handoff: str, spool: str):
    """An ndarray as pipe payload: raw dtype/shape/bytes, or a spooled
    ``.npy`` file handed off by path (``handoff="npz"``)."""
    if handoff == "npz":
        path = os.path.join(spool, f"{uuid.uuid4().hex}.npy")
        np.save(path, arr, allow_pickle=False)
        return ("npy", path)
    return ("raw", arr.dtype.str, arr.shape, arr.tobytes())


def _decode_array(payload) -> np.ndarray:
    if payload[0] == "npy":
        arr = np.load(payload[1], allow_pickle=False)
        try:
            os.unlink(payload[1])
        except OSError:
            pass
        return arr
    _, dtype, shape, buf = payload
    return np.frombuffer(buf, dtype=np.dtype(dtype)).reshape(shape).copy()


def _encode_labels(anns: List[Any], handoff: str, spool: str):
    """Labels as pipe payload.  Plain int/float labels travel as a raw
    array (reconstructed exactly via ``tolist``); anything else — schema
    dataclasses, dicts, numpy scalars — travels as an exact pickle, so the
    parent-side label values are indistinguishable from an in-process
    call."""
    if anns and all(type(a) is int for a in anns):
        arr = np.asarray(anns, np.int64)
        if arr.shape == (len(anns),) and [int(v) for v in arr] == anns:
            return ("i64", _encode_array(arr, handoff, spool))
    if anns and all(type(a) is float for a in anns):
        return ("f64", _encode_array(np.asarray(anns, np.float64),
                                     handoff, spool))
    return ("obj", anns)


def _decode_labels(payload) -> List[Any]:
    kind = payload[0]
    if kind == "obj":
        return payload[1]
    return _decode_array(payload[1]).tolist()


def _process_worker(conn, annotate: Callable, handoff: str,
                    spool: str) -> None:
    """One replica child: label sub-batches off the pipe until told to
    stop.  Every exception crosses back as data (the fault barrier lives
    here, like the thread backend's try/except around ``annotate``)."""
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            return
        if msg[0] != "task":
            conn.close()
            return
        try:
            ids = _decode_array(msg[1])
            anns = list(annotate(ids))
            if len(anns) != len(ids):
                raise OraclePoolError(
                    f"replica returned {len(anns)} annotations "
                    f"for {len(ids)} ids")
            out = ("ok", _encode_labels(anns, handoff, spool))
        except BaseException as e:  # noqa: BLE001 - replica fault barrier
            out = ("err", f"{type(e).__name__}: {e}")
        try:
            conn.send(out)
        except (EOFError, OSError, BrokenPipeError):
            return


# ---------------------------------------------------------------------------
# replica channels
# ---------------------------------------------------------------------------
class _ThreadReplica:
    """In-process replica: invoke == call the target DNN on this thread."""

    def __init__(self, fn: Callable):
        self._fn = fn

    def invoke(self, ids: np.ndarray) -> List[Any]:
        return self._fn(ids)

    def stop(self, timeout: float) -> None:
        pass

    def is_alive(self) -> bool:
        return True


class _ProcessReplica:
    """Forked replica: one worker process behind a duplex pipe, driven by
    exactly one parent-side driver thread (so the pipe is never shared)."""

    def __init__(self, fn: Callable, name: str, handoff: str, spool: str,
                 ctx) -> None:
        self._conn, child = ctx.Pipe(duplex=True)
        self._handoff = handoff
        self._spool = spool
        self.proc = ctx.Process(target=_process_worker,
                                args=(child, fn, handoff, spool),
                                name=name, daemon=True)
        self.proc.start()
        child.close()  # parent keeps only its end

    def invoke(self, ids: np.ndarray) -> List[Any]:
        try:
            self._conn.send(("task",
                             _encode_array(ids, self._handoff, self._spool)))
            msg = self._conn.recv()
        except (EOFError, OSError, BrokenPipeError) as e:
            raise _ReplicaDead(
                f"replica process {self.proc.pid} died mid-call "
                f"({type(e).__name__})") from e
        if msg[0] == "ok":
            return _decode_labels(msg[1])
        # replica-side exception: the worker survived, the call failed
        raise RuntimeError(msg[1])

    def stop(self, timeout: float) -> None:
        try:
            self._conn.send(("stop",))
        except (OSError, BrokenPipeError, ValueError):
            pass
        self.proc.join(timeout)
        if self.proc.is_alive():
            self.proc.terminate()
            self.proc.join(0.5)
        if self.proc.is_alive():
            self.proc.kill()
            self.proc.join(0.5)
        try:
            self._conn.close()
        except OSError:
            pass

    def is_alive(self) -> bool:
        return self.proc.is_alive()


# ---------------------------------------------------------------------------
# one flush
# ---------------------------------------------------------------------------
class _FlushJob:
    """One :meth:`OraclePool.run` call: a cursor over its id array that
    drivers carve latency-sized slices from, a retry queue for failed
    slices, and the condition its caller blocks on.  Drivers of several
    concurrent jobs share the pool's ticket queue; each job completes
    independently."""

    __slots__ = ("ids", "max_batch", "cursor", "retry", "results", "batches",
                 "outstanding", "error", "cond", "timings")

    def __init__(self, ids: np.ndarray, max_batch: int):
        self.ids = ids
        self.max_batch = max_batch
        self.cursor = 0                       # next uncarved offset
        # failed slices awaiting a survivor: (chunk, {replica indices tried})
        self.retry: "deque[Tuple[np.ndarray, Set[int]]]" = deque()
        self.results: Dict[int, Any] = {}
        self.batches = 0                      # successful annotate() calls
        self.outstanding = len(ids)           # ids not yet labeled
        self.error: Optional[BaseException] = None
        self.cond = threading.Condition()
        # (replica, t0, t1, n_ids) per completed sub-batch — the caller
        # turns these into trace spans after the job finishes
        self.timings: List[Tuple[int, float, float, int]] = []


class OraclePool:
    """A pool of target-DNN replica workers.

        pool = OraclePool(workload.target_dnn_batch, n_replicas=4,
                          backend="process")
        labels, batches = pool.run(ids, max_batch=64)   # {id: annotation}
        pool.close()

    ``oversub`` controls sharding granularity: the *base* slice for a flush
    of ``n`` ids is ``min(max_batch, ceil(n / (n_replicas * oversub)))``,
    so small flushes still fan out across every replica and large ones keep
    well-shaped microbatches.  Once a replica has an EWMA labels/s rate its
    slices scale by ``rate / best_rate`` (a slow replica gets smaller
    slices); ``max_batches=[...]`` caps each replica's slice individually.
    """

    def __init__(self, annotate: Optional[Callable] = None,
                 n_replicas: int = 2, *,
                 replicas: Optional[Sequence[Callable]] = None,
                 backend: str = "thread",
                 oversub: int = 2,
                 max_batches: Optional[Sequence[int]] = None,
                 handoff: str = "pipe",
                 mp_context: Optional[str] = None,
                 name: str = "oracle-replica",
                 obs=None):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; "
                             f"expected one of {BACKENDS}")
        if handoff not in HANDOFFS:
            raise ValueError(f"unknown handoff {handoff!r}; "
                             f"expected one of {HANDOFFS}")
        if replicas is None:
            if annotate is None:
                raise ValueError("OraclePool needs `annotate` or `replicas`")
            if n_replicas <= 0:
                raise ValueError(
                    f"n_replicas must be positive, got {n_replicas}")
            replicas = [annotate] * int(n_replicas)
        replicas = list(replicas)
        if not replicas:
            raise ValueError("OraclePool needs at least one replica")
        self.backend = backend
        self.handoff = handoff
        self.n_replicas = len(replicas)
        self.oversub = max(1, int(oversub))
        if max_batches is not None:
            max_batches = [int(b) for b in max_batches]
            if len(max_batches) != self.n_replicas:
                raise ValueError(
                    f"max_batches has {len(max_batches)} entries for "
                    f"{self.n_replicas} replicas")
            if any(b < 1 for b in max_batches):
                raise ValueError(f"max_batches must be >= 1, got "
                                 f"{max_batches}")
        self._max_batches = max_batches
        self._tasks: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)  # signals _active == 0
        self._active = 0                              # run() calls in flight
        self._jobs: Set[_FlushJob] = set()            # jobs awaiting labels
        self._alive = [True] * self.n_replicas        # process replicas die
        self._closed = False
        self.stats: Dict[str, Any] = {
            "flushes": 0,        # run() calls
            "dispatched": 0,     # sub-batches carved and handed to a replica
            "batches": 0,        # successful annotate() calls
            "retries": 0,        # sub-batches re-queued after a failure
            "failures": 0,       # annotate() calls that raised (or died)
            "per_replica": [0] * self.n_replicas,          # completed batches
            "per_replica_failures": [0] * self.n_replicas,
            "per_replica_ids": [0] * self.n_replicas,      # labels produced
            "per_replica_max_slice": [0] * self.n_replicas,
            # ids a replica labeled beyond its fair 1/n share of a flush
            # (it worked a slower sibling's share)
            "steals": 0,
            # per-sub-batch EWMAs: wall seconds and labels/s — the labels/s
            # signal drives latency-aware slice sizing
            "per_replica_latency_ewma_s": [0.0] * self.n_replicas,
            "per_replica_rate_ewma": [0.0] * self.n_replicas,
        }
        self.set_obs(obs)
        self._spool: Optional[str] = None
        if backend == "process":
            import multiprocessing as mp
            start = mp_context or os.environ.get(
                "REPRO_ORACLE_MP_CONTEXT", "fork")
            ctx = mp.get_context(start)
            self._spool = tempfile.mkdtemp(prefix="oracle-pool-")
            self._replicas = [
                _ProcessReplica(fn, name=f"{name}-{ridx}", handoff=handoff,
                                spool=self._spool, ctx=ctx)
                for ridx, fn in enumerate(replicas)]
        else:
            self._replicas = [_ThreadReplica(fn) for fn in replicas]
        self._threads = [
            threading.Thread(target=self._drive, args=(ridx,),
                             name=f"{name}-driver-{ridx}", daemon=True)
            for ridx in range(self.n_replicas)]
        for t in self._threads:
            t.start()

    def set_obs(self, obs) -> None:
        """Attach an :class:`~repro.obs.ObsScope`; resolves the sub-batch
        latency histogram once (drivers observe it lock-free on the
        registry side)."""
        self._obs = obs if obs is not None else NULL_SCOPE
        self._h_sub = self._obs.histogram(
            "oracle_subbatch_latency_seconds",
            "wall time of one replica sub-batch (annotate call)")

    # -- sharding ------------------------------------------------------------
    def chunk_size(self, n: int, max_batch: int) -> int:
        """Base sub-batch size for a flush of ``n`` ids: small enough that
        every replica gets ~``oversub`` batches, never larger than
        ``max_batch``.  Per-replica EWMA rates then scale this down for
        slow replicas at dispatch time (:meth:`_slice_for`)."""
        per = ceil(n / (self.n_replicas * self.oversub))
        return max(1, min(int(max_batch), per))

    def _alive_set(self) -> Set[int]:
        with self._lock:
            return {i for i, a in enumerate(self._alive) if a}

    def _slice_for(self, ridx: int, job: _FlushJob) -> int:
        """Latency-aware slice size for ``ridx``: the base size scaled by
        this replica's EWMA labels/s relative to the best live replica,
        capped by the flush ``max_batch`` and the replica's own
        ``max_batches`` entry."""
        base = self.chunk_size(len(job.ids), job.max_batch)
        cap = job.max_batch
        if self._max_batches is not None:
            cap = min(cap, self._max_batches[ridx])
        with self._lock:
            rates = self.stats["per_replica_rate_ewma"]
            mine = rates[ridx]
            best = max((rates[i] for i, a in enumerate(self._alive) if a),
                       default=0.0)
        if mine > 0.0 and best > 0.0 and mine < best:
            base = max(1, int(round(base * (mine / best))))
        return max(1, min(base, cap))

    # -- the one entry point -------------------------------------------------
    def run(self, ids, max_batch: int) -> Tuple[Dict[int, Any], int]:
        """Label ``ids`` across the replicas; blocks until every slice
        completed (or failed on every live replica).  Returns
        ``({id: annotation}, n_batches)``.  Raises :class:`OraclePoolError`
        if any slice failed everywhere — the caller's ids are then untouched
        (no partial publish)."""
        with self._lock:
            if self._closed:
                raise OraclePoolClosed("OraclePool is closed")
            if not any(self._alive):
                raise OraclePoolError(
                    f"all {self.n_replicas} replica workers are dead; "
                    "the flush failed on all replicas")
            self.stats["flushes"] += 1
            self._active += 1
        try:
            ids = np.asarray(ids, np.int64).ravel()
            if len(ids) == 0:
                return {}, 0
            job = _FlushJob(ids, int(max_batch))
            with self._lock:
                self._jobs.add(job)
            try:
                for _ in range(self.n_replicas):
                    self._tasks.put(job)
                with job.cond:
                    while job.outstanding and job.error is None:
                        job.cond.wait()
                    if job.error is not None:
                        raise job.error
                    timings = list(job.timings)
                    results, batches = dict(job.results), job.batches
            finally:
                with self._lock:
                    self._jobs.discard(job)
            # post-completion bookkeeping: replica sub-batch spans on the
            # caller's trace, and steal counting (ids a replica labeled
            # beyond its fair 1/n share of this flush)
            per_ids = [0] * self.n_replicas
            for ridx, t0, t1, n in timings:
                per_ids[ridx] += n
                add_timed_span("oracle.subbatch", t0, t1,
                               replica=ridx, n=n)
            fair = ceil(len(ids) / self.n_replicas)
            stolen = sum(max(0, c - fair) for c in per_ids)
            if stolen:
                with self._lock:
                    self.stats["steals"] += stolen
            return results, batches
        finally:
            with self._lock:
                self._active -= 1
                if self._active == 0:
                    self._idle.notify_all()

    # -- drivers (one per replica, parent side) ------------------------------
    def _drive(self, ridx: int) -> None:
        replica = self._replicas[ridx]
        while True:
            task = self._tasks.get()
            if task is _STOP:
                return
            if not self._work_job(ridx, replica, task):
                return  # replica died; this driver retires

    def _claim(self, ridx: int, job: _FlushJob):
        """Next slice for replica ``ridx``: a failed slice it has not tried,
        else a fresh latency-sized slice off the cursor.  Returns
        ``(chunk, tried)``, ``(_BLOCKED, None)`` when only slices this
        replica already failed remain, or ``(None, None)`` when the job has
        nothing left to hand out."""
        alive = self._alive_set()
        with job.cond:
            if job.error is not None or job.outstanding == 0:
                return None, None
            for k in range(len(job.retry)):
                chunk, tried = job.retry[k]
                if not (alive - tried):
                    # no live replica is left to retry this slice
                    job.error = OraclePoolError(
                        f"sub-batch of {len(chunk)} ids failed on all "
                        f"{self.n_replicas} replicas")
                    job.cond.notify_all()
                    return None, None
                if ridx not in tried:
                    del job.retry[k]
                    return chunk, tried
            if job.cursor < len(job.ids):
                take = self._slice_for(ridx, job)
                chunk = job.ids[job.cursor:job.cursor + take]
                job.cursor += take
                return chunk, set()
            if job.retry:
                return _BLOCKED, None
            return None, None

    def _work_job(self, ridx: int, replica, job: _FlushJob) -> bool:
        """Work one job ticket; returns False when this replica died."""
        while True:
            chunk, tried = self._claim(ridx, job)
            if chunk is None:
                return True
            if chunk is _BLOCKED:
                # only slices this replica already failed remain: hand the
                # ticket back for a survivor and back off so one can pick
                # it up (10ms bounds the spin without delaying the handoff)
                self._tasks.put(job)
                time.sleep(0.01)
                return True
            with self._lock:
                self.stats["dispatched"] += 1
            t0 = time.perf_counter()
            try:
                anns = replica.invoke(chunk)
                if len(anns) != len(chunk):
                    raise OraclePoolError(
                        f"replica {ridx} returned {len(anns)} annotations "
                        f"for {len(chunk)} ids")
            except _ReplicaDead as e:
                self._record_failure(ridx)
                self._retire_replica(ridx, job, chunk, tried, e)
                return False
            except Exception as e:  # noqa: BLE001 - replica fault barrier
                self._record_failure(ridx)
                self._requeue(job, chunk, tried, ridx, e)
                continue
            t1 = time.perf_counter()
            n = len(chunk)
            with job.cond:
                for i, a in zip(chunk, anns):
                    job.results[int(i)] = a
                job.batches += 1
                job.outstanding -= n
                job.timings.append((ridx, t0, t1, n))
                if job.outstanding == 0:
                    job.cond.notify_all()
            dt = max(t1 - t0, 1e-9)
            with self._lock:
                self.stats["batches"] += 1
                self.stats["per_replica"][ridx] += 1
                self.stats["per_replica_ids"][ridx] += n
                self.stats["per_replica_max_slice"][ridx] = max(
                    self.stats["per_replica_max_slice"][ridx], n)
                lat = self.stats["per_replica_latency_ewma_s"]
                lat[ridx] = dt if lat[ridx] == 0.0 else \
                    lat[ridx] + _EWMA_ALPHA * (dt - lat[ridx])
                rate = self.stats["per_replica_rate_ewma"]
                r = n / dt
                rate[ridx] = r if rate[ridx] == 0.0 else \
                    rate[ridx] + _EWMA_ALPHA * (r - rate[ridx])
            self._h_sub.observe(dt)

    def _record_failure(self, ridx: int) -> None:
        with self._lock:
            self.stats["failures"] += 1
            self.stats["per_replica_failures"][ridx] += 1

    def _requeue(self, job: _FlushJob, chunk: np.ndarray, tried: Set[int],
                 ridx: int, exc: BaseException) -> None:
        """Hand a failed slice to the survivors (or fail the job when none
        remain)."""
        tried = set(tried)
        tried.add(ridx)
        alive = self._alive_set()
        with job.cond:
            if not (alive - tried):
                job.error = OraclePoolError(
                    f"sub-batch of {len(chunk)} ids failed on all "
                    f"{self.n_replicas} replicas "
                    f"(last: {type(exc).__name__}: {exc})")
                job.cond.notify_all()
                return
            job.retry.append((chunk, tried))
        with self._lock:
            self.stats["retries"] += 1
        self._tasks.put(job)  # wake an idle survivor for the retry

    def _retire_replica(self, ridx: int, job: _FlushJob, chunk: np.ndarray,
                        tried: Set[int], exc: BaseException) -> None:
        """A process replica died mid-call: mark it dead, push its slice to
        the survivors, and fail every waiting job if it was the last one."""
        with self._lock:
            self._alive[ridx] = False
            any_alive = any(self._alive)
            jobs = list(self._jobs)
        if not any_alive:
            err = OraclePoolError(
                f"all {self.n_replicas} replica workers died; the flush "
                f"failed on all replicas (last: {exc})")
            for j in jobs:
                with j.cond:
                    if j.error is None and j.outstanding:
                        j.error = err
                        j.cond.notify_all()
            return
        self._requeue(job, chunk, tried, ridx, exc)

    # -- lifecycle -----------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """A consistent copy of ``stats`` (lists copied too)."""
        with self._lock:
            out = dict(self.stats)
            for key in ("per_replica", "per_replica_failures",
                        "per_replica_ids", "per_replica_max_slice"):
                out[key] = list(out[key])
            for key in ("per_replica_latency_ewma_s",
                        "per_replica_rate_ewma"):
                out[key] = [round(v, 6) for v in out[key]]
            out["n_replicas"] = self.n_replicas
            out["backend"] = self.backend
            out["per_replica_alive"] = list(self._alive)
            return out

    def close(self, timeout: float = 10.0) -> None:
        """Stop the drivers and replica workers (idempotent).  Drain-safe:
        waits for in-flight :meth:`run` calls to finish before the stop
        sentinels are enqueued, so a retry re-queued by a concurrent flush
        can never land behind a sentinel and strand the flush.  Process
        replicas are asked to exit, then joined, then terminated/killed —
        :meth:`close` never leaves children behind.  New :meth:`run` calls
        fail fast (the broker falls back to its current pool / inline
        labeling)."""
        deadline = time.monotonic() + timeout
        with self._idle:
            if self._closed:
                return
            self._closed = True
            while self._active:
                remaining = deadline - time.monotonic()
                if remaining <= 0 or not self._idle.wait(timeout=remaining):
                    break
        for _ in self._threads:
            self._tasks.put(_STOP)
        for t in self._threads:
            t.join(timeout=max(0.1, deadline - time.monotonic()))
        for rep in self._replicas:
            rep.stop(timeout=max(0.1, deadline - time.monotonic()))
        if self._spool is not None:
            shutil.rmtree(self._spool, ignore_errors=True)

    def __enter__(self) -> "OraclePool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
