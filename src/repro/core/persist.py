"""Atomic file writes for on-disk artifacts (index, label store).

A crash mid-save must never leave a torn ``.meta.json``/``.npz`` pair on
disk: every writer in this repo goes through :func:`atomic_write`, which
writes to a temp file in the destination directory, fsyncs, and renames into
place.  ``os.replace`` is atomic on POSIX (and on Windows for same-volume
paths), so readers only ever observe the old file or the complete new one.
"""
from __future__ import annotations

import contextlib
import os
import pathlib
import tempfile
from typing import IO, Iterator, Union


@contextlib.contextmanager
def atomic_write(path: Union[str, os.PathLike], mode: str = "w"
                 ) -> Iterator[IO]:
    """Context manager yielding a file object whose contents replace
    ``path`` atomically on clean exit (and are discarded on error).

        with atomic_write(p, "wb") as f:
            np.savez(f, ...)
    """
    if "r" in mode or "a" in mode or "+" in mode:
        raise ValueError(f"atomic_write is write-only, got mode {mode!r}")
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=path.name + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, mode) as f:
            yield f
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        with contextlib.suppress(OSError):
            os.unlink(tmp)
        raise
