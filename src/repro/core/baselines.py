"""Baselines the paper compares against (§6.1):

* ``train_query_proxy``: BlazeIt/NoScope-style *per-query* proxy model — a
  small MLP trained on ``budget`` target-DNN-annotated records with an ad-hoc
  per-query loss (regression for counts, logistic for predicates).  This is
  the "TMAS + tiny ResNet" pipeline; its cost model charges the same
  target-DNN invocations the paper charges BlazeIt.
* random sampling (aggregation): ``aggregate_control_variates(use_cv=False)``.
* TASTI-PT: the pre-trained-embedder variant — an embedder trained with a
  generic self-supervised objective (feature reconstruction), *not* the
  induced-schema triplet loss.  Built here so both TASTI variants share code.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embedder import EmbedderConfig, embed, init_embedder
from repro.models.common import ParamSpec, PyTree, init_params
from repro.optim.adamw import OptimizerConfig, adamw_update, init_opt_state


# ---------------------------------------------------------------------------
# Per-query proxy model (BlazeIt-style)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ProxyConfig:
    feature_dim: int = 64
    hidden: int = 32  # speed-class parity with the paper's tiny per-query proxies
    steps: int = 300
    lr: float = 3e-3
    batch: int = 128
    classify: bool = False
    seed: int = 0


def _proxy_specs(cfg: ProxyConfig) -> PyTree:
    return {
        "w0": ParamSpec((cfg.feature_dim, cfg.hidden), ("embed", "mlp"), jnp.float32),
        "b0": ParamSpec((cfg.hidden,), (None,), jnp.float32, init="zeros"),
        "w1": ParamSpec((cfg.hidden, cfg.hidden), ("embed", "mlp"), jnp.float32),
        "b1": ParamSpec((cfg.hidden,), (None,), jnp.float32, init="zeros"),
        "w2": ParamSpec((cfg.hidden, 1), ("embed", "mlp"), jnp.float32),
        "b2": ParamSpec((1,), (None,), jnp.float32, init="zeros"),
    }


def _proxy_fwd(p: PyTree, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu(jnp.dot(x, p["w0"]) + p["b0"])
    h = jax.nn.gelu(jnp.dot(h, p["w1"]) + p["b1"])
    return (jnp.dot(h, p["w2"]) + p["b2"])[..., 0]


def train_query_proxy(features: np.ndarray, train_ids: np.ndarray,
                      train_targets: np.ndarray,
                      cfg: Optional[ProxyConfig] = None) -> np.ndarray:
    """Train the per-query proxy on annotated ids; return proxy scores (N,)."""
    cfg = cfg or ProxyConfig(feature_dim=features.shape[1])
    key = jax.random.PRNGKey(cfg.seed)
    params = init_params(_proxy_specs(cfg), key)
    opt = OptimizerConfig(peak_lr=cfg.lr, min_lr=cfg.lr * 0.1, warmup_steps=10,
                          total_steps=cfg.steps, weight_decay=1e-4)
    state = init_opt_state(params, opt)
    x_all = jnp.asarray(features[train_ids])
    y_all = jnp.asarray(train_targets.astype(np.float32))

    def loss_fn(p, x, y):
        out = _proxy_fwd(p, x)
        if cfg.classify:
            return jnp.mean(jnp.maximum(out, 0) - out * y
                            + jnp.log1p(jnp.exp(-jnp.abs(out))))
        return jnp.mean((out - y) ** 2)

    @jax.jit
    def step(p, s, x, y):
        loss, g = jax.value_and_grad(loss_fn)(p, x, y)
        p, s, _ = adamw_update(p, g, s, opt)
        return p, s, loss

    rng = np.random.default_rng(cfg.seed)
    for _ in range(cfg.steps):
        sel = rng.integers(0, len(train_ids), size=min(cfg.batch, len(train_ids)))
        params, state, _ = step(params, state, x_all[sel], y_all[sel])

    scores = np.asarray(jax.jit(lambda p, x: _proxy_fwd(p, x))(
        params, jnp.asarray(features)))
    if cfg.classify:
        scores = 1.0 / (1.0 + np.exp(-scores))
    return scores


# ---------------------------------------------------------------------------
# "Pre-trained" embedder (TASTI-PT)
# ---------------------------------------------------------------------------

def pretrain_embedder(features: np.ndarray, ecfg: EmbedderConfig,
                      steps: int = 300, lr: float = 1e-3,
                      seed: int = 0) -> PyTree:
    """Generic self-supervised pre-training: embed -> linear decode ->
    reconstruct features.  Captures feature geometry without any access to the
    induced schema — the paper's ImageNet/BERT stand-in."""
    key = jax.random.PRNGKey(seed)
    params = init_embedder(ecfg, key)
    dec = init_params({"wd": ParamSpec((ecfg.embed_dim, ecfg.feature_dim),
                                       ("embed", "mlp"), jnp.float32)},
                      jax.random.PRNGKey(seed + 1))
    both = {"enc": params, "dec": dec}
    opt = OptimizerConfig(peak_lr=lr, min_lr=lr * 0.1, warmup_steps=10,
                          total_steps=steps, weight_decay=0.0)
    state = init_opt_state(both, opt)
    feats = jnp.asarray(features)

    def loss_fn(p, x):
        e = embed(p["enc"], x, ecfg)
        rec = jnp.dot(e, p["dec"]["wd"])
        return jnp.mean((rec - x) ** 2)

    @jax.jit
    def step(p, s, x):
        loss, g = jax.value_and_grad(loss_fn)(p, x)
        p, s, _ = adamw_update(p, g, s, opt)
        return p, s, loss

    rng = np.random.default_rng(seed)
    for _ in range(steps):
        sel = rng.integers(0, len(features), size=256)
        both, state, _ = step(both, state, feats[sel])
    return both["enc"]
