"""Deterministic, sharded, resumable data pipeline.

Multi-host layout: every host computes the same permutation stream from
(seed, epoch) and takes its own slice — no coordination traffic.  The state is
two integers (epoch, offset) carried in checkpoints, so restart/elastic
re-shard resume exactly (a host joining with a different shard count replays
from the same global offset).

Sources: synthetic LM token streams (for the train examples) and the TASTI
workload features.  A background prefetch thread keeps ``depth`` batches ready.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np


@dataclass
class PipelineState:
    epoch: int = 0
    offset: int = 0  # in global batches within the epoch

    def as_dict(self) -> Dict[str, int]:
        return {"epoch": self.epoch, "offset": self.offset}

    @staticmethod
    def from_dict(d) -> "PipelineState":
        return PipelineState(int(d["epoch"]), int(d["offset"]))


class TokenDataset:
    """Deterministic synthetic LM corpus: documents of zipf-ish tokens with
    local n-gram structure (so the loss actually decreases)."""

    def __init__(self, vocab_size: int, n_docs: int = 2048,
                 doc_len: int = 512, seed: int = 0):
        rng = np.random.default_rng(seed)
        base = rng.zipf(1.5, size=(n_docs, doc_len)).astype(np.int64)
        base = np.clip(base, 1, vocab_size - 1)
        # second-order structure: every other token depends on the previous
        shift = (base[:, :-1] * 31 + 7) % vocab_size
        base[:, 1::2] = shift[:, ::2][:, : base[:, 1::2].shape[1]]
        self.tokens = base.astype(np.int32)
        self.vocab_size = vocab_size

    def batch(self, epoch: int, index: int, batch_size: int,
              seq_len: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(hash((epoch, index)) % (2 ** 32))
        docs = rng.integers(0, len(self.tokens), size=batch_size)
        starts = rng.integers(0, self.tokens.shape[1] - seq_len - 1,
                              size=batch_size)
        tok = np.stack([self.tokens[d, s:s + seq_len + 1]
                        for d, s in zip(docs, starts)])
        return {"tokens": tok[:, :-1], "targets": tok[:, 1:]}


class ShardedLoader:
    """Per-host loader: global batches -> this host's shard, with prefetch."""

    def __init__(self, dataset: TokenDataset, global_batch: int, seq_len: int,
                 host_id: int = 0, n_hosts: int = 1,
                 state: Optional[PipelineState] = None,
                 batches_per_epoch: int = 1 << 16, prefetch_depth: int = 2):
        assert global_batch % n_hosts == 0
        self.ds = dataset
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.state = state or PipelineState()
        self.batches_per_epoch = batches_per_epoch
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch_depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _make(self, st: PipelineState) -> Dict[str, np.ndarray]:
        b = self.ds.batch(st.epoch, st.offset, self.global_batch, self.seq_len)
        per = self.global_batch // self.n_hosts
        lo = self.host_id * per
        return {k: v[lo:lo + per] for k, v in b.items()}

    def _producer(self) -> None:
        st = dataclasses.replace(self.state)
        while not self._stop.is_set():
            batch = self._make(st)
            nxt = PipelineState(st.epoch, st.offset + 1)
            if nxt.offset >= self.batches_per_epoch:
                nxt = PipelineState(st.epoch + 1, 0)
            try:
                self._q.put((batch, nxt), timeout=0.5)
                st = nxt
            except queue.Full:
                continue

    def next(self) -> Dict[str, np.ndarray]:
        batch, nxt = self._q.get()
        self.state = nxt
        return batch

    def close(self) -> None:
        self._stop.set()
