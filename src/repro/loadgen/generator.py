"""The open-loop firing engine and its latency report.

:class:`OpenLoopGenerator` materializes an arrival schedule
(:class:`~repro.loadgen.arrivals.ArrivalProcess`), samples a request per
arrival (:class:`~repro.loadgen.mix.SpecMix`), and a single pacer walks the
schedule, firing each request on its own worker thread at its scheduled
time — *never* waiting for earlier requests to finish.  If the server falls
behind, requests pile up in its queues (that is the point); the generator's
own firing jitter is recorded separately so a slow harness cannot
masquerade as a slow server.

Outstanding worker threads are capped by ``max_inflight`` (default:
unlimited).  Against a stalled server an unbounded open loop accumulates
one parked thread per arrival, and past a few thousand the spawn cost
itself distorts fire-lag percentiles — the harness's health metric — so a
bounded run sheds load instead: an arrival that finds ``max_inflight``
requests still outstanding is recorded as *dropped*
(``error_kind="dropped"``), excluded from error counts and latency
percentiles, and tallied in ``LoadReport.dropped`` (total and per class).

``post`` is any callable ``(specs, budget, priority, deadline_ms, name) ->
object``; an exception marks the request failed and its message is kept.
Errors are classified by kind — ``connect`` (``OSError``: refused,
reset, timeout — the client never got an answer), ``http_4xx``/``http_5xx``
(an exception carrying an integer ``status`` attribute, e.g.
:class:`repro.serve.client.ServerError`), ``other`` — so server-side faults
are not hidden behind client connectivity noise.  A ``post`` that accepts a
``trace_id`` keyword gets one per request (stamped on the outcome too), tying
every fired request to its server-side span tree in the flight recorder.
The report aggregates per class: counts, error counts by kind, p50/p90/p99
latency.
"""
from __future__ import annotations

import inspect
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.loadgen.arrivals import ArrivalProcess
from repro.loadgen.mix import SpecMix
from repro.obs.trace import new_trace_id

PostFn = Callable[..., Any]


def _accepts_kwarg(fn: Callable, name: str) -> bool:
    """Does ``fn`` accept ``name`` as a keyword (directly or via **kw)?
    Inspected once at construction so old post callables keep working."""
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):  # builtins / C callables: don't risk it
        return False
    return name in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values())


@dataclass
class RequestOutcome:
    """One fired request, from schedule to completion."""
    name: str                       # SpecClass name
    scheduled_s: float              # offset in the arrival schedule
    fired_s: float = 0.0            # when the thread actually posted
    done_s: float = 0.0             # when the response (or error) landed
    ok: bool = False
    error: Optional[str] = None
    # connect | http_4xx | http_5xx | other | dropped (never fired: the
    # max_inflight cap was full at its scheduled time)
    error_kind: Optional[str] = None
    trace_id: Optional[str] = None    # stamped when post accepts trace_id
    response: Any = None

    @property
    def latency_s(self) -> float:
        """Client-observed latency (post to response)."""
        return self.done_s - self.fired_s

    @property
    def fire_lag_s(self) -> float:
        """Harness jitter: how late the thread fired vs the schedule."""
        return self.fired_s - self.scheduled_s


def _classify_error(e: Exception) -> str:
    """connect (no answer) vs http_4xx/http_5xx (the server answered with a
    failure status, exposed via an integer ``status`` attr) vs other.
    ``status`` is checked first: an HTTP-status-carrying error that happens
    to subclass OSError is still a *server* answer, not connectivity."""
    status = getattr(e, "status", None)
    if isinstance(status, int) and not isinstance(status, bool):
        return "http_4xx" if 400 <= status < 500 else "http_5xx"
    if isinstance(e, OSError):
        return "connect"
    return "other"


def _percentiles(values_ms: List[float]) -> Dict[str, float]:
    if not values_ms:
        return {"p50_ms": 0.0, "p90_ms": 0.0, "p99_ms": 0.0}
    arr = np.asarray(values_ms, np.float64)
    p50, p90, p99 = np.percentile(arr, [50, 90, 99])
    return {"p50_ms": round(float(p50), 3), "p90_ms": round(float(p90), 3),
            "p99_ms": round(float(p99), 3)}


@dataclass
class LoadReport:
    """Everything one open-loop run observed."""
    duration_s: float
    offered: int                              # scheduled arrivals
    completed: int
    errors: int                               # fired and failed (by kind)
    connect_errors: int                       # never reached the server
    http_errors: int                          # server answered 4xx/5xx
    dropped: int                              # never fired: inflight cap full
    max_fire_lag_ms: float                    # harness health, not server's
    classes: Dict[str, Dict[str, float]]      # per-class n/ok/errors/pXX_ms
    outcomes: List[RequestOutcome] = field(repr=False, default_factory=list)


class OpenLoopGenerator:
    """Fire a :class:`SpecMix` at an :class:`ArrivalProcess` schedule.

        gen = OpenLoopGenerator(post, mix, process, duration_s=3.0)
        report = gen.run()

    ``run`` blocks until every fired request has completed or errored (the
    *firing* is open-loop; the run still ends cleanly).  Pre-sampling the
    whole schedule before the first shot keeps sampling cost off the firing
    path and makes the request train a pure function of the seeds.

    ``max_inflight`` bounds outstanding worker threads; an arrival landing
    while the cap is full is *dropped*, not delayed — delaying it would
    close the loop and understate offered load.  ``None`` (the default)
    keeps the historic unbounded behavior.
    """

    def __init__(self, post: PostFn, mix: SpecMix, process: ArrivalProcess,
                 duration_s: float, max_inflight: Optional[int] = None):
        if duration_s <= 0:
            raise ValueError(f"duration_s must be > 0, got {duration_s}")
        if max_inflight is not None and max_inflight <= 0:
            raise ValueError(
                f"max_inflight must be > 0 or None, got {max_inflight}")
        self.post = post
        self.mix = mix
        self.process = process
        self.duration_s = float(duration_s)
        self.max_inflight = max_inflight
        self._post_takes_trace = _accepts_kwarg(post, "trace_id")

    def run(self) -> LoadReport:
        offsets = self.process.times(self.duration_s)
        plan = []
        for off in offsets:
            cls, specs, budget = self.mix.sample()
            plan.append((off, cls, specs, budget))
        outcomes = [RequestOutcome(name=cls.name, scheduled_s=off)
                    for off, cls, _, _ in plan]
        slots = (threading.Semaphore(self.max_inflight)
                 if self.max_inflight is not None else None)
        threads: List[threading.Thread] = []
        t0 = time.monotonic()

        def fire(i: int) -> None:
            _, cls, specs, budget = plan[i]
            out = outcomes[i]
            out.fired_s = time.monotonic() - t0
            kwargs = dict(budget=budget, priority=cls.priority,
                          deadline_ms=cls.deadline_ms, name=cls.name)
            if self._post_takes_trace:
                out.trace_id = new_trace_id()
                kwargs["trace_id"] = out.trace_id
            try:
                out.response = self.post(specs, **kwargs)
                out.ok = True
            except Exception as e:  # noqa: BLE001 - outcome, not crash
                out.error = f"{type(e).__name__}: {e}"
                out.error_kind = _classify_error(e)
            finally:
                out.done_s = time.monotonic() - t0
                if slots is not None:
                    slots.release()

        # one pacer walks the schedule: sleep to each arrival, then hand it
        # to a fresh worker thread (or shed it when the cap is full)
        for i, (off, _, _, _) in enumerate(plan):
            delay = (t0 + off) - time.monotonic()
            if delay > 0:
                time.sleep(delay)
            if slots is not None and not slots.acquire(blocking=False):
                out = outcomes[i]
                out.fired_s = out.done_s = time.monotonic() - t0
                out.error = (f"dropped: {self.max_inflight} requests "
                             "already in flight")
                out.error_kind = "dropped"
                continue
            t = threading.Thread(target=fire, args=(i,),
                                 name=f"loadgen-{i}", daemon=True)
            threads.append(t)
            t.start()
        for t in threads:
            t.join()

        def _kind_count(who: List[RequestOutcome], *kinds: str) -> int:
            return sum(o.error_kind in kinds for o in who if not o.ok)

        classes: Dict[str, Dict[str, float]] = {}
        for cls in self.mix.classes:
            mine = [o for o in outcomes if o.name == cls.name]
            ok = [o for o in mine if o.ok]
            dropped = _kind_count(mine, "dropped")
            classes[cls.name] = {
                "n": len(mine),
                "ok": len(ok),
                "errors": len(mine) - len(ok) - dropped,
                "connect_errors": _kind_count(mine, "connect"),
                "http_errors": _kind_count(mine, "http_4xx", "http_5xx"),
                "dropped": dropped,
                **_percentiles([o.latency_s * 1e3 for o in ok]),
            }
        dropped = _kind_count(outcomes, "dropped")
        return LoadReport(
            duration_s=self.duration_s,
            offered=len(plan),
            completed=sum(o.ok for o in outcomes),
            errors=sum(not o.ok for o in outcomes) - dropped,
            connect_errors=_kind_count(outcomes, "connect"),
            http_errors=_kind_count(outcomes, "http_4xx", "http_5xx"),
            dropped=dropped,
            max_fire_lag_ms=round(max(
                (o.fire_lag_s * 1e3 for o in outcomes), default=0.0), 3),
            classes=classes,
            outcomes=outcomes,
        )
