"""Request-class mixes: what an open-loop arrival actually carries.

A :class:`SpecClass` is one kind of traffic — a spec-list template plus the
scheduling envelope it travels in (priority class, relative deadline, oracle
budget).  A :class:`SpecMix` samples classes by weight, so one arrival
process can carry, say, 90% interactive aggregations and 10% heavy scans.

Budgets and spec lists may be given as values or as callables of the mix's
``numpy`` generator, so per-request variation (jittered budgets, randomized
predicates) stays reproducible from the mix seed.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

Specs = List[dict]
SpecsLike = Union[Specs, Callable[[np.random.Generator], Specs]]
BudgetLike = Union[None, int, Tuple[int, int],
                   Callable[[np.random.Generator], Optional[int]]]


@dataclass(frozen=True)
class SpecClass:
    """One traffic class: a spec template and its scheduling envelope."""

    name: str
    specs: SpecsLike                     # template list, or rng -> list
    weight: float = 1.0
    priority: Optional[int] = None       # scheduling class (0 most urgent)
    deadline_ms: Optional[float] = None  # relative EDF deadline
    budget: BudgetLike = None            # int | (lo, hi) uniform | callable

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"weight for {self.name!r} must be > 0, "
                             f"got {self.weight}")

    def sample_specs(self, rng: np.random.Generator) -> Specs:
        if callable(self.specs):
            return self.specs(rng)
        # copy the template: downstream stamping must not mutate the class
        return [dict(s) for s in self.specs]

    def sample_budget(self, rng: np.random.Generator) -> Optional[int]:
        b = self.budget
        if b is None or isinstance(b, int):
            return b
        if callable(b):
            return b(rng)
        lo, hi = b
        return int(rng.integers(int(lo), int(hi) + 1))


@dataclass
class SpecMix:
    """Weighted sampling over :class:`SpecClass` es.

        mix = SpecMix([interactive, heavy], seed=0)
        cls, specs, budget = mix.sample()
    """

    classes: Sequence[SpecClass]
    seed: int = 0
    _rng: np.random.Generator = field(init=False, repr=False)
    _probs: np.ndarray = field(init=False, repr=False)

    def __post_init__(self):
        if not self.classes:
            raise ValueError("mix needs at least one SpecClass")
        names = [c.name for c in self.classes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate class names: {names}")
        self._rng = np.random.default_rng(self.seed)
        weights = np.asarray([c.weight for c in self.classes], np.float64)
        self._probs = weights / weights.sum()

    def sample(self) -> Tuple[SpecClass, Specs, Optional[int]]:
        """Draw one request: its class, a fresh spec list, and a budget."""
        i = int(self._rng.choice(len(self.classes), p=self._probs))
        cls = self.classes[i]
        return cls, cls.sample_specs(self._rng), cls.sample_budget(self._rng)
