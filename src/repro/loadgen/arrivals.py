"""Arrival-time schedules: Poisson and gamma-renewal processes.

An open-loop experiment is only as honest as its arrival process.  This
module generates the *schedule* (absolute offsets from the run start) ahead
of time, so the generator's firing loop has nothing to compute on the hot
path and the schedule itself is reproducible from the seed.

Burstiness is parameterized by the coefficient of variation ``cv`` of the
inter-arrival times: a gamma renewal process with shape ``1/cv**2`` and
scale ``cv**2 / rate`` has mean inter-arrival ``1/rate`` and the requested
cv.  ``cv=1`` is exactly the exponential — a Poisson process; ``cv<1``
approaches a metronome; ``cv>1`` produces the bursty, clumped arrivals that
stress a scheduler's fairness.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np


@dataclass(frozen=True)
class ArrivalProcess:
    """A reproducible renewal process: ``rate`` requests/second with
    inter-arrival coefficient of variation ``cv``.

        offsets = ArrivalProcess(rate=25.0).times(duration=3.0)

    ``times`` returns sorted offsets in ``[0, duration)`` seconds.
    """

    rate: float            # mean requests per second
    cv: float = 1.0        # 1 = Poisson; >1 bursty; <1 regular
    seed: int = 0

    def __post_init__(self):
        if self.rate <= 0:
            raise ValueError(f"rate must be > 0, got {self.rate}")
        if self.cv <= 0:
            raise ValueError(f"cv must be > 0, got {self.cv}")

    def times(self, duration: float) -> List[float]:
        """Arrival offsets (seconds from start) over ``duration`` seconds."""
        if duration <= 0:
            return []
        rng = np.random.default_rng(self.seed)
        shape = 1.0 / (self.cv ** 2)
        scale = (self.cv ** 2) / self.rate
        out: List[float] = []
        t = 0.0
        # draw in blocks: ~rate*duration arrivals expected, 4-sigma headroom
        block = max(16, int(self.rate * duration * 1.5) + 16)
        while True:
            gaps = rng.gamma(shape, scale, size=block)
            for g in gaps:
                t += float(g)
                if t >= duration:
                    return out
                out.append(t)
