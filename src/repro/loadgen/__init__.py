"""Open-loop load generation for the serving layer.

Closed-loop drivers (post, wait, post again) hide overload: when the server
slows down, the driver slows down with it, and measured latency stays
flattering.  An *open-loop* generator fires requests at their scheduled
arrival times regardless of how many are still in flight — the only way to
observe queueing, starvation, and SLO misses at a controlled offered load.

* :mod:`repro.loadgen.arrivals` — :class:`ArrivalProcess`: Poisson or
  gamma-renewal arrival schedules with a controlled rate and burstiness;
* :mod:`repro.loadgen.mix` — :class:`SpecClass` / :class:`SpecMix`: weighted
  sampling over request classes (spec template, priority, deadline, budget
  distribution);
* :mod:`repro.loadgen.generator` — :class:`OpenLoopGenerator`: fires the
  schedule against any ``post`` callable (typically a
  :class:`~repro.serve.client.QueryClient` wrapper) and reports per-class
  latency percentiles.

The package deliberately imports nothing from :mod:`repro.serve`: it is a
pure harness, usable against any endpoint.
"""
__all__ = ["ArrivalProcess", "LoadReport", "OpenLoopGenerator",
           "RequestOutcome", "SpecClass", "SpecMix"]

_HOMES = {"ArrivalProcess": "repro.loadgen.arrivals",
          "LoadReport": "repro.loadgen.generator",
          "OpenLoopGenerator": "repro.loadgen.generator",
          "RequestOutcome": "repro.loadgen.generator",
          "SpecClass": "repro.loadgen.mix",
          "SpecMix": "repro.loadgen.mix"}


def __getattr__(name):
    if name in _HOMES:
        import importlib
        return getattr(importlib.import_module(_HOMES[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
