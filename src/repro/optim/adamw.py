"""AdamW with configurable state dtypes + cosine schedule + global-norm clip.

Optimizer state shardings mirror the parameter shardings (ZeRO: with fsdp the
moments are sharded over data as well).  ``state_dtype="bfloat16"`` halves the
moment memory — required to fit jamba-398B on a 256-chip pod (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import PyTree, spec_map


@dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"


def schedule(opt: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = opt.peak_lr * step / max(opt.warmup_steps, 1)
    t = jnp.clip((step - opt.warmup_steps)
                 / max(opt.total_steps - opt.warmup_steps, 1), 0.0, 1.0)
    cos = opt.min_lr + 0.5 * (opt.peak_lr - opt.min_lr) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < opt.warmup_steps, warm, cos)


def opt_state_specs(param_specs: PyTree, opt: OptimizerConfig) -> PyTree:
    """ParamSpec tree -> ParamSpec tree for (mu, nu) moments."""
    dt = jnp.dtype(opt.state_dtype)
    moment = spec_map(lambda s: dataclasses.replace(s, dtype=dt, init="zeros"),
                      param_specs)
    return {"mu": moment, "nu": moment, "step": None}


def init_opt_state(params: PyTree, opt: OptimizerConfig) -> PyTree:
    dt = jnp.dtype(opt.state_dtype)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return {"mu": zeros,
            "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params: PyTree, grads: PyTree, state: PyTree,
                 opt: OptimizerConfig) -> Tuple[PyTree, PyTree, dict]:
    step = state["step"] + 1
    lr = schedule(opt, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, opt.clip_norm / (gnorm + 1e-9)) \
        if opt.clip_norm else jnp.float32(1.0)
    dt = jnp.dtype(opt.state_dtype)
    bc1 = 1 - opt.b1 ** step.astype(jnp.float32)
    bc2 = 1 - opt.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu_n = opt.b1 * mu.astype(jnp.float32) + (1 - opt.b1) * g
        nu_n = opt.b2 * nu.astype(jnp.float32) + (1 - opt.b2) * g * g
        mhat = mu_n / bc1
        vhat = nu_n / bc2
        delta = mhat / (jnp.sqrt(vhat) + opt.eps) + \
            opt.weight_decay * p.astype(jnp.float32)
        p_n = p.astype(jnp.float32) - lr * delta
        return p_n.astype(p.dtype), mu_n.astype(dt), nu_n.astype(dt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {"mu": treedef.unflatten([o[1] for o in out]),
                 "nu": treedef.unflatten([o[2] for o in out]),
                 "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
