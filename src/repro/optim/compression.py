"""Int8 error-feedback gradient compression for data-parallel all-reduce.

The DP gradient all-reduce dominates cross-pod (DCN) traffic.  We compress to
int8 with a per-tensor max-abs scale and carry the quantization residual as
error feedback (1-bit-Adam-style convergence behaviour).  Executed inside
``shard_map`` over the DP axes so the wire payload is genuinely int8:

    s   = psum_max(local max-abs) / 127      (one scalar collective)
    q_i = round(g_i / s)     -> psum over DP as int32 (no overflow: |q|<=127,
                                 <= 512 shards)         [8x fewer wire bytes]
    g   = psum(q_i) * s / n  (shared scale: exact dequantization)

The error ``e = g_local - dequant(q)`` is added to the next step's gradient.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import PyTree


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_decompress(g: jax.Array, error: Optional[jax.Array] = None):
    """Local error-feedback quantization round-trip (unit-testable core)."""
    gf = g.astype(jnp.float32)
    if error is not None:
        gf = gf + error.astype(jnp.float32)
    q, scale = quantize(gf)
    deq = dequantize(q, scale, jnp.float32)
    new_error = gf - deq
    return deq.astype(g.dtype), new_error.astype(jnp.float32)


def make_compressed_psum(mesh, dp_axes: Tuple[str, ...]):
    """Returns f(local_grads, errors) -> (mean_grads, new_errors) running an
    int8-on-the-wire all-reduce over ``dp_axes`` via shard_map."""
    from jax.experimental.shard_map import shard_map

    n_shards = 1
    for a in dp_axes:
        n_shards *= mesh.shape[a]

    def local(g, e):
        gf = g.astype(jnp.float32) + e
        # shared scale across shards: psum-max of the local max-abs (a
        # per-shard scale cannot be undone after summation)
        local_max = jnp.max(jnp.abs(gf))
        scale = jnp.maximum(jax.lax.pmax(local_max, dp_axes), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        q_sum = jax.lax.psum(q.astype(jnp.int32), dp_axes)   # int8 payload
        mean_g = (q_sum.astype(jnp.float32) * scale) / n_shards
        new_e = gf - dequantize(q, scale, jnp.float32)
        return mean_g.astype(g.dtype), new_e

    def compressed(grads: PyTree, errors: PyTree):
        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = treedef.flatten_up_to(errors)
        outs = []
        for g, e in zip(flat_g, flat_e):
            spec = P(*([None] * g.ndim))
            fn = shard_map(local, mesh=mesh, in_specs=(spec, spec),
                           out_specs=(spec, spec), check_rep=False)
            outs.append(fn(g, e))
        return (treedef.unflatten([o[0] for o in outs]),
                treedef.unflatten([o[1] for o in outs]))

    return compressed
