"""Pure-jnp oracle for fused score propagation (paper §4.2) on device.

Mirrors the float64 host path in :mod:`repro.core.propagation` in float32:
inverse-distance weights over the cached top-k representative structures,
with padded columns (squared distance at or above
:data:`~repro.kernels.distance_topk.ops.PAD_DIST`) masked to zero weight.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.distance_topk.ops import PAD_DIST


def masked_weights(topk_d2: jnp.ndarray, eps: float) -> jnp.ndarray:
    """Inverse-distance weights (N,k) with padded columns zeroed."""
    d2 = topk_d2.astype(jnp.float32)
    w = 1.0 / (jnp.sqrt(jnp.maximum(d2, 0.0)) + eps)
    return jnp.where(d2 >= PAD_DIST, 0.0, w)


def tie_break_prescale(rep_scores: jnp.ndarray,
                       topk_d2: jnp.ndarray) -> jnp.ndarray:
    """Scalar multiplier for the top-1 distance nudge.

    ``eps / (1 + max distance)`` with ``eps`` strictly below the smallest
    nonzero gap between distinct rep scores (capped at 1e-6) — the device
    twin of :func:`repro.core.propagation.top1_tie_break_eps`, so distance
    can only reorder records whose nearest reps score equal.
    """
    scores = rep_scores.astype(jnp.float32)
    if scores.shape[0] >= 2:
        gaps = jnp.diff(jnp.sort(scores))
        min_gap = jnp.min(jnp.where(gaps > 0, gaps, jnp.inf))
        eps = jnp.minimum(jnp.float32(1e-6), 0.5 * min_gap)
    else:
        eps = jnp.float32(1e-6)
    d0 = jnp.sqrt(jnp.maximum(topk_d2[:, 0].astype(jnp.float32), 0.0))
    return eps / (1.0 + jnp.max(d0))


def propagate_numeric_ref(rep_scores: jnp.ndarray, topk_ids: jnp.ndarray,
                          topk_d2: jnp.ndarray, eps: float = 1e-6,
                          clip01: bool = False) -> jnp.ndarray:
    """rep_scores (C,), topk_ids/(d2) (N,k) -> (N,) weighted-mean scores."""
    w = masked_weights(topk_d2, eps)
    s = rep_scores.astype(jnp.float32)[topk_ids]
    out = (w * s).sum(1) / w.sum(1)
    return jnp.clip(out, 0.0, 1.0) if clip01 else out


def propagate_categorical_ref(rep_scores: jnp.ndarray, topk_ids: jnp.ndarray,
                              topk_d2: jnp.ndarray, n_classes: int,
                              eps: float = 1e-6) -> jnp.ndarray:
    """Distance-weighted vote -> (N,) class ids (as float32, like the
    engine's proxy arrays)."""
    w = masked_weights(topk_d2, eps)                       # (N,k)
    cls = rep_scores.astype(jnp.float32)[topk_ids].astype(jnp.int32)
    onehot = cls[:, :, None] == jnp.arange(n_classes, dtype=jnp.int32)
    votes = jnp.sum(jnp.where(onehot, w[:, :, None], 0.0), axis=1)
    return jnp.argmax(votes, axis=1).astype(jnp.float32)


def propagate_top1_ref(rep_scores: jnp.ndarray, topk_ids: jnp.ndarray,
                       topk_d2: jnp.ndarray,
                       clip01: bool = False) -> jnp.ndarray:
    """k=1 propagation ranked (score desc, dist asc) — limit-query scoring."""
    base = rep_scores.astype(jnp.float32)[topk_ids[:, 0]]
    d = jnp.sqrt(jnp.maximum(topk_d2[:, 0].astype(jnp.float32), 0.0))
    out = base - tie_break_prescale(rep_scores, topk_d2) * d
    return jnp.clip(out, 0.0, 1.0) if clip01 else out
