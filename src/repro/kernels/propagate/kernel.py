"""Pallas TPU kernels: fused score propagation over the cached top-k.

Propagation is O(N*k) arithmetic over index structures that never change
between cracks, so the serving hot path keeps ``topk_ids``/``topk_d2``
resident in device memory and runs one fused kernel per (score fn, mode):
each (BN,) row block reads its (BN, k) slice of the rep structures once from
HBM, gathers the (C,) rep-score vector (broadcast to every block), and
writes the (BN,) proxy slice — no (N, C) intermediate, no host round-trip.

Rep-score gathers are one-hot reductions over the (BN, C) comparison grid
(TPU-friendly: iota + where + sum on the VPU; no dynamic-gather primitive
inside the kernel), unrolled over the small static k.  Padded top-k columns
(squared distance at or above ``PAD_DIST``) carry zero weight, matching the
host path in :mod:`repro.core.propagation`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# python scalar: jnp constants can't be captured by kernels
PAD_DIST = 2.9e38


def _gather(scores: jax.Array, ids: jax.Array, c: int) -> jax.Array:
    """scores (C,), ids (BN,) -> scores[ids] via a one-hot reduction."""
    onehot = ids[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (ids.shape[0], c), 1)
    return jnp.sum(jnp.where(onehot, scores[None, :], 0.0), axis=1)


def _column_weight(d2_col: jax.Array, eps: float) -> jax.Array:
    d2 = jnp.maximum(d2_col, 0.0)
    w = 1.0 / (jnp.sqrt(d2) + eps)
    return jnp.where(d2_col >= PAD_DIST, 0.0, w)


def _numeric_kernel(scores_ref, ids_ref, d2_ref, out_ref, *, k: int, c: int,
                    eps: float, clip01: bool):
    scores = scores_ref[...].astype(jnp.float32)     # (C,)
    ids = ids_ref[...]                               # (BN, k)
    d2 = d2_ref[...].astype(jnp.float32)             # (BN, k)
    num = jnp.zeros((ids.shape[0],), jnp.float32)
    den = jnp.zeros((ids.shape[0],), jnp.float32)
    for j in range(k):                               # k static: unrolled
        w = _column_weight(d2[:, j], eps)
        num = num + w * _gather(scores, ids[:, j], c)
        den = den + w
    out = num / den
    if clip01:
        out = jnp.clip(out, 0.0, 1.0)
    out_ref[...] = out


def _categorical_kernel(scores_ref, ids_ref, d2_ref, out_ref, *, k: int,
                        c: int, n_classes: int, eps: float):
    scores = scores_ref[...].astype(jnp.float32)
    ids = ids_ref[...]
    d2 = d2_ref[...].astype(jnp.float32)
    bn = ids.shape[0]
    votes = jnp.zeros((bn, n_classes), jnp.float32)
    class_ids = jax.lax.broadcasted_iota(jnp.int32, (bn, n_classes), 1)
    for j in range(k):
        w = _column_weight(d2[:, j], eps)
        cls = _gather(scores, ids[:, j], c).astype(jnp.int32)
        votes = votes + jnp.where(cls[:, None] == class_ids, w[:, None], 0.0)
    out_ref[...] = jnp.argmax(votes, axis=1).astype(jnp.float32)


def _top1_kernel(scores_ref, ids_ref, d2_ref, pre_ref, out_ref, *, c: int,
                 clip01: bool):
    scores = scores_ref[...].astype(jnp.float32)
    base = _gather(scores, ids_ref[...][:, 0], c)
    d = jnp.sqrt(jnp.maximum(d2_ref[...][:, 0].astype(jnp.float32), 0.0))
    out = base - pre_ref[0] * d
    if clip01:
        out = jnp.clip(out, 0.0, 1.0)
    out_ref[...] = out


def propagate_pallas(rep_scores: jax.Array, topk_ids: jax.Array,
                     topk_d2: jax.Array, mode: str, n_classes: int = 0,
                     clip01: bool = False, eps: float = 1e-6,
                     prescale: jax.Array = None, block_n: int = 256,
                     interpret: bool = False) -> jax.Array:
    """rep_scores (C,), topk_ids/(d2) (N,k) -> (N,) propagated proxy.

    N % block_n == 0 required (ops.py pads).  ``prescale`` is the top-1
    tie-break scalar (a (1,) array; see
    :func:`repro.kernels.propagate.ref.tie_break_prescale`) — it involves a
    global reduction over rows, so it is computed by XLA around the kernel.
    """
    n, k = topk_ids.shape
    c = rep_scores.shape[0]
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    common_specs = [
        pl.BlockSpec((c,), lambda i: (0,)),              # full rep scores
        pl.BlockSpec((block_n, k), lambda i: (i, 0)),
        pl.BlockSpec((block_n, k), lambda i: (i, 0)),
    ]
    if mode == "numeric":
        kernel = functools.partial(_numeric_kernel, k=k, c=c, eps=eps,
                                   clip01=clip01)
        operands = (rep_scores, topk_ids, topk_d2)
        in_specs = common_specs
    elif mode == "categorical":
        kernel = functools.partial(_categorical_kernel, k=k, c=c,
                                   n_classes=n_classes, eps=eps)
        operands = (rep_scores, topk_ids, topk_d2)
        in_specs = common_specs
    elif mode == "top1":
        kernel = functools.partial(_top1_kernel, c=c, clip01=clip01)
        operands = (rep_scores, topk_ids, topk_d2, prescale)
        in_specs = common_specs + [pl.BlockSpec((1,), lambda i: (0,))]
    else:
        raise ValueError(f"unknown propagation mode {mode!r}")
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(*operands)
