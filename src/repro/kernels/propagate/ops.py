"""jit'd fused propagation entry point: pads to block multiples, picks impl.

impl="auto": Pallas on TPU, XLA reference otherwise (interpret mode is a
correctness tool, not an execution path — CPU serving uses the float64 host
path in :mod:`repro.core.propagation`, and CPU benchmarks use the ref).

``rep_scores`` is donated on accelerators: the resident hot path materializes
a fresh (C,) score array per call and never reuses it, so the fused call can
recycle its buffer.  The big (N,k) rep structures are *not* donated — they
live across sessions in :class:`repro.core.resident.ResidentIndexState`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.distance_topk.ops import PAD_DIST
from repro.kernels.propagate.kernel import propagate_pallas
from repro.kernels.propagate.ref import (
    propagate_categorical_ref,
    propagate_numeric_ref,
    propagate_top1_ref,
    tie_break_prescale,
)

MODES = ("numeric", "top1", "categorical")


def _propagate_impl(rep_scores, topk_ids, topk_d2, *, mode, n_classes, clip01,
                    impl, block_n, interpret):
    if impl == "xla":
        if mode == "numeric":
            return propagate_numeric_ref(rep_scores, topk_ids, topk_d2,
                                         clip01=clip01)
        if mode == "categorical":
            out = propagate_categorical_ref(rep_scores, topk_ids, topk_d2,
                                            n_classes)
            return jnp.clip(out, 0.0, 1.0) if clip01 else out
        if mode == "top1":
            return propagate_top1_ref(rep_scores, topk_ids, topk_d2,
                                      clip01=clip01)
        raise ValueError(f"unknown propagation mode {mode!r}")
    n = topk_ids.shape[0]
    pad = (-n) % block_n
    if pad:
        # in-range ids + PAD_DIST distances: padded rows compute garbage that
        # is sliced off, but never NaN/out-of-bounds
        topk_ids = jnp.pad(topk_ids, ((0, pad), (0, 0)))
        topk_d2 = jnp.pad(topk_d2, ((0, pad), (0, 0)),
                          constant_values=PAD_DIST)
    prescale = None
    if mode == "top1":
        # global reduction over real rows only — computed by XLA around the
        # row-blocked kernel
        prescale = tie_break_prescale(rep_scores, topk_d2[:n]).reshape(1)
    out = propagate_pallas(rep_scores, topk_ids, topk_d2, mode,
                           n_classes=n_classes or 0, clip01=clip01,
                           prescale=prescale, block_n=block_n,
                           interpret=interpret)
    return out[:n]


_STATIC = ("mode", "n_classes", "clip01", "impl", "block_n", "interpret")
_jit_plain = functools.partial(jax.jit, static_argnames=_STATIC)(
    _propagate_impl)
_jit_donate = functools.partial(jax.jit, static_argnames=_STATIC,
                                donate_argnums=(0,))(_propagate_impl)


@functools.lru_cache(maxsize=None)
def _donation_ok() -> bool:
    # buffer donation is a no-op (with a warning) on CPU
    return jax.devices()[0].platform in ("tpu", "gpu")


def propagate(rep_scores: jax.Array, topk_ids: jax.Array, topk_d2: jax.Array,
              mode: str, n_classes: int | None = None, clip01: bool = False,
              impl: str = "auto", block_n: int = 256,
              interpret: bool = False, donate: bool | None = None
              ) -> jax.Array:
    """Fused device propagation: rep_scores (C,) -> proxy scores (N,) f32.

    ``mode`` is one of :data:`MODES`; ``n_classes`` is required for
    ``"categorical"``.  Padded top-k columns (squared distance at or above
    :data:`PAD_DIST`) carry zero weight, matching
    :mod:`repro.core.propagation`.  ``donate`` defaults to True on
    accelerators (rep_scores' buffer is recycled) and False on CPU.
    """
    if mode not in MODES:
        raise ValueError(f"unknown propagation mode {mode!r}")
    if mode == "categorical" and not n_classes:
        raise ValueError("categorical propagation needs n_classes")
    if impl == "auto":
        impl = "pallas" if jax.devices()[0].platform == "tpu" else "xla"
    if topk_ids.shape[0] == 0:          # empty index: avoid 0-size jit/grid
        return jnp.zeros((0,), jnp.float32)
    fn = _jit_donate if (donate if donate is not None
                         else _donation_ok()) else _jit_plain
    return fn(rep_scores, topk_ids, topk_d2, mode=mode, n_classes=n_classes,
              clip01=clip01, impl=impl, block_n=block_n, interpret=interpret)
