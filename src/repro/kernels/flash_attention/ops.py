"""jit'd wrapper: layout conversion (B,S,H,hd) <-> (B,H,S,hd), head-dim
padding to 128 multiples, seq padding to block multiples, impl selection."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "impl",
                                             "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int = 0, impl: str = "auto",
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q (B,S,H,hd), k/v (B,Skv,Hk,hd) -> (B,S,H,hd)."""
    if impl == "auto":
        impl = "pallas" if jax.devices()[0].platform == "tpu" else "xla"
    if impl == "xla":
        return flash_attention_ref(q, k, v, causal=causal, window=window)

    b, s, h, hd = q.shape
    skv = k.shape[1]
    # layout: (B,H,S,hd)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    hd_pad = (-hd) % 128
    bq = min(block_q, max(s, 1))
    bk = min(block_k, max(skv, 1))
    sq_pad = (-s) % bq
    skv_pad = (-skv) % bk
    if hd_pad:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, 0), (0, hd_pad)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, 0), (0, hd_pad)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, 0), (0, hd_pad)))
    if sq_pad:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, sq_pad), (0, 0)))
    if skv_pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, skv_pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, skv_pad), (0, 0)))
    # scale uses the PADDED head dim inside the kernel; compensate so softmax
    # logits match the logical sqrt(hd)
    scale_fix = jnp.sqrt((hd + hd_pad) / hd).astype(qt.dtype)
    out = flash_attention_pallas(qt * scale_fix, kt, vt, causal=causal,
                                 window=window, block_q=bq, block_k=bk,
                                 seq_kv=skv, interpret=interpret)
    out = out[:, :, :s, :hd].transpose(0, 2, 1, 3)
    return out.astype(q.dtype)
