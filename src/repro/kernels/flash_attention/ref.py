"""Pure-jnp oracle for flash attention (GQA, causal, sliding window)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """q (B,S,H,hd), k/v (B,Skv,Hk,hd) -> (B,S,H,hd). Full-softmax oracle."""
    b, s, h, hd = q.shape
    skv, hk = k.shape[1], k.shape[2]
    g = h // hk
    qg = q.reshape(b, s, hk, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((s, skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)
