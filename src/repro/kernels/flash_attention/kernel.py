"""Pallas TPU flash attention (forward): online-softmax over kv blocks with
VMEM accumulators, GQA via BlockSpec index mapping, causal + sliding-window
with *block-level skipping* expressed in the grid index map.

Layout: q (B,H,S,hd); k/v (B,Hk,Skv,hd).  Grid (B*H, Sq/BQ, Skv/BK): the kv
block index j sweeps innermost so the (BQ,hd) output block and the (BQ,)
m/l accumulators stay resident in VMEM across the sweep (the standard TPU
flash pattern).  GQA needs no materialized head expansion: the kv BlockSpec
maps query-head bh -> kv-head bh // group.

MXU alignment: BQ/BK default 512/512 with hd padded to a multiple of 128 by
ops.py.  VMEM working set = q(BQ,hd) + k/v(BK,hd) + scores(BQ,BK) f32
= 0.5-2 MiB for hd<=256 — well inside v5e VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_k: int, causal: bool,
            window: int, seq_kv: int):
    i = pl.program_id(1)   # q block
    j = pl.program_id(2)   # kv block

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    q = q_ref[0].astype(jnp.float32)            # (BQ, hd)
    k = k_ref[0].astype(jnp.float32)            # (BK, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < seq_kv
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = (acc_scr[...] * alpha[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True, window: int = 0,
                           block_q: int = 512, block_k: int = 512,
                           seq_kv: int = 0, interpret: bool = False):
    """q (B,H,S,hd), k/v (B,Hk,Skv,hd) padded to block multiples.

    ``seq_kv``: logical kv length (<= padded Skv); padded keys are masked.
    """
    b, h, s, hd = q.shape
    hk, skv = k.shape[1], k.shape[2]
    g = h // hk
    block_q = min(block_q, s)
    block_k = min(block_k, skv)
    assert s % block_q == 0 and skv % block_k == 0
    grid = (b * h, s // block_q, skv // block_k)
    scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, seq_kv=seq_kv or skv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, i, j, g=g: (bh // g, j, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, i, j, g=g: (bh // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q.reshape(b * h, s, hd), k.reshape(b * hk, skv, hd),
      v.reshape(b * hk, skv, hd)).reshape(b, h, s, hd)
