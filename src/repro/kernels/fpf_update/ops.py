"""jit'd wrapper for the FPF step kernel with padding + XLA fallback."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fpf_update.kernel import fpf_update_pallas
from repro.kernels.fpf_update.ref import fpf_update_ref


@functools.partial(jax.jit, static_argnames=("impl", "block_n", "interpret"))
def fpf_update(x: jax.Array, rep: jax.Array, min_d2: jax.Array,
               impl: str = "auto", block_n: int = 1024,
               interpret: bool = False):
    if impl == "auto":
        impl = "pallas" if jax.devices()[0].platform == "tpu" else "xla"
    if impl == "xla":
        return fpf_update_ref(x, rep, min_d2)
    n = x.shape[0]
    pad = (-n) % block_n
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        min_d2 = jnp.pad(min_d2, (0, pad), constant_values=-1.0)
    new_min, idx, val = fpf_update_pallas(x, rep, min_d2, block_n=block_n,
                                          interpret=interpret)
    return new_min[:n], idx, val
