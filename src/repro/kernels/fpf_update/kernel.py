"""Pallas TPU kernel for one FPF step (distance to newest rep + running min +
block argmax), fused so each step makes a single pass over the embedding
matrix instead of three (DESIGN.md §3).

FPF is inherently sequential in the number of representatives C (each argmax
depends on the previous update); the TPU win is inside a step: the (BN, D)
embedding tile is read once from HBM, the new distances, the min with the
carried state, and the per-block (max, argmax) reduction all happen in VMEM.
The tiny (n_blocks,) partials are reduced on the host side of the jit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, rep_ref, min_ref, newmin_ref, bmax_ref, bargmax_ref, *,
            block_n: int):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)          # (BN, D)
    rep = rep_ref[...].astype(jnp.float32)      # (1, D)
    diff = x - rep
    d2 = jnp.sum(diff * diff, axis=1)           # (BN,)
    new_min = jnp.minimum(min_ref[...], d2)
    newmin_ref[...] = new_min
    am = jnp.argmax(new_min)
    bmax_ref[0] = new_min[am]
    bargmax_ref[0] = (i * block_n + am).astype(jnp.int32)


def fpf_update_pallas(x: jax.Array, rep: jax.Array, min_d2: jax.Array,
                      block_n: int = 1024, interpret: bool = False):
    """x (N,D), rep (D,), min_d2 (N,) -> (new_min (N,), argmax, max).

    N % block_n == 0 required (ops.py pads with -inf min so pads never win).
    """
    n, d = x.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    new_min, bmax, bargmax = pl.pallas_call(
        functools.partial(_kernel, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((grid[0],), jnp.float32),
            jax.ShapeDtypeStruct((grid[0],), jnp.int32),
        ],
        interpret=interpret,
    )(x, rep.reshape(1, -1), min_d2)
    blk = jnp.argmax(bmax)
    return new_min, bargmax[blk], bmax[blk]
