"""Pure-jnp oracle for the FPF min-distance/argmax update step."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fpf_update_ref(x: jax.Array, rep: jax.Array, min_d2: jax.Array):
    """x (N,D), rep (D,), min_d2 (N,) -> (new_min_d2 (N,), argmax idx, max val).

    new_min = min(min_d2, ||x - rep||^2); the argmax of new_min is the next
    FPF representative (Gonzalez 1985).
    """
    d2 = jnp.sum((x.astype(jnp.float32) - rep.astype(jnp.float32)[None]) ** 2,
                 axis=1)
    new_min = jnp.minimum(min_d2, d2)
    idx = jnp.argmax(new_min)
    return new_min, idx.astype(jnp.int32), new_min[idx]
