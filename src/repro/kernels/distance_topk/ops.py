"""jit'd wrapper: pads to block multiples, picks impl.

impl="auto": Pallas on TPU, XLA reference otherwise (interpret mode is a
correctness tool, not an execution path — CPU benchmarks use the ref).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.distance_topk.kernel import distance_topk_pallas
from repro.kernels.distance_topk.ref import distance_topk_ref

PAD_DIST = jnp.float32(2.9e38)


def _pad_rows(a: jax.Array, mult: int):
    n = a.shape[0]
    pad = (-n) % mult
    if pad:
        a = jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
    return a, n


@functools.partial(jax.jit, static_argnames=("k", "impl", "block_n", "block_c",
                                             "interpret"))
def distance_topk(x: jax.Array, r: jax.Array, k: int, impl: str = "auto",
                  block_n: int = 256, block_c: int = 256,
                  interpret: bool = False):
    """x (N,D), r (C,D) -> (squared L2 dists (N,k), rep ids (N,k)), ascending."""
    if impl == "auto":
        impl = "pallas" if jax.devices()[0].platform == "tpu" else "xla"
    k_eff = min(k, r.shape[0])
    if impl == "xla":
        d, i = distance_topk_ref(x, r, k_eff)
    else:
        xp, n = _pad_rows(x, block_n)
        rp, c = _pad_rows(r, block_c)
        if rp.shape[0] != r.shape[0]:
            # padded reps must never win: offset their squared norm
            pad_rows = rp.shape[0] - r.shape[0]
            rp = jnp.concatenate(
                [rp[:c], jnp.full((pad_rows, r.shape[1]), 1e17, r.dtype)], 0)
        d, i = distance_topk_pallas(xp, rp, k_eff, block_n=block_n,
                                    block_c=block_c, interpret=interpret)
        d, i = d[:n], i[:n]
    if k_eff < k:  # fewer reps than k: tile the worst entry
        d = jnp.concatenate([d, jnp.broadcast_to(d[:, -1:], (d.shape[0],
                                                             k - k_eff))], 1)
        i = jnp.concatenate([i, jnp.broadcast_to(i[:, -1:], (i.shape[0],
                                                             k - k_eff))], 1)
    return d, i
