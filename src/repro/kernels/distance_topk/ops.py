"""jit'd wrapper: pads to block multiples, picks impl.

impl="auto": Pallas on TPU, XLA reference otherwise (interpret mode is a
correctness tool, not an execution path — CPU benchmarks use the ref).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.distance_topk.kernel import distance_topk_pallas
from repro.kernels.distance_topk.ref import distance_topk_ref

#: Squared-distance sentinel marking padded top-k columns (k > n_reps).
#: Strictly larger than any real squared distance the kernels produce, and
#: finite in float32 so arithmetic on it stays NaN-free.  Consumers
#: (repro.core.propagation, repro.kernels.propagate) treat columns at or
#: above this value as absent: zero weight, never double-counted.
PAD_DIST = 2.9e38


def _pad_rows(a: jax.Array, mult: int):
    n = a.shape[0]
    pad = (-n) % mult
    if pad:
        a = jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
    return a, n


def _pad_rep_value(dtype, d: int) -> float:
    """Per-dimension fill value for padded representative rows.

    Padded reps must lose every top-k comparison, so their squared norm
    (computed in float32 by both impls) should dwarf real distances — but it
    must stay FINITE: the value must be representable in the embedding dtype
    (1e17 overflows float16 to inf, and inf - inf in the distance expansion
    yields NaNs that win the top-k), and d * value^2 must not overflow
    float32.
    """
    v = (1e36 / max(d, 1)) ** 0.5
    if jnp.issubdtype(dtype, jnp.inexact):
        v = min(v, float(jnp.finfo(dtype).max) / 4.0)
    return v


@functools.partial(jax.jit, static_argnames=("k", "impl", "block_n", "block_c",
                                             "interpret"))
def distance_topk(x: jax.Array, r: jax.Array, k: int, impl: str = "auto",
                  block_n: int = 256, block_c: int = 256,
                  interpret: bool = False):
    """x (N,D), r (C,D) -> (squared L2 dists (N,k), rep ids (N,k)), ascending.

    With fewer reps than k, the trailing ``k - n_reps`` columns are padding:
    their distance is the :data:`PAD_DIST` sentinel (ids tile the worst real
    entry so they stay in-range).  Weighted consumers must mask them out —
    tiling the worst *distance* instead would silently double-weight that
    rep in propagation.
    """
    if impl == "auto":
        impl = "pallas" if jax.devices()[0].platform == "tpu" else "xla"
    k_eff = min(k, r.shape[0])
    if impl == "xla":
        d, i = distance_topk_ref(x, r, k_eff)
    else:
        xp, n = _pad_rows(x, block_n)
        rp, c = _pad_rows(r, block_c)
        if rp.shape[0] != r.shape[0]:
            # padded reps must never win: offset their squared norm (finite
            # in r.dtype and in the float32 norm computation — see
            # _pad_rep_value)
            pad_rows = rp.shape[0] - r.shape[0]
            rp = jnp.concatenate(
                [rp[:c], jnp.full((pad_rows, r.shape[1]),
                                  _pad_rep_value(r.dtype, r.shape[1]),
                                  r.dtype)], 0)
        d, i = distance_topk_pallas(xp, rp, k_eff, block_n=block_n,
                                    block_c=block_c, interpret=interpret)
        d, i = d[:n], i[:n]
    if k_eff < k:  # fewer reps than k: sentinel distances, in-range ids
        pad_shape = (d.shape[0], k - k_eff)
        d = jnp.concatenate([d, jnp.full(pad_shape, PAD_DIST, d.dtype)], 1)
        last = (i[:, -1:] if k_eff
                else jnp.zeros((i.shape[0], 1), i.dtype))  # repless: id 0
        i = jnp.concatenate([i, jnp.broadcast_to(last, pad_shape)], 1)
    return d, i
