"""Pure-jnp oracle for blocked pairwise-L2 + top-k."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def distance_topk_ref(x: jax.Array, r: jax.Array, k: int):
    """x (N,D), r (C,D) -> (dists (N,k), ids (N,k)), ascending by distance.

    Distances are squared L2 (monotone in L2; callers take sqrt if needed).
    """
    xf = x.astype(jnp.float32)
    rf = r.astype(jnp.float32)
    d2 = (jnp.sum(xf * xf, axis=1)[:, None]
          + jnp.sum(rf * rf, axis=1)[None, :]
          - 2.0 * xf @ rf.T)
    d2 = jnp.maximum(d2, 0.0)
    neg_top, ids = jax.lax.top_k(-d2, k)
    return -neg_top, ids
