"""Pallas TPU kernel: blocked pairwise squared-L2 distances with a *running
top-k* — the bandwidth-optimal form of the paper's N x C distance computation
(DESIGN.md §3).

Instead of materializing the (N, C) distance matrix in HBM (the paper's
``NCD * c_D`` term as implemented on GPU), each (row-block i, col-block j)
grid step computes a (BN, BC) tile on the MXU (2 x BN x BC x D FLOPs via one
``dot``) and folds it into a per-row top-k held in VMEM across the j sweep —
O(N*k) HBM writes instead of O(N*C).

Top-k maintenance is sort-free (TPU-friendly): k rounds of (min, argmin,
mask) extract the k smallest of the fresh tile, which are then merged with
the running top-k through another k rounds over the concatenated 2k
candidates.  All ops are VPU-native (max/where/iota); no lax.sort / top_k
inside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_BIG = 3.0e38  # python scalar: jnp constants can't be captured by kernels


def _k_smallest(vals: jax.Array, ids: jax.Array, k: int):
    """vals/ids (BN, M) -> k smallest per row, via k extraction rounds."""
    bn = vals.shape[0]
    out_v = jnp.zeros((bn, k), jnp.float32)
    out_i = jnp.zeros((bn, k), jnp.int32)

    def body(t, carry):
        vals_c, out_v, out_i = carry
        m = jnp.min(vals_c, axis=1)
        am = jnp.argmin(vals_c, axis=1)
        sel = jnp.take_along_axis(ids, am[:, None], axis=1)[:, 0]
        out_v = out_v.at[:, t].set(m)
        out_i = out_i.at[:, t].set(sel)
        onehot = jax.lax.broadcasted_iota(jnp.int32, vals_c.shape, 1) == am[:, None]
        vals_c = jnp.where(onehot, NEG_BIG, vals_c)
        return vals_c, out_v, out_i

    _, out_v, out_i = jax.lax.fori_loop(0, k, body, (vals, out_v, out_i))
    return out_v, out_i


def _kernel(x_ref, r_ref, xsq_ref, rsq_ref, val_ref, idx_ref, *, k: int,
            block_c: int):
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)          # (BN, D)
    r = r_ref[...].astype(jnp.float32)          # (BC, D)
    d2 = (xsq_ref[...][:, None] + rsq_ref[...][None, :]
          - 2.0 * jax.lax.dot_general(
              x, r, (((1,), (1,)), ((), ())),
              preferred_element_type=jnp.float32))
    d2 = jnp.maximum(d2, 0.0)                   # (BN, BC)
    col_ids = (j * block_c
               + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1))
    tile_v, tile_i = _k_smallest(d2, col_ids, k)

    @pl.when(j == 0)
    def _init():
        val_ref[...] = tile_v
        idx_ref[...] = tile_i

    @pl.when(j > 0)
    def _merge():
        cand_v = jnp.concatenate([val_ref[...], tile_v], axis=1)
        cand_i = jnp.concatenate([idx_ref[...], tile_i], axis=1)
        new_v, new_i = _k_smallest(cand_v, cand_i, k)
        val_ref[...] = new_v
        idx_ref[...] = new_i


def distance_topk_pallas(x: jax.Array, r: jax.Array, k: int,
                         block_n: int = 256, block_c: int = 256,
                         interpret: bool = False):
    """x (N,D), r (C,D) -> (squared dists (N,k), ids (N,k)) ascending.

    N % block_n == 0 and C % block_c == 0 are required (ops.py pads).
    """
    n, d = x.shape
    c = r.shape[0]
    assert n % block_n == 0 and c % block_c == 0, (n, c, block_n, block_c)
    xsq = jnp.sum(x.astype(jnp.float32) ** 2, axis=1)
    rsq = jnp.sum(r.astype(jnp.float32) ** 2, axis=1)
    grid = (n // block_n, c // block_c)
    return pl.pallas_call(
        functools.partial(_kernel, k=k, block_c=block_c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_c, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((block_c,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, k), jnp.float32),
            jax.ShapeDtypeStruct((n, k), jnp.int32),
        ],
        interpret=interpret,
    )(x, r, xsq, rsq)
