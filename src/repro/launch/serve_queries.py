"""Query server CLI: serve declarative ``QuerySpec`` s over HTTP.

Mounts one or more workloads into a
:class:`~repro.serve.registry.WorkloadRegistry` and starts a
:class:`~repro.serve.server.QueryServer`.  Single-workload (today's form,
unchanged):

    PYTHONPATH=src python -m repro.launch.serve_queries \\
        --workload night-street --n-frames 3000 --quick \\
        --port 8123 --admission-window 0.05 --store /tmp/tasti/ns

Multi-workload: repeat ``--workload NAME=DATASET[:INDEX_STEM]`` (or point
``--manifest`` at a workloads.json, see
:meth:`~repro.serve.registry.WorkloadRegistry.from_manifest`) and route
requests with the client's ``--workload``:

    PYTHONPATH=src python -m repro.launch.serve_queries \\
        --workload video=night-street --workload text=wikisql \\
        --n-frames 600 --quick --port 8123 --store-dir /tmp/tasti/multi

    PYTHONPATH=src python -m repro.serve.client --url http://127.0.0.1:8123 \\
        --workload text \\
        --spec '{"kind": "aggregation", "score": "score_is_select", "err": 0.1}'

Multi-workload mounts load *lazily*: the port binds immediately and each
workload pays its index build/load when the first spec routes to it
(``--preload`` forces everything up front).  With a store stem per workload
(``--store-dir`` names them ``DIR/<name>``), every oracle flush writes
labels through to ``<stem>.labels.json``/``.labels.npz`` — a restarted
server answers repeat queries on every workload with zero fresh target-DNN
invocations.  The process prints one ``{"serving": ...}`` JSON line when the
port is bound, then blocks until SIGINT or a client POSTs ``/shutdown``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.core.schema import WORKLOAD_NAMES
from repro.obs import Observability
from repro.serve.registry import WorkloadRegistry, WorkloadSpec
from repro.serve.server import QueryServer
from repro.serve.store.format import parse_bytes


def _parse_mounts(args):
    """``--workload`` values -> ``(registry, multi)``.  Each value is either
    a bare dataset name (legacy single-workload; also the mount name) or
    ``NAME=DATASET[:INDEX_STEM]``.  ``multi`` — any named mount or more than
    one — is the one definition both flag validation and the lazy/eager
    startup decision share."""
    values = args.workload or ["night-street"]
    multi = len(values) > 1 or any("=" in v for v in values)
    if args.store and args.store_dir:
        raise SystemExit("--store and --store-dir are exclusive: one stem "
                         "vs one per-workload directory")
    try:
        parse_bytes(args.store_budget)
    except ValueError as e:
        raise SystemExit(f"--store-budget: {e}") from None
    if multi and args.store:
        raise SystemExit("--store is the single-workload form; use "
                         "--store-dir (or a manifest) for per-workload "
                         "stores")
    if multi and args.index:
        raise SystemExit("--index is the single-workload form; use "
                         "NAME=DATASET:INDEX (or a manifest) per workload")
    registry = WorkloadRegistry()
    for value in values:
        name, _, rest = value.partition("=")
        if rest:
            dataset, _, index = rest.partition(":")
        else:
            dataset, index = name, None
        if dataset not in WORKLOAD_NAMES:
            raise SystemExit(
                f"unknown dataset {dataset!r} in --workload {value!r}; "
                f"known: {list(WORKLOAD_NAMES)}")
        if name in registry:
            raise SystemExit(f"workload {name!r} mounted twice")
        if not multi:
            index = index or args.index
        store = args.store if not multi else None
        if args.store_dir:
            store = os.path.join(args.store_dir, name)
        registry.declare(WorkloadSpec(
            name=name, dataset=dataset, n_records=args.n_frames,
            index=index or None, store=store,
            store_budget=args.store_budget, quick=args.quick,
            variant=args.variant, n_train=args.n_train, n_reps=args.n_reps,
            k=args.k, triplet_steps=args.triplet_steps,
            oracle_batch=args.oracle_batch,
            oracle_replicas=args.oracle_replicas,
            oracle_backend=args.oracle_backend, crack=args.crack))
    return registry, multi


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="serve declarative QuerySpecs over HTTP, one workload "
                    "or many")
    ap.add_argument("--workload", action="append", default=None,
                    metavar="NAME[=DATASET[:INDEX]]",
                    help="workload to mount (repeatable).  A bare dataset "
                         f"name ({'/'.join(WORKLOAD_NAMES)}) serves one "
                         "workload exactly as before; NAME=DATASET mounts it "
                         "under NAME, with an optional saved-index stem "
                         "after a colon")
    ap.add_argument("--manifest", default=None,
                    help="JSON manifest of workloads to mount (exclusive "
                         "with --workload; see docs/api/serving.md)")
    ap.add_argument("--default-workload", default=None,
                    help="workload unrouted specs execute against "
                         "(default: the first mounted)")
    ap.add_argument("--n-frames", type=int, default=8000)
    ap.add_argument("--index", default=None,
                    help="path stem of a saved index to load (single-"
                         "workload form; use NAME=DATASET:INDEX or the "
                         "manifest otherwise)")
    ap.add_argument("--variant", default="T", choices=["T", "PT"])
    ap.add_argument("--n-train", type=int, default=400)
    ap.add_argument("--n-reps", type=int, default=800)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--triplet-steps", type=int, default=400)
    ap.add_argument("--quick", action="store_true",
                    help="tiny build budgets (smoke tests / CI)")
    ap.add_argument("--preload", action="store_true",
                    help="load every mounted workload before binding the "
                         "port (default: lazy, on first routed spec)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8123,
                    help="0 picks an ephemeral port (printed at startup)")
    ap.add_argument("--admission-window", type=float, default=0.05,
                    help="seconds the first request of a batch waits for "
                         "co-travelers on the same workload to coalesce "
                         "into one session")
    ap.add_argument("--max-workers", type=int, default=4,
                    help="concurrently executing sessions (all workloads)")
    ap.add_argument("--share", action="append", default=None,
                    metavar="NAME=WEIGHT",
                    help="weighted fair share for a mounted workload "
                         "(repeatable; default 1.0 each): among equally "
                         "urgent waiting work the workload with the lowest "
                         "active/share ratio runs next")
    ap.add_argument("--workload-cap", action="append", default=None,
                    metavar="NAME=N",
                    help="hard cap on a workload's concurrently executing "
                         "sessions (repeatable); a capped workload cannot "
                         "monopolize the worker pool")
    ap.add_argument("--no-preempt", action="store_true",
                    help="never pause a running scan for higher-priority "
                         "arrivals (default: preempt at oracle-slice "
                         "boundaries)")
    ap.add_argument("--preempt-slice", type=int, default=None,
                    help="ids per preemption slice (default: each "
                         "workload's oracle microbatch size)")
    ap.add_argument("--oracle-batch", type=int, default=64)
    ap.add_argument("--oracle-replicas", type=int, default=1,
                    help="target-DNN replica workers behind each workload's "
                         "broker microbatcher (one pool per workload, shared "
                         "by its sessions); results are identical at any "
                         "count, flushes overlap across replicas")
    ap.add_argument("--oracle-backend", default="thread",
                    choices=["thread", "process"],
                    help="replica worker kind: threads (default; right when "
                         "the target DNN releases the GIL) or forked worker "
                         "processes (compute-bound pure-Python/numpy "
                         "oracles; see docs/runbook.md)")
    ap.add_argument("--crack", action="store_true",
                    help="engine-level default for the cracking feedback loop")
    ap.add_argument("--store", default=None,
                    help="path stem for the persistent label store (single-"
                         "workload form; default: the --index stem)")
    ap.add_argument("--store-dir", default=None,
                    help="directory for per-workload label stores, one "
                         "<dir>/<name> stem each (multi-workload form)")
    ap.add_argument("--store-budget", default=None, metavar="BYTES",
                    help="hot-tier byte budget per label store (e.g. "
                         "67108864 or '64m'); labels past it spill to warm "
                         "segment files on disk instead of growing the heap "
                         "(default: unbounded)")
    ap.add_argument("--no-obs", action="store_true",
                    help="disable observability (tracing, /metrics, the "
                         "flight recorder); default: enabled — overhead is "
                         "bounded by the obs_overhead benchmark gate")
    ap.add_argument("--trace-buffer", type=int, default=256,
                    help="completed request traces the flight recorder "
                         "retains for /debug/traces postmortems")
    args = ap.parse_args(argv)

    if args.manifest:
        if args.workload:
            raise SystemExit("--manifest and --workload are exclusive: the "
                             "manifest declares every mount")
        if args.store or args.store_dir or args.index or args.store_budget:
            raise SystemExit("--store/--store-dir/--store-budget/--index "
                             "are exclusive with --manifest: manifest "
                             "entries carry their own index and store "
                             "configuration")
        # silently ignoring a build/oracle flag would let an operator
        # believe it took effect; manifest entries carry these per workload
        overridden = [
            "--" + attr.replace("_", "-")
            for attr in ("n_frames", "variant", "n_train", "n_reps", "k",
                         "triplet_steps", "quick", "oracle_batch",
                         "oracle_replicas", "oracle_backend", "crack")
            if getattr(args, attr) != ap.get_default(attr)]
        if overridden:
            raise SystemExit(
                f"{'/'.join(overridden)} are exclusive with --manifest: "
                "set them per workload in the manifest entries")
        registry = WorkloadRegistry.from_manifest(args.manifest)
        multi = True
    else:
        registry, multi = _parse_mounts(args)
    if args.default_workload:
        try:
            registry.set_default(args.default_workload)
        except KeyError as e:
            raise SystemExit(f"--default-workload: {e.args[0]}") from None

    def parse_pairs(values, flag, cast):
        out = {}
        for value in values or []:
            name, sep, raw = value.partition("=")
            if not sep or not name:
                raise SystemExit(f"{flag} takes NAME=VALUE, got {value!r}")
            if name not in registry:
                raise SystemExit(f"{flag} {value!r}: workload {name!r} is "
                                 f"not mounted ({sorted(registry.names())})")
            try:
                out[name] = cast(raw)
            except ValueError:
                raise SystemExit(
                    f"{flag} {value!r}: bad value {raw!r}") from None
        return out

    shares = parse_pairs(args.share, "--share", float)
    caps = parse_pairs(args.workload_cap, "--workload-cap", int)

    lazy = multi and not args.preload
    if not lazy:
        # single-workload (and --preload) builds up front, exactly as
        # before: a broken index/store fails here, not on the first request
        for name in registry.names():
            try:
                registry.get(name)
            except (ValueError, OSError) as e:
                raise SystemExit(
                    f"cannot load workload {name!r}: {e}") from None

    obs = Observability(enabled=not args.no_obs,
                        trace_buffer=args.trace_buffer)
    server = QueryServer(registry, host=args.host, port=args.port,
                         admission_window=args.admission_window,
                         max_workers=args.max_workers,
                         shares=shares, workload_caps=caps,
                         preempt=not args.no_preempt,
                         preempt_slice=args.preempt_slice,
                         obs=obs).start()
    # per-workload oracle_replicas/records/store truth lives in describe()
    print(json.dumps({"serving": server.url,
                      "default_workload": registry.default,
                      "workloads": registry.describe()}),
          flush=True)
    # park until a client POSTs /shutdown (or SIGINT); wait() only returns
    # after shutdown fully finished, including the final store saves
    try:
        server.wait()
    except KeyboardInterrupt:
        print("[serve] shutting down", file=sys.stderr)
        server.shutdown()


if __name__ == "__main__":
    main()
