"""Query server CLI: serve declarative ``QuerySpec`` s over HTTP.

Builds (or loads) a TASTI index, opens the persistent
:class:`~repro.serve.store.LabelStore` next to it, and starts a
:class:`~repro.serve.server.QueryServer`:

    PYTHONPATH=src python -m repro.launch.serve_queries \\
        --workload night-street --n-frames 3000 --quick \\
        --port 8123 --admission-window 0.05 --store /tmp/tasti/ns

    PYTHONPATH=src python -m repro.serve.client --url http://127.0.0.1:8123 \\
        --spec '{"kind": "aggregation", "score": "score_count", "err": 0.1}'

With ``--store`` (defaulting to ``--index`` when one is given), every oracle
flush writes labels through to ``<stem>.labels.json``/``.labels.npz`` — a
restarted server answers repeat queries with zero fresh target-DNN
invocations.  The process prints one ``{"serving": ...}`` JSON line when the
port is bound, then blocks until SIGINT or a client POSTs ``/shutdown``.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core.engine import QueryEngine
from repro.core.index import TastiIndex
from repro.core.pipeline import TastiConfig, build_tasti
from repro.core.schema import make_workload
from repro.core.triplet import TripletConfig
from repro.serve.server import QueryServer
from repro.serve.store import LabelStore


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="serve declarative QuerySpecs over HTTP")
    ap.add_argument("--workload", default="night-street",
                    choices=["night-street", "taipei", "amsterdam", "wikisql"])
    ap.add_argument("--n-frames", type=int, default=8000)
    ap.add_argument("--index", default=None,
                    help="path stem of a saved index to load; omit to build")
    ap.add_argument("--variant", default="T", choices=["T", "PT"])
    ap.add_argument("--n-train", type=int, default=400)
    ap.add_argument("--n-reps", type=int, default=800)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--triplet-steps", type=int, default=400)
    ap.add_argument("--quick", action="store_true",
                    help="tiny build budgets (smoke tests / CI)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8123,
                    help="0 picks an ephemeral port (printed at startup)")
    ap.add_argument("--admission-window", type=float, default=0.05,
                    help="seconds the first request of a batch waits for "
                         "co-travelers to coalesce into one session")
    ap.add_argument("--max-workers", type=int, default=4,
                    help="concurrently executing sessions")
    ap.add_argument("--oracle-batch", type=int, default=64)
    ap.add_argument("--oracle-replicas", type=int, default=1,
                    help="target-DNN replica workers behind the broker's "
                         "microbatcher (one pool shared by all sessions); "
                         "results are identical at any count, flushes "
                         "overlap across replicas")
    ap.add_argument("--crack", action="store_true",
                    help="engine-level default for the cracking feedback loop")
    ap.add_argument("--store", default=None,
                    help="path stem for the persistent label store "
                         "(default: the --index stem; omit both to serve "
                         "without persistence)")
    args = ap.parse_args(argv)

    kw = ({"n_frames": args.n_frames} if args.workload != "wikisql"
          else {"n_records": args.n_frames})
    wl = make_workload(args.workload, **kw)

    if args.index:
        index = TastiIndex.load(args.index)
        if index.n_records != len(wl.features):
            raise SystemExit(
                f"index covers {index.n_records} records but workload "
                f"{wl.name} has {len(wl.features)}; pass matching --n-frames")
    else:
        if args.quick:
            cfg = TastiConfig(n_train=100, n_reps=200, k=4,
                              triplet=TripletConfig(steps=60, batch=128),
                              pretrain_steps=40)
        else:
            cfg = TastiConfig(n_train=args.n_train, n_reps=args.n_reps,
                              k=args.k,
                              triplet=TripletConfig(steps=args.triplet_steps))
        index = build_tasti(wl, cfg, variant=args.variant).index

    engine = QueryEngine(index, wl, crack=args.crack,
                         max_oracle_batch=args.oracle_batch,
                         oracle_replicas=args.oracle_replicas)
    store = None
    store_stem = args.store or args.index
    if store_stem:
        store = LabelStore.for_index(store_stem, index)
        seeded = store.attach(engine.broker, engine)
        print(f"[serve] label store {store.json_path}: "
              f"{len(store)} labels, {seeded} seeded into the broker",
              file=sys.stderr)

    server = QueryServer(engine, host=args.host, port=args.port,
                         admission_window=args.admission_window,
                         max_workers=args.max_workers, store=store).start()
    print(json.dumps({"serving": server.url, "workload": wl.name,
                      "records": index.n_records, "reps": index.n_reps,
                      "index_version": index.version,
                      "oracle_replicas": args.oracle_replicas,
                      "store_labels": None if store is None else len(store)}),
          flush=True)
    # park until a client POSTs /shutdown (or SIGINT); wait() only returns
    # after shutdown fully finished, including the final store save
    try:
        server.wait()
    except KeyboardInterrupt:
        print("[serve] shutting down", file=sys.stderr)
        server.shutdown()


if __name__ == "__main__":
    main()
