"""Index-construction driver: build (or crack/update) a TASTI index over a
workload and persist it (versioned JSON + npz; see ``TastiIndex.save``).

    PYTHONPATH=src python -m repro.launch.build_index \
        --workload night-street --n-frames 8000 --variant T \
        --out /tmp/tasti/night_street

Query the saved index declaratively with ``repro.launch.query``.

At pod scale the embedding pass is the prefill-shaped workload hillclimbed in
EXPERIMENTS.md §Perf/B (``--backbone`` selects any assigned architecture as
the embedder; the default MLP matches the paper-scale reproduction).
"""
from __future__ import annotations

import argparse
import json
import time


from repro.core.pipeline import TastiConfig, build_tasti
from repro.core.schema import WORKLOAD_NAMES, make_workload
from repro.core.triplet import TripletConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="night-street",
                    choices=list(WORKLOAD_NAMES))
    ap.add_argument("--n-frames", type=int, default=8000)
    ap.add_argument("--variant", default="T", choices=["T", "PT"])
    ap.add_argument("--n-train", type=int, default=400)
    ap.add_argument("--n-reps", type=int, default=800)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--embed-dim", type=int, default=128)
    ap.add_argument("--triplet-steps", type=int, default=400)
    ap.add_argument("--backbone", default="mlp",
                    help="'mlp' or a config name (e.g. tasti-embedder)")
    ap.add_argument("--out", required=True)
    args = ap.parse_args()

    wl = make_workload(args.workload, n_records=args.n_frames)
    cfg = TastiConfig(n_train=args.n_train, n_reps=args.n_reps, k=args.k,
                      embed_dim=args.embed_dim,
                      triplet=TripletConfig(steps=args.triplet_steps))
    t0 = time.time()
    system = build_tasti(wl, cfg, variant=args.variant)
    dt = time.time() - t0
    system.index.save(args.out)
    cost = system.index.cost
    print(json.dumps({
        "workload": wl.name,
        "records": len(wl.features),
        "variant": args.variant,
        "reps": system.index.n_reps,
        "k": system.index.k,
        "target_dnn_invocations": cost.target_invocations,
        "modeled_construction_s": round(cost.wall_clock_s(), 1),
        "actual_build_s_cpu": round(dt, 1),
        "out": args.out,
        "format_version": system.index.FORMAT_VERSION,
    }, indent=2))


if __name__ == "__main__":
    main()
