"""Fault-tolerant LM training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --preset ci --steps 50 --ckpt-dir /tmp/ckpt

Composes the full runtime: sharded data pipeline (resumable state carried in
checkpoints), AdamW, async checkpointing, straggler monitoring, retry-on-
failure, and optional failure injection (--inject-failure-at) to demonstrate
checkpoint/restart end to end.  On a pod this runs under the production mesh;
on CPU it uses the 1-device host mesh with the same code path.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import TokenDataset
from repro.models import lm
from repro.optim.adamw import OptimizerConfig, init_opt_state
from repro.runtime.fault_tolerance import StragglerMonitor, run_resilient
from repro.train.steps import make_train_step


def preset_config(arch: str, preset: str):
    cfg = get_config(arch)
    if preset == "ci":
        cfg = cfg.smoke()
        return cfg, 8, 64
    if preset == "100m":
        # ~100M-parameter member of the arch family for the e2e example
        cfg = dataclasses.replace(
            cfg.smoke(), name=cfg.name + "-100m", d_model=576, n_layers=12,
            n_heads=9, n_kv_heads=3, head_dim=64,
            d_ff=2304, vocab_size=32000, vocab_pad_multiple=128)
        return cfg, 8, 256
    return cfg, 256, 4096  # full (pod-scale)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--preset", default="ci", choices=["ci", "100m", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg, batch, seq = preset_config(args.arch, args.preset)
    opt = OptimizerConfig(peak_lr=args.lr, min_lr=args.lr * 0.1,
                          warmup_steps=max(args.steps // 20, 5),
                          total_steps=args.steps,
                          state_dtype=cfg.opt_state_dtype)
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    opt_state = init_opt_state(params, opt)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={batch} seq={seq} steps={args.steps}")

    ds = TokenDataset(vocab_size=cfg.vocab_size, seed=0)
    step_fn = jax.jit(make_train_step(cfg, opt))
    ckpt = Checkpointer(args.ckpt_dir)
    monitor = StragglerMonitor()
    losses = []
    injected = {"armed": args.inject_failure_at >= 0}

    def one_step(state, step):
        if injected["armed"] and step == args.inject_failure_at:
            injected["armed"] = False
            raise RuntimeError("injected failure (see --inject-failure-at)")
        # pipeline state rides in the checkpointed tree as numeric leaves
        params, opt_state, (epoch, offset) = state
        batch_np = ds.batch(int(epoch), int(offset), batch, seq)
        jb = {k: jnp.asarray(v) for k, v in batch_np.items()}
        params, opt_state, metrics = step_fn(params, opt_state, jb)
        losses.append(float(metrics["loss"]))
        if step % 10 == 0:
            print(f"  step {step:5d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e}")
        return (params, opt_state,
                (epoch, jnp.int32(offset + 1))), metrics

    t0 = time.time()
    report = run_resilient(
        one_step, (params, opt_state, (jnp.int32(0), jnp.int32(0))),
        n_steps=args.steps, ckpt=ckpt,
        ckpt_every=args.ckpt_every, monitor=monitor)
    dt = time.time() - t0
    print(f"[train] done: {report.steps_completed} steps in {dt:.0f}s, "
          f"restarts={report.restarts}, "
          f"first-loss={losses[0]:.4f} last-loss={losses[-1]:.4f}")
    assert losses[-1] < losses[0], "loss did not decrease"


if __name__ == "__main__":
    main()
